"""Microbenchmarks: control-plane + kernel-path costs on this host.

Emitted in the harness CSV contract (name,us_per_call,derived).  Kernel
numbers are interpret-mode (CPU) — correctness-path costs, NOT TPU perf;
TPU performance is modeled by the roofline analysis instead.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

# the shared wall-clock helper (repro.obs.timing) — this module's old
# private ``_time`` copy, now one implementation for every bench
from repro.obs.timing import timeit_us as _time


def run(quiet: bool = False, sharded: bool = False,
        fleet: bool = False) -> List[Dict]:
    """``sharded=True`` (CLI: ``--sharded``) adds the mesh-sharded /
    donated single-run rows — they spawn a multi-device
    ``scripts/bench_el.py`` subprocess (minutes, needs forced host
    devices), so they are opt-in and the default run keeps the quick
    in-process contract existing callers (``benchmarks.run``) rely on;
    the committed ``BENCH_el.json`` is the canonical record of those
    tiers.  ``fleet=True`` (CLI: ``--fleet``) likewise adds the
    multi-tenant serving row via a ``scripts/bench_fleet.py``
    subprocess; ``BENCH_fleet.json`` is its canonical record."""
    rows = []

    # bandit decision latency (cloud control plane)
    from repro.core.bandit import BanditState, arm_costs, select_arm
    st = BanditState.create(10)
    costs = arm_costs(10, 10.0, 50.0)
    rng = np.random.default_rng(0)
    for i in range(10):
        st.update(i, 0.5, costs[i])
    rows.append(dict(name="bandit_select_arm",
                     us_per_call=_time(lambda: select_arm(st, 1e4, costs,
                                                          "ol4el", rng)),
                     derived="decisions/s"))

    # weighted average aggregation (1M params, 4 edges)
    from repro.federated import weighted_average
    trees = [{"w": jnp.ones((1024, 256))} for _ in range(4)]
    agg = jax.jit(lambda ts: weighted_average(ts, [1.0] * 4))
    agg(trees)[0].block_until_ready() if isinstance(agg(trees), tuple) else None
    rows.append(dict(name="aggregate_1M_params_4edges",
                     us_per_call=_time(
                         lambda: jax.block_until_ready(agg(trees)), n=20),
                     derived="params_avg"))

    # XLA blocked attention step (the dry-run fallback path), small shape
    from repro.models import layers as L
    from repro.config import ModelConfig
    cfg = ModelConfig(d_model=256, n_heads=4, n_kv_heads=4, dtype="float32")
    p = L.init_attention(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 512, 256))
    pos = jnp.arange(512)
    att = jax.jit(lambda x: L.attention(p, cfg, x, pos, impl="blocked"))
    jax.block_until_ready(att(x))
    rows.append(dict(name="xla_blocked_attention_b1_s512_d256",
                     us_per_call=_time(lambda: jax.block_until_ready(att(x)),
                                       n=10),
                     derived="fwd"))

    # K-means E-step: Pallas interpret vs jnp ref (correctness path cost)
    from repro.kernels.kmeans_assign.ops import assign_with_dist
    from repro.kernels.kmeans_assign.ref import assign_ref
    xk = jax.random.normal(jax.random.key(2), (4096, 64))
    ck = jax.random.normal(jax.random.key(3), (3, 64))
    ref_j = jax.jit(lambda x, c: assign_ref(x, c))
    jax.block_until_ready(ref_j(xk, ck))
    rows.append(dict(name="kmeans_assign_ref_n4096_d64_k3",
                     us_per_call=_time(
                         lambda: jax.block_until_ready(ref_j(xk, ck)), n=20),
                     derived="Estep"))

    # simulator round throughput (SVM, 3 edges)
    from benchmarks.common import run_el
    t0 = time.perf_counter()
    r = run_el("svm", "ol4el", "async", 6.0, budget=1500.0, n_data=2000)
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(dict(name="el_sim_svm_async_per_aggregation",
                     us_per_call=dt / max(r.n_aggregations, 1),
                     derived=f"acc={r.final_metric:.3f}"))

    # host-driven sync loop vs the fully in-graph fast path (ONE compiled
    # lax.while_loop per run): per-aggregation cost, warm in both cases
    import dataclasses as _dc
    from repro.config import get_config as _get_config
    from repro.data import make_wafer_dataset, partition_edges
    from repro.el import ELSession
    from repro.federated import ClassicExecutor
    from repro.models import build_model
    train_d, test_d = make_wafer_dataset(n=2000, seed=0)
    exp = _get_config("svm-wafer")
    svm = build_model(exp.model)
    ol = _dc.replace(exp.ol4el, mode="sync", policy="ol4el", n_edges=3,
                     budget=6000.0, heterogeneity=6.0, utility="eval_gain",
                     seed=0)
    edges = partition_edges(train_d, 3, alpha=1.0, seed=0)
    ex = ClassicExecutor(svm, edges, test_d, batch=64, lr=0.05)
    ns = [len(e["y"]) for e in edges]

    def session():
        return ELSession(ol, metric_name="accuracy", lr=0.05) \
            .with_executor(ex, n_samples=ns)

    session().run_sync()                        # warm the executor jits
    t0 = time.perf_counter()
    host = session().run_sync()
    host_us = (time.perf_counter() - t0) * 1e6 / max(host.n_aggregations, 1)
    rows.append(dict(name="el_sync_host_per_round", us_per_call=host_us,
                     derived=f"acc={host.final_metric:.3f}"))

    sess = session()
    sess.run_sync_ingraph()                     # compile the program
    t0 = time.perf_counter()
    ing = sess.run_sync_ingraph()
    ing_us = (time.perf_counter() - t0) * 1e6 / max(ing.n_aggregations, 1)
    rows.append(dict(
        name="el_sync_ingraph_per_round", us_per_call=ing_us,
        derived=f"acc={ing.final_metric:.3f},"
                f"speedup={host_us / max(ing_us, 1e-9):.1f}x_vs_host"))

    # in-graph telemetry rings (repro.obs): per-round cost of the
    # instrumented sync program vs the bare one — both warm, min-of-3
    # (the acceptance bound is <10% overhead per round)
    from repro.obs.timing import repeat_s
    sess.run_sync_ingraph(telemetry=64)         # compile instrumented
    off_us = min(repeat_s(sess.run_sync_ingraph, 3)) * 1e6 \
        / max(ing.n_aggregations, 1)
    on = sess.run_sync_ingraph(telemetry=64)
    on_us = min(repeat_s(lambda: sess.run_sync_ingraph(telemetry=64),
                         3)) * 1e6 / max(on.n_aggregations, 1)
    rows.append(dict(
        name="el_telemetry_overhead_per_round",
        us_per_call=max(on_us - off_us, 0.0),
        derived=f"on={on_us:.0f}us,off={off_us:.0f}us,overhead="
                f"{(on_us - off_us) / max(off_us, 1e-9) * 100:.1f}pct"))

    # host-driven async event queue vs the fully in-graph event-horizon
    # program (repro.el.events: argmin finish-times + masked merges, no
    # host priority queue): per-event cost, warm in both cases
    ol_async = _dc.replace(ol, mode="async")

    def async_session():
        return ELSession(ol_async, metric_name="accuracy", lr=0.05) \
            .with_executor(ex, n_samples=ns)

    async_session().run_async()                 # warm the executor jits
    t0 = time.perf_counter()
    ahost = async_session().run_async()
    ahost_us = (time.perf_counter() - t0) * 1e6 / max(ahost.n_aggregations,
                                                      1)
    rows.append(dict(name="el_async_host_per_event", us_per_call=ahost_us,
                     derived=f"acc={ahost.final_metric:.3f}"))

    asess = async_session()
    asess.run_async_ingraph()                   # compile the program
    t0 = time.perf_counter()
    aing = asess.run_async_ingraph()
    aing_us = (time.perf_counter() - t0) * 1e6 / max(aing.n_aggregations, 1)
    rows.append(dict(
        name="el_async_ingraph_per_event", us_per_call=aing_us,
        derived=f"acc={aing.final_metric:.3f},"
                f"speedup={ahost_us / max(aing_us, 1e-9):.1f}x_vs_host"))

    # ablation sweep: 4 (ucb_c × seed) cells as ONE vmapped compiled
    # program vs the sequential host-loop equivalent (the pre-sweep way
    # benchmarks ran grids); per-grid wall-clock, warm in both cases
    from repro.el.sweep import SweepSpec
    spec = SweepSpec(ucb_c=(1.0, 2.0), budget=(3000.0,), seeds=(0, 1),
                     max_rounds=128)
    t0 = time.perf_counter()
    for ccfg in spec.cell_cfgs(ol):
        ELSession(ccfg, metric_name="accuracy", lr=0.05) \
            .with_executor(ex, n_samples=ns).run_sync()
    seq_host_us = (time.perf_counter() - t0) * 1e6
    sw = session()
    sw.sweep(spec)                              # compile the sweep
    t0 = time.perf_counter()
    rep_sw = sw.sweep(spec)
    sweep_us = (time.perf_counter() - t0) * 1e6
    rows.append(dict(
        name="el_sweep_vmapped_4cells", us_per_call=sweep_us,
        derived=f"acc={float(np.nanmean(rep_sw.final_metrics())):.3f},"
                f"speedup={seq_host_us / max(sweep_us, 1e-9):.1f}"
                "x_vs_seq_host"))

    # mesh-sharded + donated single-run data plane vs the replicated
    # in-graph program (scripts/bench_el.py in a subprocess — the
    # sharded rows need forced host devices, which must be set before
    # jax initializes, so they cannot run in this process)
    if sharded:
        rows.extend(_sharded_rows())

    # multi-tenant EL serving: a FleetServer cohort (slot waves with
    # mid-flight refill) vs sequential per-tenant sessions
    # (scripts/bench_fleet.py in a subprocess — keeps this process's
    # jax device config untouched)
    if fleet:
        rows.extend(_fleet_rows())

    if not quiet:
        for row in rows:
            print(f"micro {row['name']:40s} {row['us_per_call']:12.1f} us  "
                  f"{row['derived']}", flush=True)
    return rows


def _sharded_rows() -> List[Dict]:
    rows = []
    import json as _json
    import os as _os
    import subprocess as _sp
    import sys as _sys
    import tempfile as _tempfile
    repo = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
    with _tempfile.TemporaryDirectory() as td:
        bench_out = _os.path.join(td, "bench_el.json")
        r = _sp.run(
            [_sys.executable, _os.path.join(repo, "scripts", "bench_el.py"),
             "--devices", "4", "--skip-host", "--repeats", "3",
             "--samples", "2000", "--budget", "2000", "--max-rounds", "48",
             "--max-events", "128", "--out", bench_out],
            capture_output=True, text=True, timeout=1800,
            env=dict(_os.environ,
                     PYTHONPATH=_os.path.join(repo, "src")))
        if r.returncode != 0:
            raise RuntimeError(f"bench_el subprocess failed:\n{r.stdout}"
                               f"\n{r.stderr}")
        sub = _json.load(open(bench_out))["rows"]

    def _peak(row):
        p = row.get("peak_live_bytes")
        return "n/a" if p is None else f"{p / 1e6:.2f}MB"

    base = sub["el_sync_ingraph"]
    for name, tag in (("el_sync_ingraph_donate", "donated"),
                      ("el_sync_sharded", "sharded_2x2"),
                      ("el_sync_sharded_donate", "sharded_donated")):
        row = sub[name]
        rows.append(dict(
            name=f"{name}_per_round",
            us_per_call=row["us_per_aggregation"],
            derived=f"{tag},speedup={base['us_per_aggregation'] / max(row['us_per_aggregation'], 1e-9):.1f}"
                    f"x_vs_replicated,peak={_peak(row)}"
                    f"(vs{_peak(base)}),alias={row.get('alias_bytes', 0)}B"))
    abase = sub["el_async_ingraph"]
    arow = sub["el_async_sharded"]
    rows.append(dict(
        name="el_async_sharded_per_event",
        us_per_call=arow["us_per_aggregation"],
        derived=f"speedup={abase['us_per_aggregation'] / max(arow['us_per_aggregation'], 1e-9):.1f}"
                f"x_vs_replicated,peak={_peak(arow)}(vs{_peak(abase)})"))
    return rows


def _fleet_rows() -> List[Dict]:
    rows = []
    import json as _json
    import os as _os
    import subprocess as _sp
    import sys as _sys
    import tempfile as _tempfile
    repo = _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__)))
    with _tempfile.TemporaryDirectory() as td:
        bench_out = _os.path.join(td, "bench_fleet.json")
        r = _sp.run(
            [_sys.executable,
             _os.path.join(repo, "scripts", "bench_fleet.py"),
             "--tenants", "64", "--repeats", "1", "--out", bench_out],
            capture_output=True, text=True, timeout=1800,
            env=dict(_os.environ,
                     PYTHONPATH=_os.path.join(repo, "src")))
        if r.returncode != 0:
            raise RuntimeError(f"bench_fleet subprocess failed:\n{r.stdout}"
                               f"\n{r.stderr}")
        sub = _json.load(open(bench_out))["rows"]
    flt = sub["fleet_64"]
    rows.append(dict(
        name="fleet_tenants_per_sec",
        us_per_call=1e6 / max(flt["tenants_per_sec"], 1e-9),
        derived=f"{flt['tenants_per_sec']:.1f}t/s,"
                f"speedup={flt['speedup_vs_sequential_host']:.1f}"
                "x_vs_seq_host,"
                f"{flt['speedup_vs_sequential_ingraph']:.1f}"
                "x_vs_seq_ingraph,"
                f"waves={flt['waves']},compiles={flt['compiles']}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded", action="store_true",
                    help="also run the mesh-sharded/donated single-run "
                         "rows (spawns a multi-device scripts/bench_el.py "
                         "subprocess; minutes)")
    ap.add_argument("--fleet", action="store_true",
                    help="also run the multi-tenant fleet serving row "
                         "(spawns a scripts/bench_fleet.py subprocess)")
    _a = ap.parse_args()
    run(sharded=_a.sharded, fleet=_a.fleet)
