"""OL4EL vs task-allocation baselines under fleet churn.

The paper's comparison (§V) runs against static task-allocation
schemes; this benchmark replays it under the fleet dynamics a real edge
deployment has — edges dropping out and rejoining on a seeded Bernoulli
schedule — using the scenario engine (``repro.el.scenarios``).  The
whole (policy × churn_rate × seed) grid compiles as ONE vmapped
program: ``policy`` rides the traced ``policy_id`` knob through the
in-graph ``lax.switch`` (``repro.el.scenarios.baselines``) and
``churn_rate`` re-draws the ``scn_active`` schedule per cell, so every
cell shares the executable.

Policies (the in-graph policy switch, branch order fixed):
  * ol4el        — the paper's budget-limited UCB bandit
  * task_alloc   — greedy max-feasible workload (arXiv 1811.03748 style)
  * delay_energy — delay/energy budget pacing (arXiv 2012.00143 style)

Output: one row per (policy, churn_rate) with the seed-mean final
accuracy and consumption — the "OL4EL vs baselines under churn" curve
(README: Fleet dynamics & baselines).  ``--smoke`` shrinks the grid to
a CI-sized proof that the multi-policy scenario sweep compiles and
every cell runs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Sequence

import numpy as np

from repro.el.scenarios import ChurnSpec, ScenarioSpec
from repro.el.scenarios.baselines import INGRAPH_POLICY_ORDER

DEFAULT_RATES = (0.0, 0.2, 0.4)


def run(seeds: Sequence[int] = (0, 1, 2),
        rates: Sequence[float] = DEFAULT_RATES,
        budget: float = 1200.0, n_data: int = 4000,
        heterogeneity: float = 6.0, churn_period: int = 32,
        max_rounds: int = 256, quiet: bool = False) -> List[Dict]:
    """The churn curve: seed-mean accuracy per (policy, churn_rate)."""
    from benchmarks.common import run_el_sweep
    from repro.el.sweep import SweepSpec
    scenario = ScenarioSpec(churn=ChurnSpec(rate=float(rates[0]),
                                            period=churn_period))
    spec = SweepSpec(policy=INGRAPH_POLICY_ORDER,
                     churn_rate=tuple(float(r) for r in rates),
                     seeds=tuple(int(s) for s in seeds),
                     max_rounds=max_rounds)
    rep = run_el_sweep("svm", spec, heterogeneity, budget=budget,
                       n_data=n_data, lr=0.01, batch=32,
                       scenario=scenario)
    rows = []
    for g in rep.grouped_rows():
        rows.append(dict(figure="churn_baselines",
                         policy=str(g["policy"]),
                         churn_rate=float(g["churn_rate"]),
                         n_seeds=int(g["n_seeds"]),
                         metric=round(g["final_metric"], 4),
                         metric_std=round(g["final_metric_std"], 4),
                         consumed=round(g["total_consumed"], 1)))
        if not quiet:
            print(f"policy {g['policy']:12s} churn={g['churn_rate']:.2f} "
                  f"acc={g['final_metric']:.4f}"
                  f"±{g['final_metric_std']:.4f} "
                  f"({g['n_seeds']} seeds)", flush=True)
    if not quiet:
        print(f"churn sweep: {rep.summary()}", flush=True)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3 policies × 2 rates × 1 seed compiled grid — "
                         "the CI proof that the multi-policy scenario "
                         "sweep runs as one program (~1 min on CPU)")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default: BENCH_churn_"
                         "baselines.json at the repo root; smoke runs "
                         "do not write)")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = run(seeds=(0,), rates=(0.0, 0.4), budget=800.0,
                   n_data=1000, max_rounds=64)
        assert len(rows) == 6, rows
        ok = all(np.isfinite(r["metric"]) and r["metric"] > 0.5
                 for r in rows)
        # churn must cost SOMETHING somewhere: not every cell equal
        if not ok:
            print("SMOKE FAILED:", rows, file=sys.stderr)
            sys.exit(1)
        print("churn baselines smoke OK")
        return
    rows = run()
    out = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_churn_baselines.json")
    with open(out, "w") as f:
        json.dump({"figure": "churn_baselines",
                   "policies": list(INGRAPH_POLICY_ORDER),
                   "rows": rows}, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
