"""Policy ablation: resolving the paper's under-specified §IV.B procedure.

The paper's text ("probabilistic selection ∝ frequency") taken literally
never uses utility in the selection step.  This benchmark compares:
  * ol4el      — our interpretation: P ∝ UCB-density × frequency
  * freq_only  — the literal reading: P ∝ frequency
  * greedy     — argmax UCB density (pure fractional-KUBE)
  * eps_greedy — ε-greedy on density
  * ucb_bv     — variable-cost UCB-BV1
  * uniform    — uniform over affordable arms (floor)
  * fixed_i    — the Fixed-I baseline

on (a) a controlled bandit instance with a known best arm, and (b) the
paper's SVM testbed.  Findings are recorded in EXPERIMENTS.md §Repro
note 5.

The ol4el hyperparameter frontier (``ucb_sweep``) runs through the
compiled sweep engine: the whole ucb_c × seed grid is ONE vmapped XLA
program (``repro.el.sweep``) instead of a sequential host loop.
``--smoke`` runs a tiny 2×2 grid — the CI proof that the compiled sweep
path works on CPU.
"""

from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Sequence

import numpy as np

from repro.core.bandit import BanditState, arm_costs, regret_oracle, \
    select_arm

POLICIES = ("ol4el", "freq_only", "greedy", "eps_greedy", "ucb_bv",
            "uniform", "fixed_i")


def synthetic_bandit(policy: str, seed: int, budget: float = 60000.0,
                     noise: float = 0.05) -> float:
    """Earned utility / oracle on a skewed instance (arm 6 best density)."""
    rng = np.random.default_rng(seed)
    means = np.array([0.10, 0.12, 0.15, 0.20, 0.30, 0.45, 0.70, 0.55,
                      0.40, 0.30])
    costs = arm_costs(10, comp_cost=8.0, comm_cost=40.0)
    st = BanditState.create(10)
    residual, earned = budget, 0.0
    while True:
        arm = select_arm(st, residual, costs, policy=policy, rng=rng)
        if arm < 0:
            break
        u = means[arm] + noise * rng.standard_normal()
        st.update(arm, u, costs[arm])
        residual -= costs[arm]
        earned += means[arm]
    return earned / regret_oracle(means, costs, budget)


def el_testbed(policy: str, seed: int) -> float:
    from benchmarks.common import run_el
    mode = "async" if policy not in ("ac_sync",) else "sync"
    return run_el("svm", policy, mode, heterogeneity=6.0, budget=1200.0,
                  n_data=4000, seed=seed, lr=0.01, batch=32).final_metric


def ucb_sweep(seeds: Sequence[int] = (0, 1),
              ucb_grid: Sequence[float] = (0.5, 2.0, 8.0),
              budget: float = 1200.0, n_data: int = 4000,
              heterogeneity: float = 6.0, max_rounds: int = 256,
              quiet: bool = False) -> List[Dict]:
    """The ol4el exploration-constant frontier: every (ucb_c, seed) cell
    of the grid runs inside ONE compiled vmapped program.

    Seeds here vary only the in-program bandit/minibatch RNG streams —
    the dataset/partition/init are fixed at the base seed (program
    constants), which isolates selection-rule stochasticity per ucb_c
    point.  The ``el_testbed`` rows above resample data per seed, so the
    two sections measure deliberately different randomness sources."""
    from benchmarks.common import run_el_sweep
    from repro.el.sweep import SweepSpec
    spec = SweepSpec(ucb_c=tuple(float(c) for c in ucb_grid),
                     seeds=tuple(int(s) for s in seeds),
                     max_rounds=max_rounds)
    rep = run_el_sweep("svm", spec, heterogeneity, budget=budget,
                       n_data=n_data, lr=0.01, batch=32)
    rows = []
    for g in rep.grouped_rows():
        rows.append(dict(figure="policy_ablation",
                         policy=f"ol4el[c={g['ucb_c']:g}]",
                         svm_acc=round(g["final_metric"], 4),
                         consumed=round(g["total_consumed"], 1)))
        if not quiet:
            print(f"policy ol4el[c={g['ucb_c']:g}] "
                  f"svm_acc={g['final_metric']:.4f} "
                  f"(sweep, {g['n_seeds']} seeds)", flush=True)
    if not quiet:
        print(f"ucb sweep: {rep.summary()}", flush=True)
    return rows


def run(seeds=(0, 1, 2, 3, 4), with_testbed: bool = True,
        quiet: bool = False) -> List[Dict]:
    rows = []
    for policy in POLICIES:
        frac = float(np.mean([synthetic_bandit(policy, s) for s in seeds]))
        row = dict(figure="policy_ablation", policy=policy,
                   oracle_frac=round(frac, 4))
        if with_testbed:
            accs = [el_testbed(policy, s) for s in seeds[:2]]
            row["svm_acc"] = round(float(np.mean(accs)), 4)
        rows.append(row)
        if not quiet:
            msg = (f"policy {policy:10s} oracle_frac={row['oracle_frac']:.3f}"
                   + (f" svm_acc={row['svm_acc']:.4f}"
                      if with_testbed else ""))
            print(msg, flush=True)
    if with_testbed:
        rows += ucb_sweep(seeds=seeds[:2], quiet=quiet)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny 2×2 (ucb_c × seed) compiled-sweep grid "
                         "only — the CI fast path (~30s on CPU)")
    args = ap.parse_args(argv)
    if args.smoke:
        rows = ucb_sweep(seeds=(0, 1), ucb_grid=(1.0, 4.0), budget=800.0,
                         n_data=1000, max_rounds=64)
        assert len(rows) == 2, rows
        if not all(np.isfinite(r["svm_acc"]) and r["svm_acc"] > 0.5
                   for r in rows):
            print("SMOKE FAILED:", rows, file=sys.stderr)
            sys.exit(1)
        print("sweep smoke OK")
        return
    run()


if __name__ == "__main__":
    main()
