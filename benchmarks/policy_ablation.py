"""Policy ablation: resolving the paper's under-specified §IV.B procedure.

The paper's text ("probabilistic selection ∝ frequency") taken literally
never uses utility in the selection step.  This benchmark compares:
  * ol4el      — our interpretation: P ∝ UCB-density × frequency
  * freq_only  — the literal reading: P ∝ frequency
  * greedy     — argmax UCB density (pure fractional-KUBE)
  * eps_greedy — ε-greedy on density
  * ucb_bv     — variable-cost UCB-BV1
  * uniform    — uniform over affordable arms (floor)
  * fixed_i    — the Fixed-I baseline

on (a) a controlled bandit instance with a known best arm, and (b) the
paper's SVM testbed.  Findings are recorded in EXPERIMENTS.md §Repro
note 5.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.bandit import BanditState, arm_costs, regret_oracle, \
    select_arm

POLICIES = ("ol4el", "freq_only", "greedy", "eps_greedy", "ucb_bv",
            "uniform", "fixed_i")


def synthetic_bandit(policy: str, seed: int, budget: float = 60000.0,
                     noise: float = 0.05) -> float:
    """Earned utility / oracle on a skewed instance (arm 6 best density)."""
    rng = np.random.default_rng(seed)
    means = np.array([0.10, 0.12, 0.15, 0.20, 0.30, 0.45, 0.70, 0.55,
                      0.40, 0.30])
    costs = arm_costs(10, comp_cost=8.0, comm_cost=40.0)
    st = BanditState.create(10)
    residual, earned = budget, 0.0
    while True:
        arm = select_arm(st, residual, costs, policy=policy, rng=rng)
        if arm < 0:
            break
        u = means[arm] + noise * rng.standard_normal()
        st.update(arm, u, costs[arm])
        residual -= costs[arm]
        earned += means[arm]
    return earned / regret_oracle(means, costs, budget)


def el_testbed(policy: str, seed: int) -> float:
    from benchmarks.common import run_el
    mode = "async" if policy not in ("ac_sync",) else "sync"
    return run_el("svm", policy, mode, heterogeneity=6.0, budget=1200.0,
                  n_data=4000, seed=seed, lr=0.01, batch=32).final_metric


def run(seeds=(0, 1, 2, 3, 4), with_testbed: bool = True,
        quiet: bool = False) -> List[Dict]:
    rows = []
    for policy in POLICIES:
        frac = float(np.mean([synthetic_bandit(policy, s) for s in seeds]))
        row = dict(figure="policy_ablation", policy=policy,
                   oracle_frac=round(frac, 4))
        if with_testbed:
            accs = [el_testbed(policy, s) for s in seeds[:2]]
            row["svm_acc"] = round(float(np.mean(accs)), 4)
        rows.append(row)
        if not quiet:
            msg = (f"policy {policy:10s} oracle_frac={row['oracle_frac']:.3f}"
                   + (f" svm_acc={row['svm_acc']:.4f}"
                      if with_testbed else ""))
            print(msg, flush=True)
    return rows


if __name__ == "__main__":
    run()
