"""Benchmark harness entry point: ``python -m benchmarks.run``.

One benchmark per paper artifact (Fig. 3/4/5) plus the roofline analysis
over the dry-run artifacts and host microbenchmarks.  Prints the harness
CSV contract ``name,us_per_call,derived`` at the end.

Modes:
  --fast   tiny sizes (CI smoke, ~1 min)
  default  reduced-but-representative sizes (~10-20 min)
  --full   paper-scale (20k samples, H sweep to 15, 100 edges)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=[None, "fig3", "fig4", "fig5", "roofline",
                             "micro", "policies"])
    ap.add_argument("--out", default="results/bench.json")
    args = ap.parse_args(argv)

    if args.fast:
        kw3 = dict(budget=1200.0, n_data=2000, seeds=(0,),
                   h_values=[1.0, 6.0, 15.0])
        kw4 = dict(budget=1200.0, n_data=2000, seeds=(0,))
        kw5 = dict(budget=400.0, n_data=2000, seeds=(0,),
                   edge_counts=[3, 10], h_values=[1.0, 15.0])
    elif args.full:
        kw3 = dict(budget=5000.0, n_data=20000, seeds=(0, 1, 2))
        kw4 = dict(budget=5000.0, n_data=20000, seeds=(0, 1, 2))
        kw5 = dict(budget=600.0, n_data=20000, seeds=(0, 1),
                   edge_counts=[3, 10, 30, 100])
    else:
        kw3 = dict(budget=3000.0, n_data=8000, seeds=(0, 1),
                   h_values=[1.0, 3.0, 6.0, 9.0, 15.0])
        kw4 = dict(budget=3000.0, n_data=8000, seeds=(0, 1))
        kw5 = dict(budget=600.0, n_data=8000, seeds=(0, 1),
                   edge_counts=[3, 10, 30], h_values=[1.0, 5.0, 15.0])

    all_rows = []
    t_start = time.time()

    if args.only in (None, "fig3"):
        from benchmarks import fig3_heterogeneity
        all_rows += fig3_heterogeneity.run(**kw3)
    if args.only in (None, "fig4"):
        from benchmarks import fig4_tradeoff
        all_rows += fig4_tradeoff.run(**kw4)
    if args.only in (None, "fig5"):
        from benchmarks import fig5_scalability
        all_rows += fig5_scalability.run(**kw5)
    if args.only in (None, "policies"):
        from benchmarks import policy_ablation
        pol_seeds = (0,) if args.fast else (0, 1, 2)
        all_rows += [dict(r, metric=r.get("svm_acc", r["oracle_frac"]))
                     for r in policy_ablation.run(
                         seeds=pol_seeds, with_testbed=not args.fast)]
    roofline_rows = []
    if args.only in (None, "roofline"):
        from benchmarks import roofline
        roofline_rows = roofline.run()
    micro_rows = []
    if args.only in (None, "micro"):
        from benchmarks import microbench
        micro_rows = microbench.run()

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"figures": all_rows, "roofline": roofline_rows,
                   "micro": micro_rows,
                   "wall_s": time.time() - t_start}, f, indent=1,
                  default=str)

    # harness CSV contract: name,us_per_call,derived
    print("\nname,us_per_call,derived")
    for r in micro_rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    for r in all_rows:
        name = ":".join(str(r.get(k)) for k in
                        ("figure", "workload", "algo", "H", "n_edges",
                         "consumption_frac") if r.get(k) is not None)
        print(f"{name},0,{r['metric']:.4f}")
    for r in roofline_rows:
        name = f"roofline:{r['arch']}:{r['shape']}:{r['mesh']}:{r['step']}"
        print(f"{name},{r['bound_s'] * 1e6:.2f},{r['dominant']}")
    print(f"# total wall time: {time.time() - t_start:.1f}s")


if __name__ == "__main__":
    main()
