"""Fig. 3 replication: model accuracy vs edge heterogeneity (H = 1..15).

Paper setup: 3 heterogeneous edges, fixed per-edge budget (5000 ms ~ 5000
cost units), SVM (accuracy) and K-means (F1).  Algorithms: OL4EL-sync,
OL4EL-async, AC-sync [12], Fixed-I.

Paper claims validated here (EXPERIMENTS.md):
  * accuracy degrades as H grows, for every algorithm;
  * OL4EL outperforms AC-sync and Fixed-I throughout;
  * OL4EL-sync wins at low H (<=5); OL4EL-async wins at high H;
  * peak OL4EL-async advantage over baselines ~ 12%.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import WORKLOADS, mean_over_seeds, run_el

ALGOS = [("ol4el", "sync"), ("ol4el", "async"), ("ac_sync", "sync"),
         ("fixed_i", "sync")]
H_VALUES = [1.0, 3.0, 5.0, 6.0, 9.0, 12.0, 15.0]


def run(budget: float = 5000.0, n_data: int = 20000, seeds=(0, 1, 2),
        h_values=None, quiet: bool = False) -> List[Dict]:
    rows = []
    for workload in WORKLOADS:
        for h in (h_values or H_VALUES):
            for policy, mode in ALGOS:
                agg = mean_over_seeds(
                    lambda seed: run_el(workload, policy, mode, h,
                                        budget=budget, n_data=n_data,
                                        seed=seed),
                    seeds)
                row = dict(figure="fig3", workload=workload, H=h,
                           algo=f"{policy}-{mode}", **agg)
                rows.append(row)
                if not quiet:
                    print(f"fig3 {workload:6s} H={h:4.0f} "
                          f"{policy}-{mode:5s} metric={agg['metric']:.4f} "
                          f"(±{agg['metric_std']:.4f}) aggs={agg['aggs']:.0f}",
                          flush=True)
    return rows


if __name__ == "__main__":
    run()
