"""Fig. 4 replication: model accuracy vs edge resource consumption (H=6).

The paper plots the metric as resource consumption accumulates: all
algorithms improve with more resource, OL4EL dominating AC-sync at every
consumption level and OL4EL-async reaching the highest final accuracy.

The (ol4el, sync) rows run through the compiled sweep engine
(``repro.el.sweep``), one sweep per seed (a fig4 seed resamples the
dataset/partition/init, which are program constants), with the
consumption curves reduced from the per-cell round records.  The
(ol4el, async) SVM rows run through the compiled event-horizon program
(``run_async_ingraph``, ``repro.el.events``).  The other algorithms
(non-ol4el policies) stay on the host paths, and so does the K-means
workload (its F1 metric is host-side).
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from benchmarks.common import WORKLOADS, run_el, run_el_sweep
from repro.el.sweep import SweepSpec

ALGOS = [("ol4el", "sync"), ("ol4el", "async"), ("ac_sync", "sync"),
         ("fixed_i", "sync")]
FRACTIONS = [0.2, 0.4, 0.6, 0.8, 1.0]


def _best_at_fractions(metrics: Sequence[float],
                       consumed: Sequence[float]) -> List[float]:
    """Best metric achieved by each consumption fraction (the host and
    sweep rows share this reduction)."""
    total = consumed[-1] if len(consumed) else 0.0
    curve, best = [], 0.0
    for frac in FRACTIONS:
        target = frac * total
        vals = [m for m, c in zip(metrics, consumed)
                if c <= target and np.isfinite(m)]
        best = max(vals) if vals else best
        curve.append(best)
    return curve


def run(budget: float = 5000.0, n_data: int = 20000, heterogeneity: float = 6.0,
        seeds=(0, 1, 2), quiet: bool = False) -> List[Dict]:
    rows = []
    for workload in WORKLOADS:
        for policy, mode in ALGOS:
            # SVM (jittable accuracy) + (ol4el, sync): each seed replicate
            # runs through the compiled sweep engine.  One sweep PER seed
            # (not one sweep over the seed axis): a fig4 seed resamples the
            # dataset/partition/init like every other algorithm row, and
            # those are baked into a compiled program as constants — only
            # in-program RNG streams vmap across cells.
            if (policy, mode) == ("ol4el", "sync") and workload == "svm":
                curves = []
                for seed in seeds:
                    rep = run_el_sweep(
                        workload, SweepSpec(seeds=(seed,), max_rounds=256),
                        heterogeneity, budget=budget, seed=seed,
                        n_data=n_data)
                    n = int(rep.out["n_rounds"][0])
                    curves.append(_best_at_fractions(
                        rep.out["metric"][0][:n],
                        rep.out["consumed"][0][:n]))
            else:
                # the (ol4el, async) SVM rows get the compiled
                # event-horizon fast path; everything else is host-driven
                fast = ((policy, mode) == ("ol4el", "async")
                        and workload == "svm")
                curves = []
                for seed in seeds:
                    r = run_el(workload, policy, mode, heterogeneity,
                               budget=budget, n_data=n_data, seed=seed,
                               ingraph=fast)
                    curves.append(_best_at_fractions(
                        [rec.metric for rec in r.records],
                        [rec.total_consumed for rec in r.records]))
            mean_curve = np.mean(np.asarray(curves), axis=0)
            for frac, v in zip(FRACTIONS, mean_curve):
                rows.append(dict(figure="fig4", workload=workload,
                                 algo=f"{policy}-{mode}",
                                 consumption_frac=frac, metric=float(v)))
            if not quiet:
                curve_s = " ".join(f"{v:.3f}" for v in mean_curve)
                print(f"fig4 {workload:6s} {policy}-{mode:5s} "
                      f"metric@{FRACTIONS}: {curve_s}", flush=True)
    return rows


if __name__ == "__main__":
    run()
