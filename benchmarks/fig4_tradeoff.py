"""Fig. 4 replication: model accuracy vs edge resource consumption (H=6).

The paper plots the metric as resource consumption accumulates: all
algorithms improve with more resource, OL4EL dominating AC-sync at every
consumption level and OL4EL-async reaching the highest final accuracy.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import WORKLOADS, run_el

ALGOS = [("ol4el", "sync"), ("ol4el", "async"), ("ac_sync", "sync"),
         ("fixed_i", "sync")]
FRACTIONS = [0.2, 0.4, 0.6, 0.8, 1.0]


def run(budget: float = 5000.0, n_data: int = 20000, heterogeneity: float = 6.0,
        seeds=(0, 1, 2), quiet: bool = False) -> List[Dict]:
    rows = []
    for workload in WORKLOADS:
        for policy, mode in ALGOS:
            curves = []
            for seed in seeds:
                r = run_el(workload, policy, mode, heterogeneity,
                           budget=budget, n_data=n_data, seed=seed)
                total_budget = r.n_edges * budget
                curve = []
                best = 0.0
                for frac in FRACTIONS:
                    target = frac * r.total_consumed
                    vals = [rec.metric for rec in r.records
                            if rec.total_consumed <= target
                            and np.isfinite(rec.metric)]
                    best = max(vals) if vals else best
                    curve.append(best)
                curves.append(curve)
            mean_curve = np.mean(np.asarray(curves), axis=0)
            for frac, v in zip(FRACTIONS, mean_curve):
                rows.append(dict(figure="fig4", workload=workload,
                                 algo=f"{policy}-{mode}",
                                 consumption_frac=frac, metric=float(v)))
            if not quiet:
                curve_s = " ".join(f"{v:.3f}" for v in mean_curve)
                print(f"fig4 {workload:6s} {policy}-{mode:5s} "
                      f"metric@{FRACTIONS}: {curve_s}", flush=True)
    return rows


if __name__ == "__main__":
    run()
