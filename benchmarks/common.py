"""Shared benchmark harness utilities for the paper-figure replications."""

from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import numpy as np

from repro.config import get_config
from repro.data import (make_traffic_dataset, make_wafer_dataset,
                        partition_edges)
from repro.el import ELSession
from repro.federated import ClassicExecutor
from repro.models import build_model

# Paper workloads: ("svm", accuracy) and ("kmeans", F1).
WORKLOADS = ("svm", "kmeans")


@dataclasses.dataclass
class ELRun:
    workload: str
    policy: str
    mode: str
    heterogeneity: float
    n_edges: int
    budget: float
    final_metric: float
    n_aggregations: int
    total_consumed: float
    records: list


def make_el_session(workload: str, policy: str, mode: str,
                    heterogeneity: float, n_edges: int = 3,
                    budget: float = 5000.0, seed: int = 0,
                    n_data: int = 20000, cost_noise: float = 0.0,
                    cost_model: str = "fixed", max_interval: int = 10,
                    alpha: float = 100.0, async_alpha: float = 0.5,
                    lr: float | None = None,
                    batch: int | None = None,
                    scenario=None) -> ELSession:
    """Build a configured ``ELSession`` mirroring the paper's §V setup
    (dataset, config, executor, init params) — shared by the single-run
    and sweep harnesses.

    ``alpha`` is the Dirichlet concentration of the per-edge data split:
    the paper partitions data without skew, so the default is IID-like
    (alpha=100); pass alpha<=1 for the non-IID extension experiments.
    """
    if workload == "svm":
        train, test = make_wafer_dataset(n=n_data, seed=seed)
        exp = get_config("svm-wafer")
        metric, lr0, batch0 = "accuracy", 0.05, 64
        utility = "eval_gain"
    else:
        train, test = make_traffic_dataset(n=n_data, seed=seed)
        exp = get_config("kmeans-traffic")
        metric, lr0, batch0 = "f1", 1.0, 128
        utility = "param_delta"
    lr = lr0 if lr is None else lr
    batch = batch0 if batch is None else batch
    model = build_model(exp.model)
    ol = dataclasses.replace(
        exp.ol4el, mode=mode, policy=policy, n_edges=n_edges, budget=budget,
        heterogeneity=heterogeneity, utility=utility, seed=seed,
        cost_noise=cost_noise, cost_model=cost_model,
        max_interval=max_interval, scenario=scenario)
    edges = partition_edges(train, n_edges, alpha=alpha, seed=seed)
    ex = ClassicExecutor(model, edges, test, batch=batch, lr=lr)
    return ELSession(ol, metric_name=metric, lr=lr,
                     async_alpha=async_alpha).with_executor(
        ex, init_params=model.init(jax.random.key(seed)),
        n_samples=[len(e["y"]) for e in edges])


def run_el(workload: str, policy: str, mode: str, heterogeneity: float,
           n_edges: int = 3, budget: float = 5000.0, seed: int = 0,
           n_data: int = 20000, cost_noise: float = 0.0,
           cost_model: str = "fixed", max_interval: int = 10,
           alpha: float = 100.0, async_alpha: float = 0.5,
           lr: float | None = None, batch: int | None = None,
           ingraph: bool = False) -> ELRun:
    """One EL experiment through the ``repro.el.ELSession`` façade.
    ``ingraph=True`` routes the run through the compiled fast path for
    its mode: ``run_sync_ingraph`` (sync) or ``run_async_ingraph`` (the
    ``repro.el.events`` event-horizon program, async).
    """
    session = make_el_session(
        workload, policy, mode, heterogeneity, n_edges=n_edges,
        budget=budget, seed=seed, n_data=n_data, cost_noise=cost_noise,
        cost_model=cost_model, max_interval=max_interval, alpha=alpha,
        async_alpha=async_alpha, lr=lr, batch=batch)
    if not ingraph:
        res = session.run()
    elif mode == "sync":
        res = session.run_sync_ingraph()
    else:
        res = session.run_async_ingraph()
    return ELRun(workload, policy, mode, heterogeneity, n_edges, budget,
                 res.final_metric, res.n_aggregations, res.total_consumed,
                 res.records)


def run_el_sweep(workload: str, spec, heterogeneity: float = 6.0,
                 n_edges: int = 3, budget: float = 5000.0, seed: int = 0,
                 n_data: int = 20000, alpha: float = 100.0,
                 lr: float | None = None, batch: int | None = None,
                 mesh=None, scenario=None):
    """A whole (ucb_c × budget × heterogeneity × seeds) ablation grid as
    ONE compiled vmapped program (``repro.el.sweep``).  The base session
    is the same §V setup ``run_el`` uses with (ol4el, sync); returns the
    ``SweepReport``.  ``scenario=`` (a ``repro.el.scenarios.ScenarioSpec``)
    compiles the fleet-dynamics path, enabling the ``policy`` /
    ``churn_rate`` sweep axes."""
    session = make_el_session(
        workload, "ol4el", "sync", heterogeneity, n_edges=n_edges,
        budget=budget, seed=seed, n_data=n_data, alpha=alpha, lr=lr,
        batch=batch, scenario=scenario)
    return session.sweep(spec, mesh=mesh)


def mean_over_seeds(fn, seeds=(0, 1, 2)) -> Dict[str, float]:
    runs = [fn(seed=s) for s in seeds]
    return {
        "metric": float(np.mean([r.final_metric for r in runs])),
        "metric_std": float(np.std([r.final_metric for r in runs])),
        "aggs": float(np.mean([r.n_aggregations for r in runs])),
    }


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def us(self, n_calls: int = 1) -> float:
        return (time.perf_counter() - self.t0) * 1e6 / max(n_calls, 1)
