"""Fig. 5 replication: model accuracy vs number of edge servers (3..100).

Paper claims: OL4EL-async improves with more edges; accuracy drops with
heterogeneity; OL4EL-sync is best at H=1 but degrades dramatically at
H=15 (worse than async) because sync waits for the slowest edge.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.common import WORKLOADS, mean_over_seeds, run_el

EDGE_COUNTS = [3, 10, 30, 100]
H_VALUES = [1.0, 5.0, 15.0]


def run(budget: float = 600.0, n_data: int = 20000, seeds=(0, 1),
        edge_counts=None, h_values=None, quiet: bool = False) -> List[Dict]:
    # Slow-convergence regime (small lr/batch): convergence stays
    # budget-bound so the paper's edge-count scaling is visible instead of
    # every configuration saturating (see EXPERIMENTS.md §Repro).
    rows = []
    for workload in WORKLOADS:
        for n_edges in (edge_counts or EDGE_COUNTS):
            for h in (h_values or H_VALUES):
                for mode in ("async", "sync"):
                    lr = 0.008 if workload == "svm" else 0.5
                    agg = mean_over_seeds(
                        lambda seed: run_el(workload, "ol4el", mode, h,
                                            n_edges=n_edges, budget=budget,
                                            n_data=n_data, seed=seed,
                                            lr=lr, batch=32),
                        seeds)
                    rows.append(dict(figure="fig5", workload=workload,
                                     n_edges=n_edges, H=h,
                                     algo=f"ol4el-{mode}", **agg))
                    if not quiet:
                        print(f"fig5 {workload:6s} E={n_edges:3d} H={h:4.0f} "
                              f"ol4el-{mode:5s} metric={agg['metric']:.4f}",
                              flush=True)
    return rows


if __name__ == "__main__":
    run()
