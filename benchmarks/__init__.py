"""Benchmark suite: paper-figure replications (Fig. 3/4/5), roofline
analysis over dry-run artifacts, and host microbenchmarks."""

import os
import sys

# allow ``python -m benchmarks.run`` from the repo root without install
_src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _src not in sys.path:
    sys.path.insert(0, _src)
