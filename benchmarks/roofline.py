"""Roofline analysis from dry-run artifacts (deliverable g).

Reads the JSONL rows produced by ``repro.launch.dryrun`` and derives, per
(arch x shape x mesh):

    compute term    = HLO_FLOPs  / (chips * 197e12  bf16 FLOP/s)
    memory term     = HLO_bytes  / (chips * 819e9   HBM B/s)
    collective term = coll_bytes / (chips * 50e9    ICI B/s per link)

``cost_analysis`` on the SPMD-partitioned module reports *per-device*
flops/bytes, so terms divide by per-chip peaks directly (equivalent to the
global/(chips*peak) formulation).  MODEL_FLOPS uses 6*N_active*tokens for
training, 2*N_active*tokens for forward-only steps; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/recompute/dispatch waste.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, Iterable, List, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

SUGGESTIONS = {
    "compute": ("increase arithmetic efficiency: larger per-chip batch, "
                "reduce remat recompute, or shrink the useful-FLOPs gap"),
    "memory": ("cut HBM traffic: fuse elementwise chains, keep weights "
               "resident (bigger blocks), or drop precision of cached "
               "tensors"),
    "collective": ("cut collective volume: shard params over more axes "
                   "(fewer all-gathers), aggregate less often (larger OL4EL "
                   "interval), or overlap collectives with compute"),
}


def load_records(paths: Iterable[str]) -> List[Dict[str, Any]]:
    rows = []
    for path in paths:
        if not os.path.exists(path):
            continue
        with open(path) as f:
            for line in f:
                try:
                    rows.append(json.loads(line))
                except json.JSONDecodeError:
                    pass
    return rows


def model_flops(rec: Dict[str, Any]) -> float:
    n_active = rec.get("active_params", 0)
    shape = rec["shape"]
    from repro.config import INPUT_SHAPES
    s = INPUT_SHAPES[shape]
    if s.kind == "train":
        tokens = s.global_batch * s.seq_len
        mult = 6.0
        if rec.get("step") == "el_round":
            tokens *= rec.get("h_max", 1)
    elif s.kind == "prefill":
        tokens = s.global_batch * s.seq_len
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = s.global_batch
        mult = 2.0
    return mult * n_active * tokens


def _extract(rec: Dict[str, Any]):
    cost = rec.get("cost", {})
    return (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
            rec.get("collectives", {}).get("bytes_per_device", 0.0))


def calibration_index(records: List[Dict[str, Any]]) -> Dict:
    """(arch, shape, mesh, step) -> scan-corrected (flops, bytes, coll).

    XLA HloCostAnalysis counts lax.scan bodies once, so scanned-layer
    lowerings under-report; the 2-point unrolled depth calibration gives
    ``total = c1 + (n_groups - 1) * (c2 - c1)`` exactly.
    """
    pairs: Dict = {}
    for rec in records:
        tag = rec.get("tag", "")
        if not rec.get("ok") or "calib" not in tag:
            continue
        base, _, cal = tag.rpartition("calib")
        base = base.rstrip("|")
        key = (rec["arch"], rec["shape"], rec["mesh"], rec.get("step"),
               base)
        pairs.setdefault(key, {})["calib" + cal] = rec
    out = {}
    for key, d in pairs.items():
        if "calib1" not in d or "calib2" not in d:
            continue
        c1 = _extract(d["calib1"])
        c2 = _extract(d["calib2"])
        n = d["calib1"].get("n_groups_full") or 1
        out[key] = tuple(a + (n - 1) * (b - a) for a, b in zip(c1, c2))
    return out


def analyze(rec: Dict[str, Any],
            calib: Optional[Dict] = None) -> Optional[Dict[str, Any]]:
    if not rec.get("ok") or "calib" in rec.get("tag", ""):
        return None
    flops_dev, bytes_dev, coll_dev = _extract(rec)
    calibrated = False
    if calib:
        key = (rec["arch"], rec["shape"], rec["mesh"], rec.get("step"),
               rec.get("tag", ""))
        if key in calib:
            flops_dev, bytes_dev, coll_dev = calib[key]
            calibrated = True
    coll = rec.get("collectives", {})
    chips = rec.get("n_chips", 256)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_flops_global = flops_dev * chips
    useful = mf / hlo_flops_global if hlo_flops_global else float("nan")
    bound = max(terms.values())
    step_time = sum(terms.values())       # upper bound (no overlap)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "step": rec.get("step"), "tag": rec.get("tag", ""),
        "calibrated": calibrated,
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": useful,
        "suggestion": SUGGESTIONS[dominant],
        "collectives": coll.get("per_op", {}),
        "memory_bytes_per_dev": rec.get("memory", {}),
    }


def markdown_table(rows: List[Dict[str, Any]]) -> str:
    hdr = ("| arch | shape | mesh | step | compute s | memory s | "
           "collective s | dominant | useful FLOPs |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['useful_flops_ratio']:.2f} |")
    return hdr + "\n".join(lines) + "\n"


def run(paths: Optional[List[str]] = None, quiet: bool = False
        ) -> List[Dict[str, Any]]:
    paths = paths or sorted(glob.glob("results/dryrun*.jsonl")
                            + glob.glob("results/calib*.jsonl"))
    records = load_records(paths)
    calib = calibration_index(records)
    rows = []
    for rec in records:
        a = analyze(rec, calib)
        if a:
            rows.append(a)
    if not quiet:
        for r in rows:
            print(f"roofline {r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
                  f"{r['step']:12s} dom={r['dominant']:10s} "
                  f"bound={r['bound_s']:.3e}s useful={r['useful_flops_ratio']:.2f}",
                  flush=True)
    return rows


if __name__ == "__main__":
    import sys
    rows = run(sys.argv[1:] or None)
    print(markdown_table(rows))
