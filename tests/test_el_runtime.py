"""EL runtime: coordinator, aggregation, simulator, mesh el_round."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import OL4ELConfig, get_config, get_smoke_config
from repro.core.coordinator import CloudCoordinator, edge_speed_factors
from repro.data import (SyntheticLMData, make_traffic_dataset,
                        make_wafer_dataset, partition_edges)
from repro.federated import (ClassicExecutor, ELSimulator, init_el_state,
                             make_el_round, staleness_mix, weighted_average)
from repro.models import build_model


# ---------------------------------------------------------------------------
# coordinator
# ---------------------------------------------------------------------------


def test_edge_speed_factors_span_heterogeneity():
    f = edge_speed_factors(5, 6.0)
    assert f[0] == 1.0 and f[-1] == 6.0
    assert np.all(np.diff(f) > 0)


def test_coordinator_budget_accounting():
    cfg = OL4ELConfig(n_edges=3, budget=500.0, comp_cost=10.0,
                      comm_cost=50.0, heterogeneity=2.0, mode="async")
    c = CloudCoordinator(cfg)
    c.charge(0, 100.0)
    assert c.accounts[0].residual == 400.0
    assert c.total_consumed() == 100.0
    # slowest edge pays heterogeneity-scaled compute
    assert c.expected_cost(2, 4) == pytest.approx(4 * 20.0 + 50.0)
    assert c.expected_cost(0, 4) == pytest.approx(4 * 10.0 + 50.0)


def test_coordinator_sync_uses_binding_budget():
    cfg = OL4ELConfig(n_edges=2, budget=1000.0, heterogeneity=10.0,
                      mode="sync", policy="fixed_i", fixed_interval=2)
    c = CloudCoordinator(cfg)
    c.charge(1, 995.0)           # slow edge nearly broke
    assert c.decide() == -1 or c.all_exhausted()


def test_coordinator_terminates():
    cfg = OL4ELConfig(n_edges=2, budget=300.0, mode="async",
                      policy="ol4el")
    c = CloudCoordinator(cfg)
    for _ in range(100):
        i = c.decide(0)
        if i < 0:
            break
        c.charge(0, c.realized_cost(0, i))
        c.observe(0, i, 0.5, c.expected_cost(0, i))
    assert c.exhausted(0)


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------


@given(w=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=5),
       seed=st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_property_weighted_average_convex(w, seed):
    """Aggregate lies inside the per-coordinate min/max envelope."""
    ks = jax.random.split(jax.random.key(seed), len(w))
    trees = [{"a": jax.random.normal(k, (4, 3))} for k in ks]
    agg = weighted_average(trees, w)
    stack = jnp.stack([t["a"] for t in trees])
    assert bool(jnp.all(agg["a"] <= stack.max(0) + 1e-6))
    assert bool(jnp.all(agg["a"] >= stack.min(0) - 1e-6))


def test_weighted_average_identity():
    t = {"a": jnp.arange(6.0).reshape(2, 3)}
    agg = weighted_average([t, t, t], [1.0, 2.0, 3.0])
    np.testing.assert_allclose(np.asarray(agg["a"]), np.asarray(t["a"]))


def test_staleness_mix_endpoint():
    g = {"a": jnp.zeros(3)}
    e = {"a": jnp.ones(3)}
    np.testing.assert_allclose(np.asarray(staleness_mix(g, e, 1.0)["a"]), 1.0)
    np.testing.assert_allclose(np.asarray(staleness_mix(g, e, 0.0)["a"]), 0.0)


# ---------------------------------------------------------------------------
# simulator end-to-end (paper workloads)
# ---------------------------------------------------------------------------


def _svm_sim(mode, policy, h=4.0, budget=1500.0, seed=0):
    train, test = make_wafer_dataset(n=2000, seed=seed)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ol = dataclasses.replace(
        exp.ol4el, mode=mode, policy=policy, n_edges=3, budget=budget,
        heterogeneity=h, utility="eval_gain", seed=seed)
    edges = partition_edges(train, 3, alpha=1.0, seed=seed)
    ex = ClassicExecutor(model, edges, test, batch=64, lr=0.05)
    sim = ELSimulator(ex, ol, model.init(jax.random.key(seed)),
                      n_samples=[len(e["y"]) for e in edges],
                      metric_name="accuracy", lr=0.05)
    return sim.run()


@pytest.mark.parametrize("mode,policy", [
    ("sync", "ol4el"), ("async", "ol4el"), ("sync", "fixed_i"),
    ("sync", "ac_sync"), ("async", "ucb_bv")])
def test_simulator_runs_and_learns(mode, policy):
    res = _svm_sim(mode, policy)
    assert res.final_metric > 0.5          # well above 1/8 chance
    assert res.n_aggregations >= 2
    assert res.terminated_reason in ("budget_exhausted", "max_rounds",
                                     "max_events")


def test_simulator_respects_budgets():
    res = _svm_sim("async", "ol4el", budget=800.0)
    # per-edge consumption can exceed budget by at most one final block
    assert res.total_consumed <= 3 * (800.0 + 800.0)


def test_kmeans_utility_param_delta():
    train, test = make_traffic_dataset(n=1500)
    exp = get_config("kmeans-traffic")
    model = build_model(exp.model)
    ol = dataclasses.replace(exp.ol4el, mode="async", policy="ol4el",
                             n_edges=3, budget=800.0, heterogeneity=4.0,
                             utility="param_delta")
    edges = partition_edges(train, 3, alpha=2.0)
    ex = ClassicExecutor(model, edges, test, batch=128, lr=1.0)
    sim = ELSimulator(ex, ol, model.init(jax.random.key(1)),
                      metric_name="f1", lr=1.0)
    res = sim.run()
    assert res.final_metric > 0.5


# ---------------------------------------------------------------------------
# mesh el_round (single-device smoke; full meshes exercised by dry-run)
# ---------------------------------------------------------------------------


def _el_setup(n_edges=2, h_max=3):
    cfg = get_smoke_config("qwen3-1.7b")
    m = build_model(cfg.model)
    state = init_el_state(m, cfg.train, n_edges, jax.random.key(0))
    data = SyntheticLMData.for_model(cfg.model, 2, 32)
    batches = {"tokens": jnp.stack([
        jnp.stack([data.batch(e, s)["tokens"] for s in range(h_max)])
        for e in range(n_edges)])}
    return cfg, m, state, batches


def test_el_round_sync_broadcasts_global_model():
    cfg, m, state, batches = _el_setup()
    rnd = jax.jit(make_el_round(m, cfg.train, h_max=3))
    st2, _ = rnd(state, batches, jnp.array([1, 3]), jnp.array([1.0, 1.0]))
    for leaf in jax.tree.leaves(st2.params):
        np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                   np.asarray(leaf[1], np.float32))


def test_el_round_interval_masking():
    """interval=h applies exactly h local steps: an edge with interval=0...
    intervals are >=1; compare interval=1 vs 3 -> different params, and
    opt.step advances by h_max scan length but masked."""
    cfg, m, state, batches = _el_setup()
    rnd = jax.jit(make_el_round(m, cfg.train, h_max=3, mode="async"))
    st2, metrics = rnd(state, batches, jnp.array([1, 3]),
                       jnp.array([1.0, 1.0]))
    # async mode: edges keep distinct params (blended, not equalized)
    leaf = jax.tree.leaves(st2.params)[1]
    assert not np.allclose(np.asarray(leaf[0], np.float32),
                           np.asarray(leaf[1], np.float32))
    assert float(metrics["mean_interval"]) == 2.0
    # shapes preserved exactly (regression: async blend once grew an
    # extra edge dim per round via a bad alpha reshape)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(st2.params)):
        assert a.shape == b.shape, (a.shape, b.shape)
    # a second round must run with the returned state (same jit trace)
    st3, _ = rnd(st2, batches, jnp.array([2, 2]), jnp.array([1.0, 1.0]))
    for a, b in zip(jax.tree.leaves(st2.params),
                    jax.tree.leaves(st3.params)):
        assert a.shape == b.shape


def test_el_round_masked_steps_match_manual():
    """An edge with interval=k must equal k manual train steps + agg."""
    from repro.train import init_train_state, make_train_step
    cfg, m, state, batches = _el_setup(n_edges=2, h_max=2)
    rnd = jax.jit(make_el_round(m, cfg.train, h_max=2))
    st2, _ = rnd(state, batches, jnp.array([2, 2]), jnp.array([1.0, 1.0]))
    # manual: run both edges 2 steps then average
    step = jax.jit(make_train_step(m, cfg.train))
    from repro.train.state import TrainState
    finals = []
    for e in range(2):
        s_e = TrainState(jax.tree.map(lambda x: x[e], state.params),
                         jax.tree.map(lambda x: x[e], state.opt))
        for t in range(2):
            b = {"tokens": batches["tokens"][e, t]}
            s_e, _ = step(s_e, b)
        finals.append(s_e.params)
    agg = weighted_average(finals, [1.0, 1.0])
    got = jax.tree.map(lambda x: x[0], st2.params)
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=1e-2)


def test_el_program_in_graph_full_loop():
    """Beyond-paper: whole OL4EL loop (bandit + rounds + budgets) in one
    jitted program — losses fall, budgets drain, bandit counts grow."""
    from repro.core.bandit import jax_bandit_init
    from repro.federated.local_sgd import make_el_program

    cfg = get_smoke_config("qwen3-1.7b")
    m = build_model(cfg.model)
    n_edges, h_max, n_rounds = 2, 3, 6
    data = SyntheticLMData.for_model(cfg.model, 2, 32)

    def data_fn(edge_ids, rnd, steps):
        def per_edge(e):
            def per_step(s):
                return data.batch(e, rnd * h_max + s)["tokens"]
            return jax.vmap(per_step)(steps)
        return {"tokens": jax.vmap(per_edge)(edge_ids)}

    program = jax.jit(make_el_program(
        m, cfg.train, n_edges, h_max, n_rounds, data_fn,
        comp_costs=[10.0, 20.0], comm_costs=[50.0, 50.0]))
    state = init_el_state(m, cfg.train, n_edges, jax.random.key(0))
    bstates = jax.vmap(lambda _: jax_bandit_init(h_max))(jnp.arange(n_edges))
    budgets = jnp.asarray([1e4, 1e4], jnp.float32)
    state, bstates, budgets, hist = program(state, bstates, budgets,
                                            jax.random.key(1))
    losses = np.asarray(hist["loss"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]            # learning happened
    assert float(budgets[0]) < 1e4           # budget consumed
    assert int(bstates["t"].sum()) == n_edges * n_rounds
    assert np.asarray(hist["active"]).all()


def test_el_program_stops_spending_when_broke():
    from repro.core.bandit import jax_bandit_init
    from repro.federated.local_sgd import make_el_program

    cfg = get_smoke_config("qwen3-1.7b")
    m = build_model(cfg.model)
    n_edges, h_max = 2, 2
    data = SyntheticLMData.for_model(cfg.model, 2, 16)

    def data_fn(edge_ids, rnd, steps):
        def per_edge(e):
            return jax.vmap(lambda s: data.batch(e, rnd * h_max + s)
                            ["tokens"])(steps)
        return {"tokens": jax.vmap(per_edge)(edge_ids)}

    program = jax.jit(make_el_program(
        m, cfg.train, n_edges, h_max, 8, data_fn,
        comp_costs=[10.0, 10.0], comm_costs=[50.0, 50.0]))
    state = init_el_state(m, cfg.train, n_edges, jax.random.key(0))
    bstates = jax.vmap(lambda _: jax_bandit_init(h_max))(jnp.arange(n_edges))
    budgets = jnp.asarray([150.0, 150.0], jnp.float32)  # ~2 rounds each
    _, _, budgets, hist = program(state, bstates, budgets, jax.random.key(1))
    active = np.asarray(hist["active"])
    assert not active[-1].any()              # eventually everyone stops
    assert (np.asarray(budgets) > -1e-3).all()   # never negative
