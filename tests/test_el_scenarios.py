"""The repro.el.scenarios subsystem: ScenarioSpec validation and
structural hashing, host-side schedule materialization, the scenario
knob surface, scenario-off bit-identity of the compiled programs
(sync, async K in {1,4}, fleet cohort — replicated and on a 2x2 debug
mesh), dead-edge zero-charging, the host reference replay oracle, the
in-graph policy switch / churn sweep axes, the shared CLI glue, and
the support-matrix error messages."""

import argparse
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.config import OL4ELConfig
from repro.el import ELSession, FleetServer, SweepSpec, TenantRun
from repro.el.events import ASYNC_KNOB_NAMES, async_knob_names
from repro.el.ingraph import (KNOB_NAMES, check_ingraph_support,
                              make_sync_program, support_matrix,
                              sync_knob_names, sync_knobs)
from repro.el.scenarios import (ChurnSpec, CostSpec, ScenarioSpec,
                                as_scenario, verify_sync_replay)
from repro.el.scenarios.baselines import (INGRAPH_POLICY_ORDER,
                                          ingraph_policy_id)
from repro.el.scenarios.cli import add_scenario_args, scenario_from_args
from repro.el.scenarios.schedule import (SCENARIO_KNOB_NAMES,
                                         activity_schedule, cost_schedule,
                                         scenario_knob_names,
                                         scenario_knobs)
from repro.launch.classic import classic_fixture

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def svm():
    return classic_fixture("svm-wafer", samples=128, n_edges=4,
                           alpha=100.0, data_seed=0)


def _cfg(fx, mode="sync", scenario=None, **kw):
    kw.setdefault("budget", 700.0)
    kw.setdefault("policy", "ol4el")
    return dataclasses.replace(
        fx["exp"].ol4el, mode=mode, n_edges=4,
        utility=fx["utility"], scenario=scenario, **kw)


def _session(fx, cfg):
    return (ELSession(cfg, metric_name=fx["metric"])
            .with_executor(fx["executor"],
                           init_params=fx["init_params"],
                           n_samples=(fx["n_samples"]
                                      if cfg.mode == "sync" else None)))


def _sync_out(fx, cfg, max_rounds=48):
    """Drive make_sync_program directly (the raw out dict carries the
    per-round scenario histories the session report does not)."""
    ex = fx["executor"]
    prog = jax.jit(make_sync_program(
        ex.model, ex.edge_data, ex.eval_set, cfg, lr=ex.lr,
        batch=ex.batch,
        n_samples=np.asarray(fx["n_samples"], np.float64),
        max_rounds=max_rounds))
    _, out = prog(fx["init_params"], jax.random.key(cfg.seed + 17),
                  sync_knobs(cfg))
    return jax.tree.map(np.asarray, out)


# ---------------------------------------------------------------------------
# ScenarioSpec: validation, period, structural residue
# ---------------------------------------------------------------------------


def test_spec_validation_and_period():
    with pytest.raises(ValueError, match="rate"):
        ChurnSpec(rate=1.0)
    with pytest.raises(ValueError, match="kind"):
        ChurnSpec(kind="bogus")
    with pytest.raises(ValueError, match="trace"):
        ChurnSpec(kind="trace")
    with pytest.raises(ValueError, match="alpha"):
        CostSpec(alpha=1.0)
    with pytest.raises(ValueError, match="positive"):
        CostSpec(kind="trace", trace=((1.0, -1.0),))
    with pytest.raises(ValueError, match="drift"):
        ScenarioSpec(drift=-0.1)
    # period: lcm of the present parts; 1 when nothing is scheduled
    assert ScenarioSpec().period == 1
    assert ScenarioSpec(churn=ChurnSpec(period=6),
                        cost=CostSpec(period=4)).period == 12
    # trace rows pin the period to the trace length
    tr = ChurnSpec(kind="trace", trace=((1, 1), (1, 0), (0, 1)))
    assert tr.period == 3


def test_spec_is_hashable_and_structural_drops_knob_values():
    a = ScenarioSpec(churn=ChurnSpec(rate=0.3, seed=5),
                     cost=CostSpec(kind="lognormal", sigma=0.9),
                     drift=0.02)
    b = ScenarioSpec(churn=ChurnSpec(rate=0.05, seed=11),
                     cost=CostSpec(kind="pareto", alpha=3.0))
    assert hash(a) != hash(ScenarioSpec())
    # rates/seeds/kinds are knob values -> same executable bucket
    assert a.structural() == b.structural()
    assert a.structural() != ScenarioSpec(
        churn=ChurnSpec(period=32)).structural()


def test_as_scenario_normalization():
    assert as_scenario(None) is None
    assert as_scenario(False) is None
    assert as_scenario(True) == ScenarioSpec()
    s = ScenarioSpec(drift=0.1)
    assert as_scenario(s) is s
    with pytest.raises(TypeError, match="ScenarioSpec"):
        as_scenario("churn")


# ---------------------------------------------------------------------------
# schedule materialization
# ---------------------------------------------------------------------------


def test_activity_schedule_min_active_and_determinism():
    ch = ChurnSpec(rate=0.9, period=32, min_active=2, seed=3)
    act = activity_schedule(ch, 4, 32)
    assert act.shape == (32, 4) and act.dtype == np.float32
    assert set(np.unique(act)) <= {0.0, 1.0}
    assert (act.sum(axis=1) >= 2).all()           # revival floor
    np.testing.assert_array_equal(act, activity_schedule(ch, 4, 32))
    # None => always-on
    assert activity_schedule(None, 3, 8).min() == 1.0
    with pytest.raises(ValueError, match="edges"):
        activity_schedule(ChurnSpec(kind="trace", trace=((1, 1),)), 3, 1)


def test_cost_schedule_kinds_and_tiling():
    par = cost_schedule(CostSpec(kind="pareto", alpha=2.0, period=16),
                        4, 16)
    assert par.shape == (16, 4) and (par >= 1.0).all()   # spikes only
    logn = cost_schedule(CostSpec(kind="lognormal", sigma=0.5,
                                  period=16), 4, 16)
    assert (logn > 0).all() and not (logn >= 1.0).all()
    # shorter part tiles up to the combined lcm period
    tiled = cost_schedule(CostSpec(kind="trace",
                                   trace=((2.0, 1.0), (1.0, 3.0))), 2, 6)
    assert tiled.shape == (6, 2)
    np.testing.assert_array_equal(tiled[:2], tiled[2:4])


# ---------------------------------------------------------------------------
# knob surface: scenario=None keeps the pre-scenario traced signature
# ---------------------------------------------------------------------------


def test_knob_names_scenario_off_are_the_pre_scenario_tuples():
    """The scenario-off programs take EXACTLY the historical knobs —
    the traced signature (and thus the compiled program) is unchanged."""
    off = OL4ELConfig(mode="sync")
    assert off.scenario is None
    assert sync_knob_names(off) == KNOB_NAMES == (
        "ucb_c", "budget", "comp", "comm", "costs_k", "min_edge_cost",
        "cost_noise")
    assert async_knob_names(dataclasses.replace(off, mode="async")) \
        == ASYNC_KNOB_NAMES == (
            "ucb_c", "budget", "comp", "comm", "costs_ek",
            "min_edge_cost", "cost_noise", "async_alpha", "event_cap")
    assert set(sync_knobs(off)) == set(KNOB_NAMES)


def test_knob_names_and_arrays_with_scenario():
    scn = ScenarioSpec(churn=ChurnSpec(rate=0.2, period=8),
                       cost=CostSpec(period=8), drift=0.01)
    cfg = OL4ELConfig(mode="sync", n_edges=3, scenario=scn)
    assert sync_knob_names(cfg) == KNOB_NAMES + SCENARIO_KNOB_NAMES \
        + ("policy_id",)
    assert scenario_knob_names("async") == SCENARIO_KNOB_NAMES
    knobs = scenario_knobs(cfg)
    assert knobs["scn_active"].shape == (8, 3)
    assert knobs["scn_mult"].shape == (8, 3)
    assert knobs["scn_drift"] == np.float32(0.01)
    assert knobs["policy_id"] == np.int32(0)           # ol4el = branch 0
    acfg = dataclasses.replace(cfg, mode="async")
    assert "policy_id" not in scenario_knobs(acfg)
    assert async_knob_names(acfg) == ASYNC_KNOB_NAMES \
        + SCENARIO_KNOB_NAMES
    # full sync_knobs picks the scenario arrays up automatically
    assert set(sync_knobs(cfg)) == set(sync_knob_names(cfg))


def test_policy_switch_order_and_registry_parity():
    from repro.el import policies as el_policies
    assert INGRAPH_POLICY_ORDER == ("ol4el", "task_alloc", "delay_energy")
    for i, name in enumerate(INGRAPH_POLICY_ORDER):
        assert ingraph_policy_id(name) == i
        assert name in el_policies.available()       # host twins exist
    with pytest.raises(ValueError, match="greedy"):
        ingraph_policy_id("greedy")


# ---------------------------------------------------------------------------
# scenario-off bit-identity (THE hard correctness bar): with
# scenario=None the compiled programs reproduce the pre-scenario
# behavior bit-for-bit.  Anchors that predate the scenario engine:
# the async host event queue on shared jax RNG streams, and fleet
# cohorts vs independent single runs.
# ---------------------------------------------------------------------------


def _assert_async_bit_identical(ref, ing):
    assert ref.n_aggregations == ing.n_aggregations > 0
    for t, (a, b) in enumerate(zip(ref.records, ing.records)):
        assert a.edge == b.edge, t
        assert a.interval == b.interval, t
        assert a.wall_time == b.wall_time, t
        assert a.total_consumed == b.total_consumed, t
        assert a.utility == b.utility, t
    assert ref.arm_pulls == ing.arm_pulls
    assert ref.terminated_reason == ing.terminated_reason
    assert ref.final_metric == ing.final_metric


@pytest.mark.parametrize("batch_k", [1, 4])
def test_scenario_off_async_bit_identical_to_host_queue(svm, batch_k):
    cfg = _cfg(svm, "async", scenario=None, budget=500.0,
               async_batch_k=batch_k)
    ref = _session(svm, cfg).run_async(rng_streams="jax")
    ing = _session(svm, cfg).run_async_ingraph()
    _assert_async_bit_identical(ref, ing)


def test_scenario_off_sync_and_explicit_none_agree(svm):
    """scenario=None is the dataclass default; spelling it explicitly
    (or via as_scenario(False)) must hit the identical compiled run."""
    base = _cfg(svm, "sync", budget=600.0)
    out_a = _sync_out(svm, base)
    out_b = _sync_out(svm, dataclasses.replace(
        base, scenario=as_scenario(False)))
    assert set(out_a) == set(out_b)
    assert "active_edges" not in out_a       # scenario hist is absent
    for k in out_a:
        np.testing.assert_array_equal(np.asarray(out_a[k]),
                                      np.asarray(out_b[k]))


def test_scenario_off_fleet_cohort_bit_identical(svm):
    cfgs = [_cfg(svm, "sync", budget=b, seed=s, scenario=None)
            for b, s in [(600.0, 0), (750.0, 1)]]
    srv = FleetServer(n_slots=2, rounds_per_wave=4)
    ids = [srv.submit(TenantRun(
               cfg=c, executor=svm["executor"],
               metric_name=svm["metric"], n_samples=svm["n_samples"],
               init_params=svm["init_params"])) for c in cfgs]
    reports = srv.drain()
    for tid, c in zip(ids, cfgs):
        ref = _session(svm, c).run_sync_ingraph()
        r = reports[tid]
        assert r.n_aggregations == ref.n_aggregations > 0
        assert r.total_consumed == ref.total_consumed
        assert r.wall_time == ref.wall_time
        assert r.arm_pulls == ref.arm_pulls
        for pa, pb in zip(jax.tree.leaves(ref.final_params),
                          jax.tree.leaves(r.final_params)):
            assert np.array_equal(np.asarray(pa), np.asarray(pb))


_MESH_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax, numpy as np
    assert jax.device_count() == 4, jax.devices()
    from repro.el import ELSession
    from repro.el.scenarios import ScenarioSpec, ChurnSpec
    from repro.launch.classic import classic_fixture
    from repro.launch.mesh import make_debug_mesh

    fx = classic_fixture("svm-wafer", samples=128, n_edges=4,
                         alpha=100.0, data_seed=0)
    cfg = dataclasses.replace(
        fx["exp"].ol4el, mode="sync", policy="ol4el", n_edges=4,
        utility=fx["utility"], budget=600.0, scenario=None)
    mesh = make_debug_mesh(2, 2)

    def run(mesh_):
        s = (ELSession(cfg, metric_name=fx["metric"])
             .with_executor(fx["executor"],
                            init_params=fx["init_params"],
                            n_samples=fx["n_samples"]))
        return s.run_sync_ingraph(mesh=mesh_)

    rep = run(None)
    mrep = run(mesh)
    assert mrep.n_aggregations == rep.n_aggregations > 0
    assert mrep.total_consumed == rep.total_consumed
    assert mrep.arm_pulls == rep.arm_pulls

    # scenario path on the mesh: compiles and respects the schedule
    scn = ScenarioSpec(churn=ChurnSpec(rate=0.3, period=16))
    scfg = dataclasses.replace(cfg, scenario=scn)
    s = (ELSession(scfg, metric_name=fx["metric"])
         .with_executor(fx["executor"], init_params=fx["init_params"],
                        n_samples=fx["n_samples"]))
    srep = s.run_sync_ingraph(mesh=mesh)
    assert srep.n_aggregations > 0
    print("SCENARIO-MESH-OK", rep.n_aggregations, srep.n_aggregations)
""")


@pytest.mark.slow
def test_scenario_mesh_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_EL_CONTRACTS="1",
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"))
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "SCENARIO-MESH-OK" in r.stdout


# ---------------------------------------------------------------------------
# scenario semantics in the compiled sync program
# ---------------------------------------------------------------------------


def test_dead_edges_run_zero_work_and_are_not_charged(svm):
    """An edge dropped by the churn trace for the WHOLE run keeps its
    full budget (zero charge), and every round's active count matches
    the schedule — the mask-aware aggregation skipped it correctly."""
    trace = ((1, 1, 1, 0),) * 4                   # edge 3 always out
    scn = ScenarioSpec(churn=ChurnSpec(kind="trace", trace=trace))
    cfg = _cfg(svm, "sync", scenario=scn, budget=600.0)
    out = _sync_out(svm, cfg)
    n = int(out["n_rounds"])
    assert n > 0
    np.testing.assert_array_equal(out["active_edges"][:n],
                                  np.full(n, 3, np.int32))
    # dead edge: budget untouched; live edges: charged
    assert float(out["budgets_left"][3]) == 600.0
    assert (np.asarray(out["budgets_left"][:3]) < 600.0).all()


def test_identity_scenario_runs_all_edges_active(svm):
    """ScenarioSpec() (the identity scenario) takes the scenario-path
    program but schedules nothing: all edges active every round, no
    drift, unit multipliers — and the policy switch runs branch 0."""
    cfg = _cfg(svm, "sync", scenario=ScenarioSpec(), budget=600.0)
    out = _sync_out(svm, cfg)
    n = int(out["n_rounds"])
    assert n > 0
    np.testing.assert_array_equal(out["active_edges"][:n],
                                  np.full(n, 4, np.int32))


def test_churn_reference_replay_matches_event_for_event(svm):
    """Acceptance bar: the host-side numpy replay of a churn schedule
    agrees with the compiled program event-for-event — termination
    round and per-round active-edge counts exactly, budget/wall
    bookkeeping to float32 round-off."""
    scn = ScenarioSpec(churn=ChurnSpec(rate=0.3, period=16),
                       cost=CostSpec(kind="lognormal", sigma=0.4,
                                     period=16))
    cfg = _cfg(svm, "sync", scenario=scn, budget=700.0)
    out = _sync_out(svm, cfg, max_rounds=64)
    ref = verify_sync_replay(cfg, out, 64)
    assert int(ref["n_rounds"]) == int(out["n_rounds"]) > 0
    # churn actually happened (not a degenerate always-on schedule)
    n = int(out["n_rounds"])
    assert out["active_edges"][:n].min() < 4


def test_replay_oracle_rejects_noisy_costs(svm):
    scn = ScenarioSpec(churn=ChurnSpec(rate=0.2))
    cfg = _cfg(svm, "sync", scenario=scn, cost_model="variable",
               cost_noise=0.2)
    with pytest.raises(ValueError, match="cost_noise"):
        verify_sync_replay(cfg, {"interval": np.zeros(4)}, 4)


def test_async_scenario_requires_single_event_waves(svm):
    from repro.el.events.knobs import resolve_async_batch_k
    scn = ScenarioSpec(churn=ChurnSpec(rate=0.2, period=8))
    cfg = _cfg(svm, "async", scenario=scn)
    assert resolve_async_batch_k(cfg) == 1           # auto pins to 1
    bad = dataclasses.replace(cfg, async_batch_k=4)
    with pytest.raises(ValueError, match="async_batch_k"):
        _session(svm, bad).run_async_ingraph()
    rep = _session(svm, cfg).run_async_ingraph(max_events=128)
    assert rep.n_aggregations > 0


# ---------------------------------------------------------------------------
# sweep axes: policy switch + churn rate as vmapped cell axes
# ---------------------------------------------------------------------------


def test_policy_axis_sweeps_baselines_in_one_program(svm):
    scn = ScenarioSpec(churn=ChurnSpec(rate=0.25, period=16))
    cfg = _cfg(svm, "sync", scenario=scn, budget=600.0)
    spec = SweepSpec(policy=INGRAPH_POLICY_ORDER, max_rounds=48)
    sess = _session(svm, cfg)
    rep = sess.sweep(spec)
    assert rep.n_cells == 3
    assert sess._sweep_program._cache_size() == 1    # ONE executable
    assert (np.asarray(rep.out["n_rounds"]) > 0).all()
    # the ol4el cell is bit-identical to an independent scenario run
    ind = _sync_out(svm, cfg, max_rounds=48)
    i = list(INGRAPH_POLICY_ORDER).index("ol4el")
    assert int(rep.out["n_rounds"][i]) == int(ind["n_rounds"])
    n = int(ind["n_rounds"])
    np.testing.assert_array_equal(rep.out["interval"][i][:n],
                                  ind["interval"][:n])
    np.testing.assert_array_equal(rep.out["consumed"][i][:n],
                                  ind["consumed"][:n])
    # the baselines take different allocation trajectories
    iv = [tuple(np.asarray(rep.out["interval"][j]
                           )[:int(rep.out["n_rounds"][j])])
          for j in range(3)]
    assert len(set(iv)) >= 2


def test_churn_rate_axis_redraws_the_activity_schedule(svm):
    scn = ScenarioSpec(churn=ChurnSpec(rate=0.1, period=16))
    cfg = _cfg(svm, "sync", scenario=scn, budget=600.0)
    spec = SweepSpec(churn_rate=(0.0, 0.6), max_rounds=48)
    rep = _session(svm, cfg).sweep(spec)
    assert rep.n_cells == 2
    n0, n1 = (int(x) for x in rep.out["n_rounds"])
    act0 = np.asarray(rep.out["active_edges"][0][:n0])
    act1 = np.asarray(rep.out["active_edges"][1][:n1])
    assert (act0 == 4).all()                  # rate 0: nobody drops
    assert act1.min() < 4                     # rate 0.6: churn bites


def test_scenario_axes_require_a_scenario_config(svm):
    cfg = _cfg(svm, "sync", scenario=None)
    with pytest.raises(ValueError, match="identity ScenarioSpec"):
        SweepSpec(policy=("ol4el", "task_alloc")).cell_cfgs(cfg)
    with pytest.raises(ValueError, match="churn"):
        SweepSpec(churn_rate=(0.1,)).cell_cfgs(
            dataclasses.replace(cfg, scenario=ScenarioSpec()))
    with pytest.raises(ValueError, match="policy"):
        SweepSpec(policy=("bogus",))


# ---------------------------------------------------------------------------
# structural keys: scenario joins compile-cache / cohort bucketing
# ---------------------------------------------------------------------------


def test_structural_cfg_buckets_scenario_points_together(svm):
    key = ELSession._structural_cfg
    a = _cfg(svm, "sync", scenario=ScenarioSpec(
        churn=ChurnSpec(rate=0.1, seed=0)))
    b = _cfg(svm, "sync", scenario=ScenarioSpec(
        churn=ChurnSpec(rate=0.5, seed=9)))
    assert key(a) == key(b)                    # rates are knob values
    # the policy switch traces every branch: policy is a knob value too
    c = dataclasses.replace(a, policy="task_alloc")
    assert key(a) == key(c)
    # scenario on vs off are different executables
    assert key(a) != key(_cfg(svm, "sync", scenario=None))
    # but scenario-off policy stays structural (separate host programs)
    off_a = _cfg(svm, "sync", scenario=None)
    off_b = dataclasses.replace(off_a, policy="greedy")
    assert key(off_a) != key(off_b)


# ---------------------------------------------------------------------------
# CLI glue (shared by repro.launch.train / repro.launch.sweep)
# ---------------------------------------------------------------------------


def _parse(argv):
    ap = argparse.ArgumentParser()
    add_scenario_args(ap)
    return ap.parse_args(argv)


def test_cli_defaults_build_no_scenario():
    scn, base = scenario_from_args(_parse([]))
    assert scn is None and base == "fixed"
    scn, base = scenario_from_args(_parse(["--cost-model", "variable"]))
    assert scn is None and base == "variable"


def test_cli_flags_round_trip_to_scenario_spec(tmp_path):
    scn, base = scenario_from_args(_parse(
        ["--churn", "0.2", "--churn-period", "8",
         "--cost-model", "pareto", "--drift", "0.01"]))
    assert base == "fixed"
    assert scn == ScenarioSpec(churn=ChurnSpec(rate=0.2, period=8),
                               cost=CostSpec(kind="pareto", period=8),
                               drift=0.01)
    # trace file: one column broadcasts per-slot multipliers
    p = tmp_path / "times.txt"
    p.write_text("1.0\n2.5\n1.5\n")
    scn, _ = scenario_from_args(_parse(["--cost-model", f"trace:{p}"]))
    assert scn.cost.kind == "trace" and scn.cost.period == 3
    assert scn.cost.trace == ((1.0,), (2.5,), (1.5,))
    with pytest.raises(SystemExit):
        _parse(["--cost-model", "bogus"])


# ---------------------------------------------------------------------------
# support matrix: the front door names the whole menu
# ---------------------------------------------------------------------------


def test_support_matrix_enumerates_scenario_and_cost_models():
    menu = support_matrix()
    for token in ("scenario", "ScenarioSpec", "pareto", "lognormal",
                  "trace:<path>", "task_alloc", "delay_energy",
                  "'fixed', 'variable'"):
        assert token in menu, token


def test_check_support_scenario_error_messages(svm):
    ex = svm["executor"]
    # a scenario cost KIND on cfg.cost_model: redirected to ScenarioSpec
    with pytest.raises(ValueError, match="CostSpec"):
        check_ingraph_support(_cfg(svm, "sync", cost_model="pareto"), ex)
    # baseline policy without a scenario: names the identity spelling
    with pytest.raises(ValueError, match="identity scenario"):
        check_ingraph_support(
            _cfg(svm, "sync", policy="task_alloc", scenario=None), ex)
    # the policy switch is sync-only
    with pytest.raises(ValueError, match="policy switch"):
        check_ingraph_support(
            _cfg(svm, "async", policy="delay_energy",
                 scenario=ScenarioSpec()), ex)
    # a non-spec scenario object is a TypeError with the menu attached
    with pytest.raises(TypeError, match="supported in-graph matrix"):
        check_ingraph_support(
            _cfg(svm, "sync", scenario="churn"), ex)
    # every rejection carries the full menu
    try:
        check_ingraph_support(_cfg(svm, "sync", cost_model="pareto"), ex)
    except ValueError as e:
        assert "supported in-graph matrix" in str(e)
