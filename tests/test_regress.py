"""repro.obs.regress + scripts/bench_check.py: the bench-regression
gate — schema-versioned history JSONL, the known-regression ledger,
direction-aware baseline comparison, within-run ratio checks, recorded
census/alias contracts over BENCH rows, and the gate's exit codes
(including "failing better" when a ledgered regression is fixed)."""

import importlib.util
import json
import os
import subprocess
import sys

import pytest

from repro.obs import regress

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def bench_check():
    spec = importlib.util.spec_from_file_location(
        "bench_check", os.path.join(REPO, "scripts", "bench_check.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# -- history ----------------------------------------------------------------


def test_history_append_load_roundtrip(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    assert regress.load_history(path) == []          # missing file: empty
    e1 = regress.append_history(path, "el", {"edges": 8},
                                {"r": {"wall_us": 10.0}}, commit="abc123")
    regress.append_history(path, "fleet", {"n": 64},
                           {"f": {"tenants_per_sec": 5.0}}, commit="abc123")
    assert e1["schema"] == regress.SCHEMA_VERSION
    assert e1["commit"] == "abc123" and e1["timestamp"] > 0
    entries = regress.load_history(path)
    assert [e["kind"] for e in entries] == ["el", "fleet"]
    only_el = regress.load_history(path, kind="el")
    assert len(only_el) == 1
    assert only_el[0]["rows"]["r"]["wall_us"] == 10.0


# -- ledger -----------------------------------------------------------------


def _ledger_entry(**kw):
    base = dict(bench="el", row="slow", metric="us_per_aggregation",
                reference="fast", max_ratio=6.0, fixed_below_ratio=1.5)
    base.update(kw)
    return regress.LedgerEntry(**base)


def test_load_ledger_and_lookup(tmp_path):
    assert regress.load_ledger(str(tmp_path / "nope.json")) == []
    path = str(tmp_path / "ledger.json")
    path_doc = {"schema": 1, "known": [
        {"bench": "el", "row": "slow", "metric": "us_per_aggregation",
         "reference": "fast", "max_ratio": 6.0, "reason": "known-slow",
         "unknown_future_field": True}]}
    with open(path, "w") as f:
        json.dump(path_doc, f)
    entries = regress.load_ledger(path)   # unknown fields are ignored
    assert len(entries) == 1 and entries[0].max_ratio == 6.0
    assert regress.ledgered(entries, "el", "slow",
                            "us_per_aggregation") is entries[0]
    assert regress.ledgered(entries, "fleet", "slow",
                            "us_per_aggregation") is None


def test_check_ledger_known_worse_fixed_missing():
    ledger = [_ledger_entry()]

    def kinds(rows):
        return [f.kind for f in regress.check_ledger(rows, ledger,
                                                     bench="el")]

    rows = {"fast": {"us_per_aggregation": 100.0}}
    assert kinds({**rows, "slow": {"us_per_aggregation": 400.0}}) \
        == ["known"]                                  # 4x <= 6x
    assert kinds({**rows, "slow": {"us_per_aggregation": 700.0}}) \
        == ["regression"]                             # got worse
    assert kinds({**rows, "slow": {"us_per_aggregation": 120.0}}) \
        == ["fixed"]                                  # failing better
    assert kinds(rows) == ["regression"]              # row vanished
    assert kinds({**rows, "slow": {}}) == ["regression"]   # metric gone

    # direction-aware: for higher-is-better metrics the ratio inverts
    inv = [_ledger_entry(metric="tenants_per_sec")]
    f, = regress.check_ledger(
        {"fast": {"tenants_per_sec": 100.0},
         "slow": {"tenants_per_sec": 25.0}}, inv, bench="el")
    assert f.kind == "known" and "4.00x" in f.detail


# -- fresh-vs-baseline comparison -------------------------------------------


def test_compare_to_baseline_direction_aware_tolerances():
    base = {"r": {"us_per_aggregation": 100.0, "tenants_per_sec": 100.0,
                  "note": "strings are skipped"}}

    def find(fresh_row):
        return regress.compare_to_baseline(base, {"r": fresh_row},
                                           bench="el")

    assert find({"us_per_aggregation": 120.0}) == []       # within 25%
    bad = find({"us_per_aggregation": 130.0})              # 30% slower
    assert [f.kind for f in bad] == ["regression"]
    assert "30%" in bad[0].detail
    # higher-is-better: throughput DROPPING is the regression
    assert find({"tenants_per_sec": 130.0}) == []
    assert [f.kind for f in find({"tenants_per_sec": 70.0})] \
        == ["regression"]
    # a ledgered (row, metric) downgrades to "known"
    known = regress.compare_to_baseline(
        base, {"r": {"us_per_aggregation": 200.0}}, bench="el",
        ledger=[_ledger_entry(row="r")])
    assert [f.kind for f in known] == ["known"]


def test_compare_ratios_within_run_drift():
    base = {"a": {"us_per_aggregation": 200.0},
            "ref": {"us_per_aggregation": 100.0}}   # baseline ratio 2x

    def find(fresh_a, **kw):
        fresh = {"a": {"us_per_aggregation": fresh_a},
                 "ref": {"us_per_aggregation": 100.0}}
        return regress.compare_ratios(
            base, fresh, bench="el", metric="us_per_aggregation",
            pairs=[("a", "ref")], **kw)

    ok, = find(300.0, slack=1.5)          # 3x < 2x * 2.5
    assert ok.kind == "ok"
    bad, = find(600.0, slack=1.5)         # 6x > 5x
    assert bad.kind == "regression" and "6.00x" in bad.detail
    known, = find(600.0, slack=1.5,
                  ledger=[_ledger_entry(row="a", reference="ref")])
    assert known.kind == "known"
    # rows missing on either side are skipped, not failed
    assert regress.compare_ratios(
        base, {"ref": {"us_per_aggregation": 1.0}}, bench="el",
        metric="us_per_aggregation", pairs=[("a", "ref")]) == []


def test_worst_exit_code():
    F = regress.Finding
    mk = lambda kind: F(kind, "el", "r", "m", "")
    assert regress.worst_exit_code([]) == 0
    assert regress.worst_exit_code([mk("ok"), mk("known")]) == 0
    assert regress.worst_exit_code([mk("ok"), mk("fixed")]) == 3
    assert regress.worst_exit_code([mk("fixed"), mk("regression")]) == 1


# -- bench_check: recorded-census contracts over BENCH rows -----------------


def _good_rows():
    return {
        "host_loop": {"us_per_aggregation": 900.0},   # no census: skipped
        "el_sync_ingraph": {"alias_bytes": 0, "collectives": {}},
        "el_sync_sharded": {
            "alias_bytes": 0,
            "collectives": {"all-gather": {"count": 2, "bytes": 15360}}},
        "el_sync_sharded_donate": {
            "alias_bytes": 1920,
            "collectives": {"all-gather": {"count": 2, "bytes": 15360}}},
        "el_async_sharded_donate": {
            "alias_bytes": 1920,
            "collectives": {"all-gather": {"count": 2, "bytes": 15360}}},
    }


def test_contract_findings_pass_on_clean_rows(bench_check):
    findings = bench_check.contract_findings(_good_rows())
    assert [f.kind for f in findings] == ["ok"]


def test_contract_findings_flag_census_and_alias_breaks(bench_check):
    # an all-reduce sneaking into a sharded program is a regression
    rows = _good_rows()
    rows["el_sync_sharded"]["collectives"]["all-reduce"] = \
        {"count": 1, "bytes": 40}
    bad = bench_check.contract_findings(rows)
    assert any(f.kind == "regression" and "all-reduce" in f.detail
               for f in bad)

    # a replicated program must not issue collectives at all
    rows = _good_rows()
    rows["el_sync_ingraph"]["collectives"] = \
        {"all-gather": {"count": 1, "bytes": 8}}
    assert any(f.kind == "regression"
               for f in bench_check.contract_findings(rows))

    # donation falling off (alias 0) is a regression
    rows = _good_rows()
    rows["el_sync_sharded_donate"]["alias_bytes"] = 0
    assert any("donation fell off" in f.detail
               for f in bench_check.contract_findings(rows))

    # two donated rows aliasing different sizes: one param tree, one size
    rows = _good_rows()
    rows["el_async_sharded_donate"]["alias_bytes"] = 64
    assert any("different byte counts" in f.detail
               for f in bench_check.contract_findings(rows))

    # a non-donated row that aliases anything is a violation too
    rows = _good_rows()
    rows["el_sync_sharded"]["alias_bytes"] = 1920
    assert any(f.kind == "regression"
               for f in bench_check.contract_findings(rows))


# -- the gate end-to-end on the committed artifacts -------------------------


def _run_gate(*argv):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "bench_check.py"),
         *argv],
        capture_output=True, text=True, timeout=120,
        env=dict(os.environ,
                 PYTHONPATH=os.path.join(REPO, "src")))


def test_gate_passes_on_committed_baselines():
    r = _run_gate()
    assert r.returncode == 0, r.stdout + r.stderr
    assert "bench_check: OK" in r.stdout
    # the async-sharded rows surface as ledgered, not silently passed
    assert "[known] el:el_async_sharded" in r.stdout


def test_gate_fails_on_injected_regression(tmp_path):
    with open(os.path.join(REPO, "BENCH_el.json")) as f:
        doc = json.load(f)
    doc["rows"]["el_sync_ingraph"]["us_per_aggregation"] *= 2.0
    fresh = str(tmp_path / "BENCH_el_fresh.json")
    with open(fresh, "w") as f:
        json.dump(doc, f)
    r = _run_gate("--fresh", fresh, "--bench", "el")
    assert r.returncode == 1, r.stdout + r.stderr
    assert "REGRESSION" in r.stdout
    assert "el_sync_ingraph.us_per_aggregation" in r.stdout


def test_gate_fails_better_when_ledgered_row_is_fixed(tmp_path):
    with open(os.path.join(REPO, "BENCH_el.json")) as f:
        doc = json.load(f)
    # "fix" the known async-sharded regression: ratio drops under 1.5x
    base = doc["rows"]["el_async_ingraph"]["us_per_aggregation"]
    for row in ("el_async_sharded", "el_async_sharded_donate"):
        doc["rows"][row]["us_per_aggregation"] = base * 1.1
    fixed = str(tmp_path / "BENCH_el_fixed.json")
    with open(fixed, "w") as f:
        json.dump(doc, f)
    r = _run_gate("--fresh", fixed, "--bench", "el")
    assert r.returncode == 3, r.stdout + r.stderr
    assert "FAILING-BETTER" in r.stdout
    assert "remove the stale" in r.stdout
