"""The mesh-sharded single-run data plane: placement policy (pure
spec level), sharded bit-identity vs the unsharded compiled programs
(subprocess debug mesh), buffer donation, mesh-aware compile-cache keys,
and Pallas-backed K-means local blocks."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_config
from repro.data import (make_traffic_dataset, make_wafer_dataset,
                        partition_edges)
from repro.el import ELSession
from repro.federated import ClassicExecutor
from repro.models import build_model
from repro.sharding import (EL_EDGE_KNOBS, EL_SCALAR_KNOBS,
                            el_edge_dim_axes, el_run_partition_specs)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# placement policy (pure — no devices)
# ---------------------------------------------------------------------------


def test_el_edge_dim_axes_tiles_or_replicates():
    sizes = {"pod": 2, "data": 16, "model": 16}
    # 64 edges tile the 32-way (pod, data) edge axes
    assert el_edge_dim_axes(("pod", "data", "model"), sizes, 64) == \
        ("pod", "data")
    # a fleet that does not tile replicates (resolver-style fallback)
    assert el_edge_dim_axes(("pod", "data", "model"), sizes, 3) is None
    # no edge axes at all -> replicate
    assert el_edge_dim_axes(("model",), {"model": 4}, 8) is None
    # single-device edge axes -> nothing to shard over
    assert el_edge_dim_axes(("data", "model"), {"data": 1, "model": 1},
                            8) is None


def test_el_run_partition_specs_data_plane_vs_control_plane():
    from repro.el.events.knobs import ASYNC_KNOB_NAMES
    from repro.el.ingraph import KNOB_NAMES
    edge_spec, knobs = el_run_partition_specs(
        ("data", "model"), {"data": 2, "model": 2}, 8, KNOB_NAMES)
    assert edge_spec == P(("data",))
    # the control plane replicates — every knob, scalar or per-edge
    assert set(knobs) == set(KNOB_NAMES)
    assert all(s == P() for s in knobs.values())
    # the shared knob-layout classification covers both programs' knobs
    assert set(EL_EDGE_KNOBS) < set(KNOB_NAMES)
    assert set(EL_EDGE_KNOBS) < set(ASYNC_KNOB_NAMES)
    assert set(EL_SCALAR_KNOBS) & set(ASYNC_KNOB_NAMES) == \
        {"ucb_c", "budget", "cost_noise", "async_alpha", "event_cap"}
    # non-tiling fleet: edge dim replicated
    edge_spec, _ = el_run_partition_specs(
        ("data", "model"), {"data": 2, "model": 2}, 3, KNOB_NAMES)
    assert edge_spec == P(None)


def test_el_stacked_param_specs_resolver_layout():
    """[E, ...]-stacked params: edge dim over (pod, data); tensor dims by
    the per-arch name+shape resolver (divisible heads -> 'model', classic
    names replicate)."""
    from repro.sharding import el_stacked_param_specs
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 1-device mesh: every dim replicates (nothing tiles)
    tree = {"w": jax.ShapeDtypeStruct((4, 59, 8), np.float32)}
    specs = el_stacked_param_specs(mesh, 4, tree)
    assert specs["w"] == P(None, None, None)


# ---------------------------------------------------------------------------
# shared fixture
# ---------------------------------------------------------------------------


def _svm_fixture(n=800, n_edges=4, seed=0, budget=900.0, **cfg_kw):
    train, test = make_wafer_dataset(n=n, seed=seed)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ol = dataclasses.replace(
        exp.ol4el, mode="sync", policy="ol4el", n_edges=n_edges,
        budget=budget, heterogeneity=4.0, utility="eval_gain", seed=seed,
        **cfg_kw)
    edges = partition_edges(train, n_edges, alpha=1.0, seed=seed)
    ex = ClassicExecutor(model, edges, test, batch=32, lr=0.05)
    init = model.init(jax.random.key(seed))
    ns = [len(e["y"]) for e in edges]
    return ol, model, ex, init, ns


def _session(ol, ex, init, ns) -> ELSession:
    return (ELSession(ol, metric_name="accuracy", lr=0.05)
            .with_executor(ex, init_params=init, n_samples=ns))


# ---------------------------------------------------------------------------
# buffer donation
# ---------------------------------------------------------------------------


def test_donated_params_buffer_is_invalidated_and_reuse_raises():
    ol, model, ex, _, ns = _svm_fixture()
    init = model.init(jax.random.key(0))
    sess = _session(ol, ex, init, ns)
    rep = sess.run_sync_ingraph(max_rounds=16, donate=True)
    assert rep.n_aggregations > 0
    # the donated buffers are really gone (XLA aliased them into the
    # output params instead of copying the fleet's parameters)
    assert all(leaf.is_deleted() for leaf in jax.tree.leaves(init))
    # and the session refuses to silently reuse them
    with pytest.raises(RuntimeError, match="donated"):
        sess.run_sync_ingraph(max_rounds=16)


def test_donated_run_is_bit_identical_to_undonated():
    ol, model, ex, init, ns = _svm_fixture()
    base = _session(ol, ex, init, ns).run_sync_ingraph(max_rounds=32)
    fresh = model.init(jax.random.key(0))
    don = _session(ol, ex, fresh, ns).run_sync_ingraph(max_rounds=32,
                                                       donate=True)
    assert base.n_aggregations == don.n_aggregations > 0
    assert [r.metric for r in base.records] == \
        [r.metric for r in don.records]
    assert [r.total_consumed for r in base.records] == \
        [r.total_consumed for r in don.records]
    assert base.arm_pulls == don.arm_pulls

    ol_async = dataclasses.replace(ol, mode="async")
    base = _session(ol_async, ex, init, ns).run_async_ingraph(max_events=48)
    fresh = model.init(jax.random.key(0))
    don = _session(ol_async, ex, fresh, ns).run_async_ingraph(
        max_events=48, donate=True)
    assert base.n_aggregations == don.n_aggregations > 0
    assert [r.metric for r in base.records] == \
        [r.metric for r in don.records]
    assert base.arm_pulls == don.arm_pulls


# ---------------------------------------------------------------------------
# compile-cache identity: mesh and donation are part of the key
# ---------------------------------------------------------------------------


def test_compile_cache_keys_carry_mesh_and_donation_identity():
    ol, model, ex, init, ns = _svm_fixture(n=400)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sess = _session(ol, ex, init, ns)
    r_plain = sess.run_sync_ingraph(max_rounds=16)
    prog_plain = sess._fastpath
    r_mesh = sess.run_sync_ingraph(max_rounds=16, mesh=mesh)
    prog_mesh = sess._fastpath
    # two meshes (None vs a real one) must not share a cache entry ...
    assert prog_mesh is not prog_plain
    assert len(sess._programs) == 2
    # ... and re-running the first must REUSE its entry, not thrash
    sess.run_sync_ingraph(max_rounds=16)
    assert sess._fastpath is prog_plain
    assert len(sess._programs) == 2
    # a second session run on the same mesh object also reuses
    sess.run_sync_ingraph(max_rounds=16, mesh=mesh)
    assert sess._fastpath is prog_mesh
    # donation compiles its own (aliased) executable
    sess.run_sync_ingraph(max_rounds=16, donate=True)
    assert len(sess._programs) == 3
    # on one device the mesh program is the same math — same results
    assert [r.metric for r in r_plain.records] == \
        [r.metric for r in r_mesh.records]


# ---------------------------------------------------------------------------
# sharded bit-identity (subprocess: forced 4-device host, 2x2 debug mesh)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = textwrap.dedent("""
    import dataclasses, sys
    import jax, numpy as np
    assert jax.device_count() == 4, jax.devices()
    from repro.config import get_config
    from repro.data import make_wafer_dataset, partition_edges
    from repro.el import ELSession
    from repro.federated import ClassicExecutor
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model

    mode = sys.argv[1]
    batch_k = int(sys.argv[2]) if len(sys.argv) > 2 else 0
    train, test = make_wafer_dataset(n=800, seed=0)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ol = dataclasses.replace(
        exp.ol4el, mode=mode, policy="ol4el", n_edges=4, budget=900.0,
        heterogeneity=4.0, utility="eval_gain", seed=0)
    edges = partition_edges(train, 4, alpha=1.0, seed=0)
    ex = ClassicExecutor(model, edges, test, batch=32, lr=0.05)
    init = model.init(jax.random.key(0))
    ns = [len(e["y"]) for e in edges]

    def run(mesh, cfg=ol):
        s = (ELSession(cfg, metric_name="accuracy", lr=0.05)
             .with_executor(ex, init_params=init, n_samples=ns))
        if mode == "sync":
            return s.run_sync_ingraph(max_rounds=32, mesh=mesh)
        return s.run_async_ingraph(max_events=64, mesh=mesh)

    # the reference is always the replicated K=1 program; an explicit
    # batch_k pins the sharded run's wave width (0 = auto-tuned)
    ol_mesh = (ol if not batch_k
               else dataclasses.replace(ol, async_batch_k=batch_k))
    r0 = run(None)
    r1 = run(make_debug_mesh(2, 2), ol_mesh)
    assert r0.n_aggregations == r1.n_aggregations > 0
    for field in ("metric", "utility", "interval", "total_consumed",
                  "wall_time"):
        a = [getattr(r, field) for r in r0.records]
        b = [getattr(r, field) for r in r1.records]
        assert a == b, (field, a[:4], b[:4])
    assert r0.arm_pulls == r1.arm_pulls
    for pa, pb in zip(jax.tree.leaves(r0.final_params),
                      jax.tree.leaves(r1.final_params)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb))
    print("BIT-IDENTICAL", mode, r0.n_aggregations)
""")


def _run_sharded_subprocess(mode: str, batch_k: int = 0):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"))
    return subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT, mode, str(batch_k)],
        capture_output=True, text=True, env=env, timeout=900)


@pytest.mark.slow
def test_sync_sharded_run_bit_identical_to_unsharded_subprocess():
    r = _run_sharded_subprocess("sync")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "BIT-IDENTICAL sync" in r.stdout


@pytest.mark.slow
def test_async_sharded_run_bit_identical_to_unsharded_subprocess():
    # batch_k=0 auto-tunes on the 2x2 mesh (min(4, n_edges) = 4), so
    # this also pins sharded K=4 waves == replicated K=1 pops
    r = _run_sharded_subprocess("async")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "BIT-IDENTICAL async" in r.stdout


@pytest.mark.slow
def test_async_sharded_k2_waves_bit_identical_to_unsharded_k1():
    """Explicit async_batch_k=2 on the 2x2 debug mesh: partial waves
    (K strictly between 1 and n_edges) against the replicated
    single-event reference."""
    r = _run_sharded_subprocess("async", batch_k=2)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "BIT-IDENTICAL async" in r.stdout


# ---------------------------------------------------------------------------
# Pallas-backed K-means local blocks inside the compiled programs
# ---------------------------------------------------------------------------


def _kmeans_session(impl: str) -> ELSession:
    train, test = make_traffic_dataset(n=600)
    exp = get_config("kmeans-traffic")
    model = build_model(exp.model, impl=impl)
    ol = dataclasses.replace(exp.ol4el, mode="sync", policy="ol4el",
                             n_edges=2, budget=500.0, heterogeneity=2.0,
                             utility="param_delta", seed=0)
    edges = partition_edges(train, 2, alpha=2.0)
    ex = ClassicExecutor(model, edges, test, batch=128, lr=1.0)
    return (ELSession(ol, metric_name="f1", lr=1.0)
            .with_executor(ex, init_params=model.init(jax.random.key(1))))


def test_kmeans_pallas_local_block_runs_ingraph_and_matches_jnp():
    """impl='pallas' routes the in-graph local block's E-step through the
    kmeans_assign kernel (interpret mode on CPU) under the program's
    vmap/scan; with identical assignments the Lloyd centers — and the
    whole run — match the jnp path."""
    rep_jnp = _kmeans_session("jnp").run_sync_ingraph(max_rounds=12)
    rep_pal = _kmeans_session("pallas").run_sync_ingraph(max_rounds=12)
    assert rep_pal.n_aggregations == rep_jnp.n_aggregations > 0
    assert rep_pal.final_metric == pytest.approx(rep_jnp.final_metric,
                                                 abs=0.02)
    assert [r.interval for r in rep_pal.records] == \
        [r.interval for r in rep_jnp.records]


def test_kmeans_impl_validation_and_back_compat():
    cfg = get_config("kmeans-traffic").model
    with pytest.raises(ValueError, match="impl"):
        build_model(cfg, impl="cuda")
    assert build_model(cfg, use_kernel=True).impl == "pallas"
    assert build_model(cfg).impl == "jnp"
