"""The unified repro.el runtime API: policy registry, ELSession façade,
in-graph fast path equivalence, async cost-accounting regression."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.config import OL4ELConfig, get_config
from repro.core.bandit import BanditState, arm_costs, select_arm
from repro.core.strategies import POLICIES
from repro.data import (make_traffic_dataset, make_wafer_dataset,
                        partition_edges)
from repro.el import (ELReport, ELSession, EdgeExecutor, RoundRecord,
                      policies, validate_executor)
from repro.federated import ClassicExecutor
from repro.models import build_model


# ---------------------------------------------------------------------------
# policy registry
# ---------------------------------------------------------------------------


def test_registry_covers_all_paper_policies():
    assert policies.available() == tuple(sorted(POLICIES))


@pytest.mark.parametrize("name", sorted(POLICIES))
def test_registry_round_trip(name):
    p = policies.get(name, ucb_c=1.5, eps=0.2, fixed_arm=2, eta=0.05,
                     max_interval=6)
    assert isinstance(p, policies.Policy)
    assert p.name == name
    # a fresh bandit over affordable arms must select something valid
    st = BanditState.create(6)
    costs = arm_costs(6, 10.0, 50.0)
    arm = p.select(st, 1e4, costs, np.random.default_rng(0))
    assert 0 <= arm < 6
    # and -1 when broke
    assert p.select(st, 1.0, costs, np.random.default_rng(0)) == -1


def test_registry_unknown_name_lists_alternatives():
    with pytest.raises(KeyError, match="ol4el"):
        policies.get("nope")


@pytest.mark.parametrize("name", ["ol4el", "ucb_bv", "greedy", "freq_only",
                                  "eps_greedy", "uniform", "fixed_i"])
def test_select_arm_shim_matches_policy_objects(name):
    """The legacy select_arm() and the policy object must make identical
    decisions from identical RNG streams (bit-for-bit repro guarantee)."""
    costs = arm_costs(6, 8.0, 40.0)
    st1, st2 = BanditState.create(6), BanditState.create(6)
    r1, r2 = np.random.default_rng(7), np.random.default_rng(7)
    pol = policies.get(name, ucb_c=2.0, eps=0.1, fixed_arm=3)
    for _ in range(40):
        a1 = select_arm(st1, 900.0, costs, policy=name, rng=r1)
        a2 = pol.select(st2, 900.0, costs, r2)
        assert a1 == a2
        if a1 >= 0:
            u = 0.3 + 0.1 * a1
            st1.update(a1, u, costs[a1])
            st2.update(a2, u, costs[a2])


# ---------------------------------------------------------------------------
# executor protocol
# ---------------------------------------------------------------------------


def test_executor_protocol_accepts_classic_and_rejects_junk():
    train, test = make_wafer_dataset(n=400, seed=0)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ex = ClassicExecutor(model, partition_edges(train, 2, alpha=1.0),
                         test, batch=32, lr=0.05)
    assert isinstance(ex, EdgeExecutor)
    validate_executor(ex)           # no raise

    class Junk:
        pass

    assert not isinstance(Junk(), EdgeExecutor)
    with pytest.raises(TypeError, match="local_train"):
        validate_executor(Junk())


# ---------------------------------------------------------------------------
# ELSession smoke (the paper's workloads through the façade)
# ---------------------------------------------------------------------------


def _svm_session(mode="sync", policy="ol4el", budget=1200.0, n=1200,
                 seed=0, **cfg_kw):
    train, test = make_wafer_dataset(n=n, seed=seed)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ol = dataclasses.replace(
        exp.ol4el, mode=mode, policy=policy, n_edges=3, budget=budget,
        heterogeneity=4.0, utility="eval_gain", seed=seed, **cfg_kw)
    edges = partition_edges(train, 3, alpha=1.0, seed=seed)
    ex = ClassicExecutor(model, edges, test, batch=64, lr=0.05)
    return (ELSession(ol, metric_name="accuracy", lr=0.05)
            .with_executor(ex, init_params=model.init(jax.random.key(seed)),
                           n_samples=[len(e["y"]) for e in edges]))


def test_session_sync_svm_learns_and_reports():
    rounds = []
    rep = _svm_session("sync").on_round(rounds.append).run()
    assert isinstance(rep, ELReport)
    assert rep.final_metric > 0.5
    assert rep.mode == "sync" and rep.policy == "ol4el"
    assert rep.terminated_reason == "budget_exhausted"
    # streaming callbacks saw every aggregation, in order
    assert [r.n_aggregations for r in rounds] == \
        list(range(1, rep.n_aggregations + 1))
    assert all(isinstance(r, RoundRecord) for r in rounds)
    assert sum(rep.arm_pulls) == rep.n_aggregations
    assert rep.final_params is not None


def test_session_async_kmeans_smoke():
    train, test = make_traffic_dataset(n=900)
    exp = get_config("kmeans-traffic")
    model = build_model(exp.model)
    ol = dataclasses.replace(exp.ol4el, mode="async", policy="ol4el",
                             n_edges=3, budget=700.0, heterogeneity=4.0,
                             utility="param_delta")
    edges = partition_edges(train, 3, alpha=2.0)
    ex = ClassicExecutor(model, edges, test, batch=128, lr=1.0)
    rep = (ELSession(ol, metric_name="f1", lr=1.0)
           .with_executor(ex, init_params=model.init(jax.random.key(1)))
           .run())
    assert rep.final_metric > 0.5
    assert rep.n_aggregations >= 2


def test_session_with_policy_object():
    pol = policies.get("fixed_i", fixed_arm=1)
    rep = _svm_session("sync").with_policy(pol).run()
    assert rep.policy == "fixed_i"
    # fixed-I pulls exactly one arm (interval 2) once past feasibility
    pulls = np.asarray(rep.arm_pulls)
    assert pulls[1] == pulls.sum()


def test_session_requires_executor():
    with pytest.raises(RuntimeError, match="with_executor"):
        ELSession(OL4ELConfig()).run()


# ---------------------------------------------------------------------------
# in-graph fast path: equivalence + guards
# ---------------------------------------------------------------------------


def test_ingraph_matches_host_sync_on_svm_wafer():
    """Acceptance: the compiled lax.while_loop program and the host-driven
    loop agree on the final metric and total consumption within tolerance
    (their RNG streams differ, so trajectories differ round-to-round)."""
    host = _svm_session("sync", budget=1500.0, n=1500).run_sync()
    ing = _svm_session("sync", budget=1500.0, n=1500).run_sync_ingraph()
    assert host.terminated_reason == ing.terminated_reason == \
        "budget_exhausted"
    assert host.final_metric > 0.5 and ing.final_metric > 0.5
    assert abs(host.final_metric - ing.final_metric) <= 0.08
    assert ing.total_consumed == pytest.approx(host.total_consumed,
                                               rel=0.15)
    # both respect every edge's budget (+ at most one final block)
    assert ing.total_consumed <= 3 * 1500.0 + 3 * 150.0
    assert ing.n_aggregations == len(ing.records) > 0
    ivals = [r.interval for r in ing.records]
    assert all(1 <= i <= 10 for i in ivals)


def test_ingraph_rejects_unsupported_configs():
    s = _svm_session("sync", policy="greedy")
    # the ValueError names the unsupported (policy, ...) combination
    with pytest.raises(ValueError, match="policy='greedy'"):
        s.run_sync_ingraph()
    s = _svm_session("sync", cost_model="bogus")
    with pytest.raises(ValueError, match="cost_model"):
        s.run_sync_ingraph()

    class NotInGraph:
        def local_train(self, params, edge, n_iters, seed):
            return params, {}

        def evaluate(self, params):
            return {"accuracy": 0.0}

    s = ELSession(OL4ELConfig(mode="sync")).with_executor(
        NotInGraph(), init_params={})
    with pytest.raises(TypeError, match="in-graph"):
        s.run_sync_ingraph()


def test_ingraph_async_cfg_is_coerced_to_sync():
    rep = _svm_session("async", budget=900.0, n=800).run_sync_ingraph()
    assert rep.mode == "sync"
    assert rep.n_aggregations > 0


def test_ingraph_variable_cost_now_supported():
    """cost_model='variable' compiles (the cost-noise draws moved into
    the program via jax.random) — it used to raise ValueError."""
    rep = _svm_session("sync", budget=900.0, n=800, cost_model="variable",
                       cost_noise=0.2).run_sync_ingraph()
    assert rep.n_aggregations > 0
    assert rep.terminated_reason == "budget_exhausted"


# ---------------------------------------------------------------------------
# async cost accounting: charged == scheduled (regression)
# ---------------------------------------------------------------------------


def test_async_charged_cost_equals_scheduled_cost():
    """With one edge, simulated wall-clock is exactly the sum of scheduled
    block durations — and the budget must be charged those same draws.
    (Regression: variable-cost mode used to charge a second independent
    realized_cost draw at completion.)"""
    train, test = make_wafer_dataset(n=600, seed=3)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ol = dataclasses.replace(
        exp.ol4el, mode="async", policy="ol4el", n_edges=1, budget=1200.0,
        heterogeneity=1.0, utility="eval_gain", seed=3,
        cost_model="variable", cost_noise=0.3)
    ex = ClassicExecutor(model, [train], test, batch=32, lr=0.05)
    rep = (ELSession(ol, metric_name="accuracy", lr=0.05)
           .with_executor(ex, init_params=model.init(jax.random.key(3)))
           .run_async())
    assert rep.n_aggregations >= 3
    assert rep.total_consumed == pytest.approx(rep.wall_time, abs=1e-6)


# ---------------------------------------------------------------------------
# review regressions: coordinator pre-run access, fast-path cache, policy
# objects in-graph, ingraph+async benchmark guard
# ---------------------------------------------------------------------------


def test_coordinator_inspectable_and_adjustable_before_run():
    """Legacy ELSimulator exposed .coord at construction; the session (and
    shim) must keep pre-run coordinator access working, and mutations must
    carry into the run that follows."""
    s = _svm_session("sync", budget=1200.0, n=800)
    coord = s.coordinator()
    assert coord.accounts[0].budget == 1200.0
    coord.charge(0, 1150.0)              # nearly exhaust one edge pre-run
    rep = s.run_sync()
    assert s.coord is coord              # the run consumed that instance
    assert rep.n_aggregations <= 2       # feasibility respected the charge
    # and the next run starts from a FRESH coordinator (budgets reset)
    rep2 = s.run_sync()
    assert s.coord is not coord
    assert rep2.n_aggregations > rep.n_aggregations


def test_simulator_shim_coord_available_pre_run():
    import warnings
    train, test = make_wafer_dataset(n=400, seed=0)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ex = ClassicExecutor(model, [train], test, batch=32, lr=0.05)
    ol = dataclasses.replace(exp.ol4el, mode="sync", n_edges=1,
                             budget=500.0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.federated import ELSimulator
        sim = ELSimulator(ex, ol, model.init(jax.random.key(0)))
    assert sim.coord.accounts[0].budget == 500.0


def test_ingraph_recompiles_when_session_reconfigured():
    """The cached compiled program must not survive a weight change."""
    s = _svm_session("sync", budget=900.0, n=800)
    s.run_sync_ingraph()
    prog1 = s._fastpath
    # skew the aggregation weights -> different program required
    s._n_samples = np.asarray([10.0, 1.0, 1.0])
    s.run_sync_ingraph()
    assert s._fastpath is not prog1


def test_ingraph_honors_injected_ol4el_policy_ucb_c():
    pol = policies.get("ol4el", ucb_c=0.25)
    s = _svm_session("sync", budget=900.0, n=800).with_policy(pol)
    # the effective fast-path config carries the policy object's constant
    assert s._ingraph_cfg("test").ucb_c == 0.25
    rep = s.run_sync_ingraph()
    assert rep.n_aggregations > 0


def test_ingraph_program_reused_across_knob_changes():
    """ucb_c/budget/heterogeneity/seed are traced inputs of the compiled
    program — changing them must NOT rebuild or retrace it."""
    s = _svm_session("sync", budget=900.0, n=800)
    r1 = s.run_sync_ingraph()
    prog = s._fastpath
    s.cfg = dataclasses.replace(s.cfg, ucb_c=0.5, budget=1300.0, seed=5)
    r2 = s.run_sync_ingraph()
    assert s._fastpath is prog
    assert prog._cache_size() == 1
    # the new knob values actually reached the (reused) program
    assert r2.n_aggregations > 0
    assert r2.total_consumed != r1.total_consumed


def test_run_el_routes_ingraph_async_through_event_program():
    """ingraph=True used to be sync-only; async runs now compile through
    the repro.el.events event-horizon program."""
    from benchmarks.common import run_el
    r = run_el("svm", "ol4el", "async", 3.0, budget=500.0, n_data=400,
               ingraph=True)
    assert r.mode == "async"
    assert r.n_aggregations > 0
    # per-event records carry the completing edge
    assert {rec.edge for rec in r.records} <= {0, 1, 2}


# ---------------------------------------------------------------------------
# compile-cache lifecycle: bounded pool, close(), device-buffer release
# ---------------------------------------------------------------------------


def test_program_cache_counts_and_evicts_fifo():
    from repro.el.cache import ProgramCache
    c = ProgramCache(max_entries=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1 and c.hits == 1 and c.misses == 0
    assert c.get("zzz") is None and c.misses == 1
    c.put("c", 3)                       # evicts "a" (FIFO)
    assert "a" not in c and c.get("b") == 2 and c.get("c") == 3
    assert len(c) == 2
    assert c.clear() == 2 and len(c) == 0


def test_clear_compile_cache_reports_dropped_programs():
    s = _svm_session("sync", budget=600.0)
    s.run_sync_ingraph(max_rounds=64)
    assert len(s.compile_cache) == 1
    assert s.clear_compile_cache() == 1
    assert len(s.compile_cache) == 0
    # session stays usable: the next run recompiles into the pool
    r = s.run_sync_ingraph(max_rounds=64)
    assert r.n_aggregations > 0 and len(s.compile_cache) == 1


def test_close_frees_device_buffers_and_refuses_runs():
    """close() must actually release device memory: each compiled
    program's closure pins padded device copies of the per-edge
    datasets, so the live-buffer count has to DROP once the cache (and
    the session's params reference) is dropped."""
    import gc
    s = _svm_session("sync", budget=600.0)
    r = s.run_sync_ingraph(max_rounds=64)
    del r                               # report holds final_params
    gc.collect()
    before = len(jax.live_arrays())
    s.close()
    gc.collect()
    after = len(jax.live_arrays())
    assert after < before, (before, after)
    with pytest.raises(RuntimeError, match="closed"):
        s.run_sync_ingraph(max_rounds=64)
    with pytest.raises(RuntimeError, match="closed"):
        s.run_sync()
    s.close()                           # idempotent
