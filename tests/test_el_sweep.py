"""The repro.el.sweep subsystem: spec flattening, vmapped-cell
bit-equivalence with independent in-graph runs, variable-cost in-graph
semantics, report reductions, and mesh placement policy."""

import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.config import OL4ELConfig, get_config
from repro.data import (make_traffic_dataset, make_wafer_dataset,
                        partition_edges)
from repro.el import ELSession, SweepReport, SweepSpec
from repro.el.sweep import sweep_partition_specs
from repro.el.sweep.spec import AXIS_ORDER
from repro.federated import ClassicExecutor
from repro.models import build_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _svm_fixture(n=800, n_edges=3, seed=0, budget=900.0, **cfg_kw):
    train, test = make_wafer_dataset(n=n, seed=seed)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ol = dataclasses.replace(
        exp.ol4el, mode="sync", policy="ol4el", n_edges=n_edges,
        budget=budget, heterogeneity=4.0, utility="eval_gain", seed=seed,
        **cfg_kw)
    edges = partition_edges(train, n_edges, alpha=1.0, seed=seed)
    ex = ClassicExecutor(model, edges, test, batch=32, lr=0.05)
    init = model.init(jax.random.key(seed))
    ns = [len(e["y"]) for e in edges]
    return ol, ex, init, ns


def _session(ol, ex, init, ns) -> ELSession:
    return (ELSession(ol, metric_name="accuracy", lr=0.05)
            .with_executor(ex, init_params=init, n_samples=ns))


# ---------------------------------------------------------------------------
# SweepSpec flattening
# ---------------------------------------------------------------------------


def test_spec_defaults_inherit_cfg_and_seed_varies_fastest():
    cfg = OL4ELConfig(ucb_c=1.5, budget=777.0, heterogeneity=3.0)
    spec = SweepSpec(ucb_c=(1.0, 2.0), seeds=(0, 7))
    assert spec.n_cells == 4
    cells = spec.cells(cfg)
    # row-major, seed fastest
    assert [c["seed"] for c in cells] == [0, 7, 0, 7]
    assert [c["ucb_c"] for c in cells] == [1.0, 1.0, 2.0, 2.0]
    # empty axes default from the config
    assert all(c["budget"] == 777.0 for c in cells)
    assert all(c["heterogeneity"] == 3.0 for c in cells)
    ccfgs = spec.cell_cfgs(cfg)
    assert [c.seed for c in ccfgs] == [0, 7, 0, 7]
    # the session config's mode carries into every cell (it picks the
    # sync round vs the async event-horizon program for the whole grid)
    assert all(c.mode == cfg.mode for c in ccfgs)
    assert all(c.async_alpha == cfg.async_alpha for c in ccfgs)
    assert tuple(spec.axes(cfg)) == AXIS_ORDER


def test_spec_validation():
    with pytest.raises(ValueError, match="seed"):
        SweepSpec(seeds=())
    with pytest.raises(ValueError, match="max_rounds"):
        SweepSpec(max_rounds=0)
    with pytest.raises(ValueError, match="budget"):
        SweepSpec(budget=(0.0,))
    with pytest.raises(ValueError, match="heterogeneity"):
        SweepSpec(heterogeneity=(0.5,))
    # sequences coerce to tuples (hashable -> usable as a cache key)
    spec = SweepSpec(ucb_c=[1.0, 2.0], seeds=[0])
    assert spec.ucb_c == (1.0, 2.0) and hash(spec)


# ---------------------------------------------------------------------------
# THE acceptance property: a [k]-cell vmapped sweep is bit-identical per
# cell to k independent run_sync_ingraph runs with the same seeds (the
# bandit RNG call order is load-bearing)
# ---------------------------------------------------------------------------


def test_sweep_cells_bit_identical_to_independent_ingraph_runs():
    ol, ex, init, ns = _svm_fixture()
    # 2 policy-hyperparams × 2 budgets × 2 seeds, ONE compiled program
    spec = SweepSpec(ucb_c=(1.0, 2.0), budget=(900.0, 1300.0),
                     seeds=(0, 3), max_rounds=64)
    sess = _session(ol, ex, init, ns)
    rep = sess.sweep(spec)
    # single jit trace for the whole grid
    assert sess._sweep_program._cache_size() == 1
    assert rep.n_cells == 8

    for i, ccfg in enumerate(spec.cell_cfgs(ol)):
        ind = _session(ccfg, ex, init, ns).run_sync_ingraph(max_rounds=64)
        n = int(rep.out["n_rounds"][i])
        assert n == ind.n_aggregations > 0
        # float32 -> float64 casts are exact, so == is bit-identity
        assert np.array_equal(
            rep.out["metric"][i][:n].astype(np.float64),
            np.array([r.metric for r in ind.records]))
        assert np.array_equal(
            rep.out["interval"][i][:n].astype(np.float64),
            np.array([r.interval for r in ind.records]))
        assert np.array_equal(
            rep.out["consumed"][i][:n].astype(np.float64),
            np.array([r.total_consumed for r in ind.records]))
        assert np.array_equal(np.asarray(rep.out["arm_pulls"][i]),
                              np.asarray(ind.arm_pulls))
        assert float(rep.out["wall_time"][i]) == ind.wall_time


def test_sweep_reruns_reuse_the_compiled_program():
    ol, ex, init, ns = _svm_fixture(n=400)
    spec = SweepSpec(ucb_c=(1.0, 2.0), seeds=(0,), max_rounds=32)
    sess = _session(ol, ex, init, ns)
    r1 = sess.sweep(spec)
    prog = sess._sweep_program
    r2 = sess.sweep(spec)
    assert sess._sweep_program is prog
    assert prog._cache_size() == 1
    assert np.array_equal(r1.out["metric"], r2.out["metric"],
                          equal_nan=True)


def test_sweep_rejects_unsupported_combinations():
    ol, ex, init, ns = _svm_fixture(n=400)
    bad = dataclasses.replace(ol, policy="greedy")
    with pytest.raises(ValueError, match="policy='greedy'"):
        _session(bad, ex, init, ns).sweep(SweepSpec(seeds=(0,)))

    class NotInGraph:
        def local_train(self, params, edge, n_iters, seed):
            return params, {}

        def evaluate(self, params):
            return {"accuracy": 0.0}

    s = ELSession(OL4ELConfig(mode="sync")).with_executor(
        NotInGraph(), init_params={})
    with pytest.raises(TypeError, match="in-graph"):
        s.sweep(SweepSpec(seeds=(0,)))


# ---------------------------------------------------------------------------
# variable-cost in-graph mode (ROADMAP item)
# ---------------------------------------------------------------------------


def test_variable_cost_noise_zero_is_bitwise_fixed():
    """cost_model='variable' with zero noise must reproduce the fixed-cost
    program bit-for-bit (the noise key is drawn OUTSIDE the per-edge
    fold range, so the other RNG streams are untouched)."""
    ol, ex, init, ns = _svm_fixture()
    fixed = _session(ol, ex, init, ns).run_sync_ingraph(max_rounds=64)
    var0 = _session(
        dataclasses.replace(ol, cost_model="variable", cost_noise=0.0),
        ex, init, ns).run_sync_ingraph(max_rounds=64)
    assert fixed.n_aggregations == var0.n_aggregations
    assert [r.metric for r in fixed.records] == \
        [r.metric for r in var0.records]
    assert [r.total_consumed for r in fixed.records] == \
        [r.total_consumed for r in var0.records]
    assert fixed.arm_pulls == var0.arm_pulls


def test_variable_cost_ingraph_matches_host_charged_cost_semantics():
    """The compiled variable-cost path must charge like the host path:
    every edge pays the straggler slot max_e(expected_e · mult_e) with
    mult_e = max(0.1, 1 + noise·N(0,1)), so each round's charge is at
    least 10% of the binding edge's expected cost, and totals agree with
    the host loop statistically (the RNG streams differ)."""
    from repro.el.ingraph import sync_knobs
    ol, ex, init, ns = _svm_fixture(n=1200, cost_model="variable",
                                    cost_noise=0.3, budget=1500.0)
    ing = _session(ol, ex, init, ns).run_sync_ingraph(max_rounds=64)
    host = _session(ol, ex, init, ns).run_sync()
    assert ing.terminated_reason == host.terminated_reason == \
        "budget_exhausted"
    knobs = sync_knobs(ol)
    comp_worst = float(knobs["comp"].max())
    comm = float(ol.comm_cost)
    prev = 0.0
    for rec in ing.records:
        slot = (rec.total_consumed - prev) / ol.n_edges
        expected = rec.interval * comp_worst + comm
        assert slot >= 0.1 * expected - 1e-3
        prev = rec.total_consumed
    # same charged-cost model => totals in the same ballpark
    assert ing.total_consumed == pytest.approx(host.total_consumed,
                                               rel=0.35)


# ---------------------------------------------------------------------------
# SweepReport reductions
# ---------------------------------------------------------------------------


def _toy_report() -> SweepReport:
    """2 ucb_c × 2 seeds, hand-built round records (R=4)."""
    spec = SweepSpec(ucb_c=(1.0, 2.0), seeds=(0, 1), max_rounds=4)
    cfg = OL4ELConfig(budget=100.0, heterogeneity=1.0)
    nan = np.nan
    metric = np.array([
        [0.5, 0.6, 0.7, nan],       # cell 0: ucb 1.0 seed 0, 3 rounds
        [0.4, 0.6, nan, nan],       # cell 1: ucb 1.0 seed 1, 2 rounds
        [0.5, 0.8, 0.9, 0.9],       # cell 2: ucb 2.0 seed 0, 4 rounds
        [0.5, 0.7, 0.8, nan],       # cell 3: ucb 2.0 seed 1, 3 rounds
    ])
    consumed = np.cumsum(np.where(np.isnan(metric), 0.0, 60.0), axis=1)
    out = {
        "metric": metric,
        "consumed": consumed,
        "utility": np.zeros_like(metric),
        "interval": np.ones_like(metric, np.int32),
        "wall": consumed / 3.0,
        "n_rounds": np.array([3, 2, 4, 3]),
        "budgets_left": np.zeros((4, 3), np.float32),
        "arm_pulls": np.zeros((4, 10), np.int32),
        "wall_time": consumed[:, -1] / 3.0,
    }
    return SweepReport(spec=spec, axes=spec.axes(cfg),
                       cells=spec.cells(cfg), out=out)


def test_report_final_metrics_and_consumed_respect_termination():
    rep = _toy_report()
    assert np.allclose(rep.final_metrics(), [0.7, 0.6, 0.9, 0.8])
    assert np.allclose(rep.total_consumed(), [180.0, 120.0, 240.0, 180.0])


def test_report_learning_curves_mean_and_ci_over_seeds():
    rep = _toy_report()
    curves = rep.learning_curves()
    assert len(curves) == 2                       # one per ucb_c point
    c1 = next(c for c in curves if c["ucb_c"] == 1.0)
    assert c1["n_seeds"] == 2 and c1["rounds"] == 3
    assert np.allclose(c1["mean"], [0.45, 0.6, 0.7])
    # round 2: only seed 0 alive -> no CI; round 0: two seeds
    assert c1["ci95"][0] == pytest.approx(1.96 * 0.05 / np.sqrt(2))
    assert c1["ci95"][2] == 0.0


def test_report_pareto_frontier_is_nondominated_over_seed_means():
    rep = _toy_report()
    front = rep.pareto_frontier()
    # ucb 1.0: mean metric 0.65 @ 150; ucb 2.0: 0.85 @ 210 — both survive
    assert [p["ucb_c"] for p in front] == [1.0, 2.0]
    rows = rep.grouped_rows()
    assert {r["ucb_c"]: r["final_metric"] for r in rows} == \
        pytest.approx({1.0: 0.65, 2.0: 0.85})
    # a dominated point must be dropped
    rep.out["metric"][2:, :] = np.array([[0.3, 0.4, 0.5, 0.5],
                                         [0.3, 0.4, 0.5, np.nan]])
    front = rep.pareto_frontier()
    assert [p["ucb_c"] for p in front] == [1.0]


def test_report_learning_curves_survive_metricless_workloads():
    """With no jittable in-graph metric the metric history is all-NaN by
    design — the consumed curve must still reduce from n_rounds."""
    rep = _toy_report()
    rep.out["metric"] = np.full_like(rep.out["metric"], np.nan)
    curves = rep.learning_curves()
    c1 = next(c for c in curves if c["ucb_c"] == 1.0)
    assert np.isnan(c1["mean"]).all()
    assert np.isfinite(c1["consumed"]).all()
    assert c1["consumed"][0] == pytest.approx(60.0)


def test_report_to_rows_flat_contract():
    rows = _toy_report().to_rows()
    assert len(rows) == 4
    assert set(AXIS_ORDER) <= set(rows[0])
    assert rows[0]["n_rounds"] == 3
    assert rows[0]["final_metric"] == pytest.approx(0.7)


# ---------------------------------------------------------------------------
# kmeans (no jittable metric): host-side final-params scoring fallback
# ---------------------------------------------------------------------------


def test_kmeans_sweep_scores_final_params_host_side():
    train, test = make_traffic_dataset(n=600)
    exp = get_config("kmeans-traffic")
    model = build_model(exp.model)
    ol = dataclasses.replace(exp.ol4el, mode="sync", policy="ol4el",
                             n_edges=2, budget=500.0, heterogeneity=2.0,
                             utility="param_delta")
    edges = partition_edges(train, 2, alpha=2.0)
    ex = ClassicExecutor(model, edges, test, batch=128, lr=1.0)
    sess = (ELSession(ol, metric_name="f1", lr=1.0)
            .with_executor(ex, init_params=model.init(jax.random.key(1))))
    rep = sess.sweep(SweepSpec(seeds=(0, 1), max_rounds=32))
    assert "final_metric_host" in rep.out
    finals = rep.final_metrics()
    assert finals.shape == (2,)
    assert np.isfinite(finals).all() and (finals > 0.3).all()


# ---------------------------------------------------------------------------
# mesh placement policy (pure spec level) + sharded execution subprocess
# ---------------------------------------------------------------------------


def test_sweep_partition_specs_placement_and_divisibility():
    from jax.sharding import PartitionSpec as P
    key_spec, knobs = sweep_partition_specs(
        ("pod", "data", "model"), {"pod": 2, "data": 16, "model": 16},
        n_cells=64, n_edges=32)
    assert key_spec == P(("pod", "data"))
    assert knobs["comp"] == P(("pod", "data"), "model")        # [C, E]
    assert knobs["costs_k"] == P(("pod", "data"), None)        # [C, K]
    assert knobs["budget"] == P(("pod", "data"))               # [C]
    # edge dim replicates when it does not divide the model axis
    _, knobs = sweep_partition_specs(
        ("data", "model"), {"data": 4, "model": 16},
        n_cells=8, n_edges=3)
    assert knobs["comp"] == P(("data",), None)
    # grid must tile the sweep axes
    with pytest.raises(ValueError, match="does not tile"):
        sweep_partition_specs(("data", "model"), {"data": 4, "model": 2},
                              n_cells=6, n_edges=2)
    # a mesh without edge axes cannot host a sweep
    with pytest.raises(ValueError, match="edge axes"):
        sweep_partition_specs(("model",), {"model": 4},
                              n_cells=4, n_edges=2)


@pytest.mark.slow
def test_sweep_sharded_on_debug_mesh_subprocess(tmp_path):
    """The launch entry point runs the sweep sharded over a forced 2x2
    host-device mesh (sweep dim over 'data', knob edge dim over 'model')."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_SWEEP_DEVICES="4")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.sweep", "--arch", "svm-wafer",
         "--ucb-c", "1.0", "2.0", "--seeds", "0", "1", "--samples", "800",
         "--max-rounds", "32", "--edges", "2", "--mesh", "debug"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "Pareto frontier" in r.stdout
    assert "4 cells" in r.stdout
