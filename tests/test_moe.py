"""MoE routing/dispatch properties (unit + hypothesis)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import ModelConfig, MoEConfig
from repro.models import moe as MoE


def make_cfg(e=4, k=2, d=32, f=16, shared=0, cf=1.25):
    return ModelConfig(
        d_model=d, moe=MoEConfig(num_experts=e, top_k=k, expert_ffn_dim=f,
                                 num_shared_experts=shared,
                                 shared_ffn_dim=f * max(shared, 1),
                                 capacity_factor=cf),
        dtype="float32")


def test_output_shape_and_finite():
    cfg = make_cfg()
    p = MoE.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32))
    y, aux = MoE.moe_ffn(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["load_balance_loss"]) > 0.0


def test_decode_dropless_consistency():
    """Single-token dispatch must equal its slice of the full pass."""
    cfg = make_cfg()
    p = MoE.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(2), (4, 16, 32))
    y_full, _ = MoE.moe_ffn(p, cfg, x)
    for t in [0, 7, 15]:
        y_t, _ = MoE.moe_ffn(p, cfg, x[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(y_full[:, t]),
                                   np.asarray(y_t[:, 0]), atol=1e-5)


def test_shared_experts_always_contribute():
    """Zeroing the routed experts must leave the shared-expert output."""
    cfg = make_cfg(shared=2)
    p = MoE.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(3), (1, 4, 32))
    y, _ = MoE.moe_ffn(p, cfg, x)
    p_zero = dict(p, we_down=jnp.zeros_like(p["we_down"]))
    y_shared, _ = MoE.moe_ffn(p_zero, cfg, x)
    assert float(jnp.max(jnp.abs(y_shared))) > 0.0
    assert not np.allclose(np.asarray(y), np.asarray(y_shared))


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives lb_loss == 1 (E * E * (1/E)^2)."""
    cfg = make_cfg(e=8, k=1)
    # craft logits: uniform probabilities -> P_e = 1/E; f_e depends on
    # argmax tie-breaks, so use rotation-symmetric inputs instead
    t = 64
    x = jax.random.normal(jax.random.key(4), (1, t, 32))
    p = MoE.init_moe(jax.random.key(5), cfg)
    _, aux = MoE.moe_ffn(p, cfg, x)
    # random init routes near-uniformly in expectation: loss close to 1
    assert 0.8 < float(aux["load_balance_loss"]) < 1.6


@given(e=st.sampled_from([2, 4, 8]), k=st.integers(1, 4),
       t=st.integers(1, 16), seed=st.integers(0, 20))
@settings(max_examples=25, deadline=None)
def test_property_topk_gates_normalized(e, k, t, seed):
    k = min(k, e)
    cfg = make_cfg(e=e, k=k)
    x = jax.random.normal(jax.random.key(seed), (1, t, 32))
    p = MoE.init_moe(jax.random.key(seed + 1), cfg)
    logits = (x.reshape(-1, 32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / gate.sum(-1, keepdims=True)
    assert np.allclose(np.asarray(gate.sum(-1)), 1.0, atol=1e-5)
    # top-k indices are distinct per token
    idx = np.asarray(idx)
    for row in idx:
        assert len(set(row.tolist())) == k


@given(t=st.sampled_from([8, 64, 256]), seed=st.integers(0, 10))
@settings(max_examples=12, deadline=None)
def test_property_moe_permutation_equivariant(t, seed):
    """Permuting tokens permutes outputs (given dropless capacity)."""
    cfg = make_cfg(cf=8.0)            # high capacity: no drops
    p = MoE.init_moe(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 99), (1, t, 32))
    perm = jax.random.permutation(jax.random.key(seed + 5), t)
    y1, _ = MoE.moe_ffn(p, cfg, x)
    y2, _ = MoE.moe_ffn(p, cfg, x[:, perm])
    np.testing.assert_allclose(np.asarray(y1[:, perm]), np.asarray(y2),
                               atol=2e-5)


def test_capacity_drops_tokens_when_tight():
    """With capacity_factor -> tiny and large T, some contributions drop."""
    cfg = make_cfg(cf=0.25)
    p = MoE.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(6), (8, 1024, 32))
    y_tight, _ = MoE.moe_ffn(p, cfg, x)
    cfg_loose = make_cfg(cf=8.0)
    y_loose, _ = MoE.moe_ffn(p, cfg_loose, x)
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_loose))


def test_sort_dispatch_bit_identical_to_cumsum():
    """§Perf optimization: sort-based dispatch must match the baseline
    exactly, including capacity drops (stable sort preserves token order)."""
    import dataclasses
    cfg_c = make_cfg(cf=0.5)                 # tight capacity: drops happen
    cfg_s = dataclasses.replace(
        cfg_c, moe=dataclasses.replace(cfg_c.moe, dispatch="sort"))
    p = MoE.init_moe(jax.random.key(0), cfg_c)
    x = jax.random.normal(jax.random.key(1), (4, 512, 32))
    y1, a1 = MoE.moe_ffn(p, cfg_c, x)
    y2, a2 = MoE.moe_ffn(p, cfg_s, x)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert float(a1["load_balance_loss"]) == pytest.approx(
        float(a2["load_balance_loss"]))
