"""repro.obs: timing helpers, metrics registry + Prometheus exposition,
the span tracer, and the in-graph telemetry rings — including the two
load-bearing contracts: telemetry OFF leaves every program bit-identical
(params, records, report scalars), and telemetry ON rings equal an
independent host-side f32 replay of the run's history, bit for bit."""

import dataclasses
import json
import os

import numpy as np
import pytest

from repro.el import ELSession, FleetServer, TenantRun
from repro.launch.classic import classic_fixture
from repro.obs import metrics as obs_metrics
from repro.obs import rings as obs_rings
from repro.obs import timing as obs_timing
from repro.obs import trace as obs_trace


@pytest.fixture(scope="module")
def svm():
    return classic_fixture("svm-wafer", samples=128, n_edges=4,
                           alpha=100.0, data_seed=0)


def _cfg(fx, mode, budget, seed=0):
    return dataclasses.replace(
        fx["exp"].ol4el, mode=mode, policy="ol4el", n_edges=4,
        utility=fx["utility"], budget=float(budget), seed=seed)


def _session(fx, cfg):
    return (ELSession(cfg, metric_name=fx["metric"])
            .with_executor(fx["executor"], init_params=fx["init_params"],
                           n_samples=(fx["n_samples"]
                                      if cfg.mode == "sync" else None)))


def _assert_reports_equal(a, b):
    assert a.final_metric == b.final_metric
    assert a.n_aggregations == b.n_aggregations
    assert a.total_consumed == b.total_consumed
    assert a.wall_time == b.wall_time
    assert a.terminated_reason == b.terminated_reason
    assert a.arm_pulls == b.arm_pulls
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        assert ra == rb
    flat_a, _ = _flatten(a.final_params)
    flat_b, _ = _flatten(b.final_params)
    for xa, xb in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def _flatten(tree):
    import jax
    return jax.tree.flatten(tree)


# -- timing -----------------------------------------------------------------


def test_time_block_units():
    with obs_timing.time_block() as tb:
        x = sum(range(1000))
    assert x == 499500
    assert tb.ns > 0
    assert tb.us == tb.ns / 1e3
    assert tb.ms == tb.ns / 1e6
    assert tb.s == tb.ns / 1e9


def test_timeit_us_and_repeat_s():
    calls = []
    us = obs_timing.timeit_us(lambda: calls.append(1), n=10, warmup=2)
    assert us >= 0.0
    assert len(calls) == 12                    # warmup + timed
    reps = obs_timing.repeat_s(lambda: None, 3)
    assert len(reps) == 3 and all(r >= 0.0 for r in reps)


def test_summarize_ns():
    s = obs_timing.summarize_ns([4.0, 1.0, 3.0, 2.0])
    assert s["count"] == 4
    assert s["min"] == 1.0 and s["max"] == 4.0
    assert s["mean"] == 2.5
    assert s["p50"] == 2.5
    assert obs_timing.summarize_ns([])["count"] == 0


# -- metrics registry + Prometheus exposition -------------------------------


def test_prometheus_render_parse_roundtrip():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("req_total", "requests").inc(3, {"code": "200"})
    reg.counter("req_total").inc(1, {"code": "500"})
    reg.gauge("depth", "queue depth").set(7)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe_many([0.05, 0.5, 5.0])
    text = reg.render_prometheus()
    assert "# HELP req_total requests" in text
    assert "# TYPE lat_seconds histogram" in text
    parsed = obs_metrics.parse_prometheus(text)
    by_code = {s["labels"]["code"]: s["value"]
               for s in parsed["req_total"]}
    assert by_code == {"200": 3.0, "500": 1.0}
    assert parsed["depth"][0]["value"] == 7.0
    buckets = {s["labels"]["le"]: s["value"]
               for s in parsed["lat_seconds_bucket"]}
    assert buckets["0.1"] == 1.0
    assert buckets["1"] == 2.0
    assert buckets["+Inf"] == 3.0
    assert parsed["lat_seconds_count"][0]["value"] == 3.0
    assert parsed["lat_seconds_sum"][0]["value"] == pytest.approx(5.55)


def test_prometheus_parser_rejects_garbage():
    with pytest.raises(ValueError):
        obs_metrics.parse_prometheus("this is not { prometheus\n")


def test_registry_type_conflicts_raise():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("x_total")
    with pytest.raises(TypeError):
        reg.gauge("x_total")


def test_prometheus_label_value_escaping_roundtrip():
    # backslash, quote and newline in label VALUES must survive the
    # exposition format (spec escapes: \\ \" \n)
    nasty = 'a\\b"c\nd'
    reg = obs_metrics.MetricsRegistry()
    reg.counter("esc_total", "escaping").inc(2, {"path": nasty,
                                                "plain": "ok"})
    text = reg.render_prometheus()
    assert '\\\\' in text and '\\"' in text and '\\n' in text
    assert "c\nd" not in text          # the newline itself never leaks
    parsed = obs_metrics.parse_prometheus(text)
    s, = parsed["esc_total"]
    assert s["labels"] == {"path": nasty, "plain": "ok"}
    assert s["value"] == 2.0


def test_prometheus_empty_registry_renders_and_parses():
    text = obs_metrics.MetricsRegistry().render_prometheus()
    assert obs_metrics.parse_prometheus(text) == {}
    assert obs_metrics.parse_prometheus("") == {}


def test_prometheus_inf_bucket_and_values_parse():
    text = ('# TYPE lat_bucket counter\n'
            'lat_bucket{le="+Inf"} 7\n'
            'peak_ratio +Inf\n'
            'neg_headroom -Inf\n')
    parsed = obs_metrics.parse_prometheus(text)
    s, = parsed["lat_bucket"]
    assert s["labels"]["le"] == "+Inf" and s["value"] == 7.0
    assert parsed["peak_ratio"][0]["value"] == float("inf")
    assert parsed["neg_headroom"][0]["value"] == float("-inf")


# -- tracer -----------------------------------------------------------------


def test_tracer_span_event_and_jsonl(tmp_path):
    path = str(tmp_path / "spans.jsonl")
    tr = obs_trace.Tracer(jsonl_path=path)
    with tr.span("unit.scope", tag="a") as sp:
        sp["inner"] = 42
    tr.event("unit.tick", n=np.int32(3))
    tr.close()
    evs = tr.events()
    assert [e["name"] for e in evs] == ["unit.scope", "unit.tick"]
    assert evs[0]["ev"] == "span" and evs[0]["dur_us"] >= 0.0
    assert evs[0]["inner"] == 42 and evs[0]["tag"] == "a"
    assert evs[1]["n"] == 3                    # numpy scalar coerced
    disk = obs_trace.read_jsonl(path)
    assert disk == evs
    assert json.dumps(disk)                    # JSON-safe end to end


def test_tracer_jsonl_flushes_span_on_exception(tmp_path):
    # a span whose body raises still times and streams its record (the
    # emit sits in a finally), so crashed dispatches stay observable
    path = str(tmp_path / "boom.jsonl")
    tr = obs_trace.Tracer(jsonl_path=path)
    with pytest.raises(RuntimeError, match="boom"):
        with tr.span("unit.crash", tag="x"):
            raise RuntimeError("boom")
    disk = obs_trace.read_jsonl(path)   # flushed before close()
    assert [e["name"] for e in disk] == ["unit.crash"]
    assert disk[0]["ev"] == "span" and disk[0]["tag"] == "x"
    tr.close()
    tr.close()                          # close is idempotent


def test_tracer_reentrant_spans_nest_and_order(tmp_path):
    path = str(tmp_path / "nest.jsonl")
    tr = obs_trace.Tracer(jsonl_path=path)
    with tr.span("outer"):
        with tr.span("inner", depth=2):
            pass
        with tr.span("inner", depth=2):
            pass
    tr.close()
    evs = tr.events()
    # inner scopes finish (and emit) before the enclosing outer span
    assert [e["name"] for e in evs] == ["inner", "inner", "outer"]
    assert all(e["dur_us"] <= evs[-1]["dur_us"] for e in evs[:-1])
    assert obs_trace.read_jsonl(path) == evs


def test_tracer_configure_swaps_process_tracer(tmp_path):
    prev = obs_trace.get_tracer()
    try:
        tr = obs_trace.configure(
            jsonl_path=str(tmp_path / "t.jsonl"))
        obs_trace.event("cfg.check")
        assert tr.events("cfg.check")
    finally:
        obs_trace.use_tracer(prev).close()


# -- telemetry spec gating --------------------------------------------------


def test_as_spec_normalization():
    assert obs_rings.as_spec(None) is None
    assert obs_rings.as_spec(False) is None
    assert obs_rings.as_spec(True).ring_size == obs_rings.DEFAULT_RING
    assert obs_rings.as_spec(16).ring_size == 16
    spec = obs_rings.TelemetrySpec(ring_size=4)
    assert obs_rings.as_spec(spec) is spec
    with pytest.raises(ValueError):
        obs_rings.TelemetrySpec(ring_size=0)
    with pytest.raises(TypeError):
        obs_rings.as_spec("on")


def test_ring_order_wraparound():
    assert obs_rings.ring_order(3, 8) == [(0, 0), (1, 1), (2, 2)]
    assert obs_rings.ring_order(5, 3) == [(2, 2), (3, 0), (4, 1)]


# -- telemetry-off bit-identity + telemetry-on reference replays ------------


def test_sync_telemetry_off_bit_identical(svm):
    cfg = _cfg(svm, "sync", budget=1200.0)
    off = _session(svm, cfg).run_sync_ingraph(max_rounds=32)
    on = _session(svm, cfg).run_sync_ingraph(max_rounds=32, telemetry=16)
    _assert_reports_equal(off, on)
    assert "rings" not in (off.telemetry or {})
    assert "rings" in on.telemetry
    rings = obs_rings.unroll_ring(on.telemetry["rings"])
    n = min(on.n_aggregations, 16)
    assert rings["arm"].shape == (n,)
    assert np.all(rings["arm"] >= 0)


def test_sync_reference_replay_bit_identical(svm):
    import jax
    from repro.el.ingraph import make_sync_program, sync_knobs
    cfg = _cfg(svm, "sync", budget=1500.0)
    ex = svm["executor"]
    core = make_sync_program(
        svm["model"], ex.edge_data, ex.eval_set, cfg, lr=ex.lr,
        batch=ex.batch, n_samples=np.asarray(svm["n_samples"], np.float64),
        max_rounds=32, telemetry=4)            # ring < rounds: wraps
    knobs = sync_knobs(cfg)
    _, out = jax.jit(core)(svm["init_params"],
                           jax.random.key(cfg.seed + 17), knobs)
    out = jax.tree.map(np.asarray, out)
    assert int(out["telemetry"]["head"]) == int(out["n_rounds"])
    dev = obs_rings.unroll_ring(out["telemetry"])
    ref = obs_rings.sync_reference_telemetry(out, knobs,
                                             n_arms=cfg.max_interval)
    assert set(dev) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(dev[k], ref[k], err_msg=k)


def test_async_telemetry_off_bit_identical_and_replay(svm):
    import jax
    from repro.el.events import async_knobs, make_async_program
    cfg = _cfg(svm, "async", budget=500.0)
    off = _session(svm, cfg).run_async_ingraph(max_events=64)
    on = _session(svm, cfg).run_async_ingraph(max_events=64, telemetry=8)
    _assert_reports_equal(off, on)
    assert "rings" in on.telemetry

    ex = svm["executor"]
    core = make_async_program(
        svm["model"], ex.edge_data, ex.eval_set, cfg, lr=ex.lr,
        batch=ex.batch, max_events=64, telemetry=8)
    knobs = async_knobs(cfg)
    _, out = jax.jit(core)(svm["init_params"],
                           jax.random.key(cfg.seed + 17), knobs)
    out = jax.tree.map(np.asarray, out)
    head = int(out["telemetry"]["head"])
    assert head == int(out["n_rounds"]) and head > 8   # wraps the ring
    dev = obs_rings.unroll_ring(out["telemetry"])
    ref = obs_rings.async_reference_telemetry(
        out, knobs, n_edges=cfg.n_edges, n_arms=cfg.max_interval)
    assert set(dev) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(dev[k], ref[k], err_msg=k)
    assert np.all(dev["alpha"] > 0.0)
    assert np.all(dev["interarrival"] >= 0.0)


def test_async_k_wave_telemetry_replay_bit_identical(svm):
    """K > 1 waves write K ring rows per while-loop step (the coalesced
    per-group scatters): the device rings must STILL equal the host
    K=1-order replay bit for bit, wraparound included."""
    import jax
    from repro.el.events import async_knobs, make_async_program
    cfg = dataclasses.replace(_cfg(svm, "async", budget=500.0),
                              async_batch_k=3)
    ex = svm["executor"]
    core = make_async_program(
        svm["model"], ex.edge_data, ex.eval_set, cfg, lr=ex.lr,
        batch=ex.batch, max_events=64, telemetry=8)
    knobs = async_knobs(cfg)
    _, out = jax.jit(core)(svm["init_params"],
                           jax.random.key(cfg.seed + 17), knobs)
    out = jax.tree.map(np.asarray, out)
    head = int(out["telemetry"]["head"])
    assert head == int(out["n_rounds"]) and head > 8   # wraps the ring
    dev = obs_rings.unroll_ring(out["telemetry"])
    ref = obs_rings.async_reference_telemetry(
        out, knobs, n_edges=cfg.n_edges, n_arms=cfg.max_interval)
    assert set(dev) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(dev[k], ref[k], err_msg=k)
    # the session path agrees too: K=3 telemetry report == K=1 report
    on1 = _session(svm, dataclasses.replace(cfg, async_batch_k=1)) \
        .run_async_ingraph(max_events=64, telemetry=8)
    on3 = _session(svm, cfg).run_async_ingraph(max_events=64, telemetry=8)
    _assert_reports_equal(on1, on3)
    r1 = obs_rings.unroll_ring(on1.telemetry["rings"])
    r3 = obs_rings.unroll_ring(on3.telemetry["rings"])
    assert set(r1) == set(r3)
    for k in r1:
        np.testing.assert_array_equal(r1[k], r3[k], err_msg=k)


def test_fleet_telemetry_off_bit_identical(svm):
    runs = [TenantRun(cfg=_cfg(svm, "sync", budget=b, seed=s),
                      executor=svm["executor"], tenant_id=f"t{s}",
                      metric_name=svm["metric"],
                      n_samples=svm["n_samples"],
                      init_params=svm["init_params"], max_rounds=32)
            for s, b in enumerate((600.0, 900.0, 1200.0))]
    plain = FleetServer(n_slots=2, rounds_per_wave=4)
    teled = FleetServer(n_slots=2, rounds_per_wave=4, telemetry=8)
    for r in runs:
        plain.submit(dataclasses.replace(r))
        teled.submit(dataclasses.replace(r))
    a, b = plain.drain(), teled.drain()
    assert set(a) == set(b)
    for tid in a:
        _assert_reports_equal(a[tid], b[tid])
        assert "rings" in b[tid].telemetry
        rings = obs_rings.unroll_ring(b[tid].telemetry["rings"])
        assert rings["arm"].shape[0] == min(a[tid].n_aggregations, 8)
    plain.close(), teled.close()


# -- cache stats + report folding -------------------------------------------


def test_program_cache_stats(svm):
    cfg = _cfg(svm, "sync", budget=900.0)
    s = _session(svm, cfg)
    s.run_sync_ingraph(max_rounds=32)
    st = s.compile_cache.stats()
    assert st["misses"] == 1 and st["hits"] == 0
    assert st["entries"] == 1 and st["evictions"] == 0
    s.run_sync_ingraph(max_rounds=32)
    assert s.compile_cache.stats()["hits"] == 1


def test_session_report_carries_cache_stats(svm):
    cfg = _cfg(svm, "sync", budget=900.0)
    rep = _session(svm, cfg).run_sync_ingraph(max_rounds=32)
    assert rep.telemetry["cache"]["misses"] == 1


def test_registry_from_report_and_files(svm, tmp_path):
    cfg = _cfg(svm, "sync", budget=1200.0)
    rep = _session(svm, cfg).run_sync_ingraph(max_rounds=32,
                                              telemetry=16)
    reg = obs_metrics.registry_from_report(rep, labels={"arch": "svm"})
    text = reg.render_prometheus()
    parsed = obs_metrics.parse_prometheus(text)
    assert parsed["el_rounds_total"][0]["value"] == rep.n_aggregations
    assert (parsed["el_round_cost_count"][0]["value"]
            == min(rep.n_aggregations, 16))
    pulls = sum(s["value"] for s in parsed["el_arm_pulls_total"])
    assert pulls == sum(rep.arm_pulls)
    assert parsed["el_program_cache_misses_total"][0]["value"] == 1

    path = str(tmp_path / "run.prom")
    written = obs_metrics.write_metrics_files(reg, path)
    assert written == [path, path + ".json"]
    assert obs_metrics.parse_prometheus(open(path).read())
    assert json.load(open(path + ".json"))


def test_spans_into_registry():
    evs = [{"ev": "span", "name": "cohort.wave", "dur_us": 1500.0},
           {"ev": "span", "name": "cohort.wave", "dur_us": 500.0},
           {"ev": "event", "name": "cohort.refill"}]
    reg = obs_metrics.spans_into_registry(evs)
    parsed = obs_metrics.parse_prometheus(reg.render_prometheus())
    assert parsed["obs_span_cohort_wave_seconds_count"][0]["value"] == 2
    assert (parsed["obs_span_cohort_wave_seconds_sum"][0]["value"]
            == pytest.approx(0.002))
    assert parsed["obs_event_cohort_refill_total"][0]["value"] == 1


def test_registry_from_fleet():
    reg = obs_metrics.registry_from_fleet(
        {"tenants_submitted": 8, "tenants_done": 8, "tenants_pending": 0,
         "tenants_active": 0, "cohorts": 2, "compiles": 2,
         "cache_hits": 0, "cache_misses": 2, "cache_evictions": 0,
         "waves": 7})
    parsed = obs_metrics.parse_prometheus(reg.render_prometheus())
    assert parsed["fleet_tenants_done_total"][0]["value"] == 8
    assert parsed["fleet_cohorts"][0]["value"] == 2
