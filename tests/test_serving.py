"""Serving engine: wave admission, lock-step decode, EOS/max-token exit."""

import numpy as np
import jax
import pytest

from repro.config import get_smoke_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def _engine(arch="qwen3-1.7b", n_slots=3, max_len=96):
    cfg = get_smoke_config(arch)
    m = build_model(cfg.model)
    params = m.init(jax.random.key(0))
    return cfg, ServingEngine(m, params, n_slots=n_slots, max_len=max_len)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-370m",
                                  "jamba-1.5-large-398b"])
def test_engine_completes_all_requests(arch):
    cfg, eng = _engine(arch)
    rng = np.random.default_rng(0)
    for uid in range(5):                     # 5 requests > 3 slots: 2 waves
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.model.vocab_size,
                                size=int(rng.integers(4, 12))
                                ).astype(np.int32),
            max_new_tokens=5))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 5 for r in done)
    assert all(0 <= t < cfg.model.vocab_size
               for r in done for t in r.output)


def test_engine_eos_terminates_early():
    cfg, eng = _engine()
    m = eng.model
    # find the model's greedy next token for a fixed prompt, use it as EOS
    prompt = np.arange(1, 9, dtype=np.int32)
    cache = m.init_cache(eng.n_slots, eng.max_len)
    batch = np.tile(prompt, (eng.n_slots, 1))
    logits, _ = m.prefill(eng.params, batch, cache)
    eos = int(np.argmax(np.asarray(logits)[0, -1]))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=8, eos_id=eos))
    done = eng.run()
    assert len(done) == 1
    assert done[0].output[-1] == eos
    assert len(done[0].output) == 1          # first sampled token == EOS


def test_engine_matches_single_request_decode():
    """Batch slots must not leak across requests: a request decoded in a
    full wave equals the same request decoded alone."""
    cfg, eng1 = _engine(n_slots=1)
    prompt = np.arange(2, 10, dtype=np.int32)
    eng1.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    solo = eng1.run()[0].output

    cfg, eng3 = _engine(n_slots=3)
    rng = np.random.default_rng(1)
    eng3.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    for uid in (1, 2):
        eng3.submit(Request(
            uid=uid, prompt=rng.integers(0, cfg.model.vocab_size, size=8
                                         ).astype(np.int32),
            max_new_tokens=4))
    batched = [r for r in eng3.run() if r.uid == 0][0].output
    assert solo == batched


def test_engine_admits_into_free_slot_mid_flight():
    """Regression: ``step()`` promised free-slot admission but only
    admitted when ALL slots were empty — a queued request now joins as
    soon as any slot frees, while the others keep decoding."""
    cfg, eng = _engine(n_slots=2)
    rng = np.random.default_rng(2)
    p = lambda n: rng.integers(0, cfg.model.vocab_size,
                               size=n).astype(np.int32)
    eng.submit(Request(uid=0, prompt=p(8), max_new_tokens=3))
    eng.submit(Request(uid=1, prompt=p(8), max_new_tokens=9))
    eng.submit(Request(uid=2, prompt=p(6), max_new_tokens=4))
    done = []
    for _ in range(3):                  # prefill + 2 decodes: uid0 exits
        done += eng.step()
    assert [r.uid for r in done] == [0]
    assert eng.active == 1 and len(eng.waiting) == 1
    done += eng.step()                  # uid2 admits into the freed slot
    assert eng.active == 2 and not eng.waiting
    assert {r.uid for r in eng.slot_req if r is not None} == {1, 2}
    done += eng.run()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert all(len(r.output) == r.max_new_tokens for r in done)


def test_engine_mid_flight_admission_matches_solo_decode():
    """A greedy request admitted mid-flight decodes exactly like a solo
    run of the same (position-aligned) prompt — the scratch-cache
    prefill + row scatter must not disturb numerics."""
    cfg, eng = _engine(n_slots=2)
    prompt = np.arange(2, 8, dtype=np.int32)        # len 6 < cur_len 8
    eng.submit(Request(uid=0, prompt=np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=3))
    eng.submit(Request(uid=1, prompt=np.arange(3, 11, dtype=np.int32),
                       max_new_tokens=9))
    eng.submit(Request(uid=2, prompt=prompt, max_new_tokens=4))
    batched = [r for r in eng.run() if r.uid == 2][0].output

    # uid0 exits after 3 tokens (prefill + 2 decodes), so uid2 admits at
    # shared position 10 — the solo twin runs the same left-padded prompt
    cfg, solo = _engine(n_slots=1)
    solo.submit(Request(uid=2, prompt=np.pad(prompt, (10 - len(prompt), 0)),
                        max_new_tokens=4))
    assert solo.run()[0].output == batched


def test_engine_defers_prompt_longer_than_shared_position():
    """A queued prompt longer than the slots' shared position cannot be
    position-aligned mid-flight; it waits for the next fresh wave (and
    still completes)."""
    cfg, eng = _engine(n_slots=2)
    rng = np.random.default_rng(3)
    p = lambda n: rng.integers(0, cfg.model.vocab_size,
                               size=n).astype(np.int32)
    eng.submit(Request(uid=0, prompt=p(8), max_new_tokens=3))
    eng.submit(Request(uid=1, prompt=p(8), max_new_tokens=5))
    eng.submit(Request(uid=2, prompt=p(40), max_new_tokens=2))
    done = []
    for _ in range(4):
        done += eng.step()
    # uid0 exited, but uid2 (longer than the shared position) must wait
    assert eng.active == 1 and len(eng.waiting) == 1
    done += eng.run()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert all(len(r.output) == r.max_new_tokens for r in done)


def test_engine_per_slot_temperature():
    """Each slot samples with its own request's temperature (regression:
    the whole batch used to inherit the first slot's temperature, so a
    greedy request admitted after a hot one decoded stochastically)."""
    cfg, eng1 = _engine(n_slots=1)
    prompt = np.arange(2, 10, dtype=np.int32)
    eng1.submit(Request(uid=0, prompt=prompt, max_new_tokens=4,
                        temperature=0.0))
    greedy_solo = eng1.run()[0].output

    cfg, eng2 = _engine(n_slots=2)
    # slot 0 = hot sampler, slot 1 = the greedy request under test
    eng2.submit(Request(uid=1, prompt=np.arange(5, 13, dtype=np.int32),
                        max_new_tokens=4, temperature=5.0))
    eng2.submit(Request(uid=0, prompt=prompt, max_new_tokens=4,
                        temperature=0.0))
    batched = [r for r in eng2.run() if r.uid == 0][0].output
    assert batched == greedy_solo
