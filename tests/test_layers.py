"""Layer-level behaviour: RoPE, RMSNorm, attention paths, mamba mixer."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MambaConfig, ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M


CFG = ModelConfig(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                  dtype="float32")


def test_rms_norm_scale_identity():
    x = jax.random.normal(jax.random.key(0), (2, 8, 16))
    y = L.rms_norm(jnp.zeros(16), x)
    rms = jnp.sqrt(jnp.mean(y ** 2, -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relative():
    x = jax.random.normal(jax.random.key(1), (1, 8, 2, 32))
    pos = jnp.arange(8)
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.key(2), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.key(3), (1, 1, 1, 32))
    def dot(i, j):
        qi = L.apply_rope(q, jnp.array([i]), 10000.0)
        kj = L.apply_rope(k, jnp.array([j]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert dot(3, 1) == pytest.approx(dot(7, 5), rel=1e-4)


def test_blocked_attention_matches_naive():
    cfg = CFG
    p = L.init_attention(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 96, 64))
    pos = jnp.arange(96)
    y_naive = L.attention(p, cfg, x, pos, impl="naive")
    y_blocked = L.attention(p, cfg, x, pos, impl="blocked")
    np.testing.assert_allclose(np.asarray(y_naive), np.asarray(y_blocked),
                               atol=1e-4, rtol=1e-4)


def test_sliding_window_blocks_distant_tokens():
    cfg = dataclasses.replace(CFG, sliding_window=8)
    p = L.init_attention(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 64, 64))
    pos = jnp.arange(64)
    y = L.attention(p, cfg, x, pos, impl="naive")
    # perturbing a token far outside the window must not change the output
    x2 = x.at[:, 0].add(100.0)
    y2 = L.attention(p, cfg, x2, pos, impl="naive")
    np.testing.assert_allclose(np.asarray(y[:, 32:]),
                               np.asarray(y2[:, 32:]), atol=1e-4)


def test_windowed_slice_matches_masked():
    """The KV-slice optimization must be numerically identical."""
    cfg = dataclasses.replace(CFG, sliding_window=32)
    p = L.init_attention(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 256, 64))
    pos = jnp.arange(256)
    y_masked = L.attention(p, cfg, x, pos, impl="blocked")
    y_sliced = L.attention(p, cfg, x, pos, impl="blocked",
                           window_slice=True)
    np.testing.assert_allclose(np.asarray(y_masked), np.asarray(y_sliced),
                               atol=1e-4, rtol=1e-4)


def test_attention_fill_then_decode_consistent():
    cfg = CFG
    p = L.init_attention(jax.random.key(0), cfg)
    s = 16
    x = jax.random.normal(jax.random.key(1), (2, s, 64))
    pos = jnp.arange(s)
    y_full = L.attention(p, cfg, x, pos, impl="naive")
    ck = jnp.zeros((2, s + 4, 2, 16))
    cv = jnp.zeros((2, s + 4, 2, 16))
    _, ck, cv = L.attention_fill(p, cfg, x[:, :-1], pos[:-1], ck, cv)
    y_dec, _, _ = L.attention_decode(p, cfg, x[:, -1:], ck, cv,
                                     jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(y_full[:, -1:]),
                               np.asarray(y_dec), atol=1e-4, rtol=1e-4)


def test_qkv_bias_and_qk_norm_paths():
    for flags in [dict(qkv_bias=True), dict(qk_norm=True),
                  dict(qkv_bias=True, qk_norm=True)]:
        cfg = dataclasses.replace(CFG, **flags)
        p = L.init_attention(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 8, 64))
        y = L.attention(p, cfg, x, jnp.arange(8))
        assert bool(jnp.isfinite(y).all())
        if flags.get("qkv_bias"):
            assert "bq" in p
        if flags.get("qk_norm"):
            assert "q_norm" in p


# ---------------------------------------------------------------------------
# mamba mixer
# ---------------------------------------------------------------------------


def _mamba_cfg():
    return ModelConfig(d_model=32, n_layers=1, d_ff=0, dtype="float32",
                       mamba=MambaConfig(d_state=16, d_conv=4, expand=2,
                                         head_dim=16, chunk_size=16))


def test_mamba_mixer_prefill_decode_chain():
    cfg = _mamba_cfg()
    p = M.init_mamba(jax.random.key(0), cfg)
    s = 24
    x = jax.random.normal(jax.random.key(1), (2, s, 32))
    y_full, cache = M.mamba_mixer_with_state(p, cfg, x)
    # continue decoding one more token from the cached state
    x_next = jax.random.normal(jax.random.key(2), (2, 1, 32))
    y_dec, _ = M.mamba_decode(p, cfg, x_next, cache)
    # reference: full pass over s+1 tokens
    y_ref, _ = M.mamba_mixer_with_state(
        p, cfg, jnp.concatenate([x, x_next], axis=1))
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_ref[:, -1:]),
                               atol=2e-4, rtol=1e-3)


def test_mamba_chunk_padding_is_exact():
    """seq not a multiple of chunk_size must give identical results."""
    cfg = _mamba_cfg()                      # chunk 16
    p = M.init_mamba(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 40, 32))   # 40 % 16 != 0
    y40 = M.mamba_mixer(p, cfg, x)
    y48 = M.mamba_mixer(p, cfg, jnp.pad(x, ((0, 0), (0, 8), (0, 0))))
    np.testing.assert_allclose(np.asarray(y40), np.asarray(y48[:, :40]),
                               atol=2e-4, rtol=1e-3)
