"""Budget-limited MAB: invariants + behaviour (unit + hypothesis property)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bandit import (BanditState, arm_costs, regret_oracle,
                               select_arm)

POLICIES = ["ol4el", "ucb_bv", "greedy", "freq_only", "eps_greedy",
            "uniform", "fixed_i"]


def test_arm_costs_linear_in_interval():
    c = arm_costs(5, comp_cost=10.0, comm_cost=50.0)
    assert np.allclose(c, [60, 70, 80, 90, 100])


@pytest.mark.parametrize("policy", POLICIES)
def test_never_selects_unaffordable(policy):
    rng = np.random.default_rng(0)
    costs = arm_costs(6, 10.0, 50.0)      # 60..110
    st_ = BanditState.create(6)
    for t in range(200):
        budget = rng.uniform(0, 130)
        arm = select_arm(st_, budget, costs, policy=policy, rng=rng)
        if arm >= 0:
            assert costs[arm] <= budget + 1e-9
            st_.update(arm, rng.uniform(), costs[arm])


@pytest.mark.parametrize("policy", POLICIES)
def test_returns_minus_one_when_broke(policy):
    costs = arm_costs(4, 10.0, 50.0)
    st_ = BanditState.create(4)
    assert select_arm(st_, 10.0, costs, policy=policy) == -1


def test_initialization_phase_tries_every_arm():
    """Paper §IV.B: the initial phase tries each feasible arm once."""
    rng = np.random.default_rng(1)
    costs = arm_costs(5, 1.0, 2.0)
    st_ = BanditState.create(5)
    seen = []
    for _ in range(5):
        arm = select_arm(st_, 1000.0, costs, policy="ol4el", rng=rng)
        seen.append(arm)
        st_.update(arm, 0.5, costs[arm])
    assert sorted(seen) == [0, 1, 2, 3, 4]


def _simulate(policy, means, costs, budget, seed=0, noise=0.05):
    rng = np.random.default_rng(seed)
    st_ = BanditState.create(len(means))
    residual, total_u, pulls = budget, 0.0, 0
    while True:
        arm = select_arm(st_, residual, costs, policy=policy, rng=rng)
        if arm < 0:
            break
        u = means[arm] + noise * rng.standard_normal()
        st_.update(arm, u, costs[arm])
        residual -= costs[arm]
        total_u += means[arm]           # true expected utility earned
        pulls += 1
    return total_u, pulls


def test_ol4el_beats_uniform_on_skewed_arms():
    """With one clearly-best density arm, OL4EL should out-earn uniform."""
    means = np.array([0.05, 0.1, 0.8, 0.15, 0.1])
    costs = arm_costs(5, 2.0, 10.0)     # 12..20
    u_ol, _ = zip(*[ _simulate("ol4el", means, costs, 2000.0, s)
                     for s in range(5) ])
    u_un, _ = zip(*[ _simulate("uniform", means, costs, 2000.0, s)
                     for s in range(5) ])
    assert np.mean(u_ol) > np.mean(u_un) * 1.1


def test_greedy_matches_oracle_asymptotically():
    means = np.array([0.2, 0.9, 0.3])
    costs = np.array([10.0, 12.0, 11.0])
    u, pulls = _simulate("greedy", means, costs, 5000.0, noise=0.01)
    oracle = regret_oracle(means, costs, 5000.0)
    assert u > 0.85 * oracle


def test_ucb_bv_learns_costs():
    """Variable costs: ucb_bv should discover the cheap-good arm."""
    rng = np.random.default_rng(3)
    means_u = np.array([0.3, 0.3, 0.3])
    means_c = np.array([30.0, 10.0, 30.0])     # arm 1 cheapest
    st_ = BanditState.create(3)
    residual = 3000.0
    picks = []
    while True:
        arm = select_arm(st_, residual, means_c, policy="ucb_bv", rng=rng)
        if arm < 0:
            break
        c = means_c[arm] * (1 + 0.2 * rng.standard_normal())
        c = max(c, 1.0)
        st_.update(arm, means_u[arm] + 0.05 * rng.standard_normal(), c)
        residual -= c
        picks.append(arm)
    tail = picks[len(picks) // 2:]
    assert np.mean(np.asarray(tail) == 1) > 0.5


@given(
    n_arms=st.integers(2, 8),
    comp=st.floats(0.5, 20.0),
    comm=st.floats(0.5, 50.0),
    budget=st.floats(10.0, 5000.0),
    policy=st.sampled_from(POLICIES),
    seed=st.integers(0, 100),
)
@settings(max_examples=60, deadline=None)
def test_property_budget_never_exceeded(n_arms, comp, comm, budget, policy,
                                        seed):
    """System invariant: cumulative cost never exceeds the budget, and
    termination always happens (-1) once no arm is affordable."""
    rng = np.random.default_rng(seed)
    costs = arm_costs(n_arms, comp, comm)
    st_ = BanditState.create(n_arms)
    residual = budget
    for _ in range(10_000):
        arm = select_arm(st_, residual, costs, policy=policy, rng=rng)
        if arm < 0:
            assert (costs > residual + 1e-9).all()
            break
        st_.update(arm, rng.uniform(), costs[arm])
        residual -= costs[arm]
        assert residual >= -1e-6
    else:
        pytest.fail("bandit loop did not terminate")


@given(utilities=st.lists(st.floats(0.0, 1.0), min_size=3, max_size=3),
       seed=st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_property_state_statistics(utilities, seed):
    st_ = BanditState.create(3)
    for i, u in enumerate(utilities):
        st_.update(i % 3, u, 1.0)
    assert st_.t == len(utilities)
    assert st_.counts.sum() == len(utilities)
    assert np.isclose(st_.utility_sum.sum(), sum(utilities))
