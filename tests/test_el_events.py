"""The repro.el.events subsystem: the compiled async event-horizon
program vs the host event queue (bit-for-bit on shared jax streams),
variable-cost semantics, horizon derivation, the async support matrix,
and async/cost-noise sweep axes vs independent in-graph runs."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.config import OL4ELConfig, get_config
from repro.data import (make_traffic_dataset, make_wafer_dataset,
                        partition_edges)
from repro.el import ELSession, SweepSpec
from repro.el.events import (ASYNC_KNOB_NAMES, async_knobs,
                             default_event_horizon)
from repro.federated import ClassicExecutor
from repro.models import build_model


def _svm_fixture(n=600, n_edges=3, seed=0, budget=700.0, mode="async",
                 utility="eval_gain", **cfg_kw):
    train, test = make_wafer_dataset(n=n, seed=seed)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ol = dataclasses.replace(
        exp.ol4el, mode=mode, policy="ol4el", n_edges=n_edges,
        budget=budget, heterogeneity=4.0, utility=utility, seed=seed,
        **cfg_kw)
    edges = partition_edges(train, n_edges, alpha=1.0, seed=seed)
    ex = ClassicExecutor(model, edges, test, batch=32, lr=0.05)
    init = model.init(jax.random.key(seed))
    return ol, ex, init


def _session(ol, ex, init) -> ELSession:
    return (ELSession(ol, metric_name="accuracy", lr=0.05)
            .with_executor(ex, init_params=init))


def _assert_bit_identical(ref, ing):
    """Event order, merge values (metric/utility), charged costs and
    bandit statistics must agree exactly (float64 casts of f32 values,
    so == is bit-identity)."""
    assert ref.n_aggregations == ing.n_aggregations > 0
    for t, (a, b) in enumerate(zip(ref.records, ing.records)):
        assert a.edge == b.edge, t
        assert a.interval == b.interval, t
        assert a.wall_time == b.wall_time, t
        assert a.total_consumed == b.total_consumed, t
        assert a.metric == b.metric or (
            np.isnan(a.metric) and np.isnan(b.metric)), t
        assert a.utility == b.utility, t
    assert ref.arm_pulls == ing.arm_pulls
    assert ref.terminated_reason == ing.terminated_reason
    assert ref.final_metric == ing.final_metric


# ---------------------------------------------------------------------------
# knobs + horizon
# ---------------------------------------------------------------------------


def test_async_knobs_shapes_and_noise_gating():
    cfg = OL4ELConfig(mode="async", n_edges=3, heterogeneity=4.0,
                      cost_noise=0.3)                 # cost_model=fixed
    knobs = async_knobs(cfg)
    assert set(knobs) == set(ASYNC_KNOB_NAMES)
    assert knobs["costs_ek"].shape == (3, cfg.max_interval)
    assert knobs["comp"].shape == (3,)
    # interval-1 cost of every edge == its min cost
    np.testing.assert_allclose(knobs["costs_ek"][:, 0],
                               knobs["min_edge_cost"])
    # noise only applies in variable-cost mode (host realized_cost rule)
    assert knobs["cost_noise"] == 0.0
    var = async_knobs(dataclasses.replace(cfg, cost_model="variable"))
    assert var["cost_noise"] == np.float32(0.3)
    assert knobs["async_alpha"] == np.float32(0.5)


def test_default_event_horizon_scales_with_budget_and_never_truncates():
    cfg = OL4ELConfig(mode="async", n_edges=2, budget=600.0,
                      comp_cost=10.0, comm_cost=50.0, heterogeneity=1.0)
    h = default_event_horizon(cfg)
    assert h == 2 * (int(600.0 // 60.0) + 1)
    assert default_event_horizon(
        dataclasses.replace(cfg, budget=6000.0)) > h
    # variable-cost blocks can realize at the 0.1 multiplier floor
    assert default_event_horizon(
        dataclasses.replace(cfg, cost_model="variable",
                            cost_noise=0.5)) >= 10 * (h - 2)
    # a real run under the derived horizon terminates on budget, not
    # on the horizon (no silent truncation)
    ol, ex, init = _svm_fixture()
    rep = _session(ol, ex, init).run_async_ingraph()
    assert rep.terminated_reason == "budget_exhausted"
    assert rep.n_aggregations < default_event_horizon(ol)


# ---------------------------------------------------------------------------
# THE acceptance property: the compiled event-horizon program is
# bit-identical to the host priority-queue loop on the same jax RNG
# streams in fixed-cost mode (event order, merge values, charged costs)
# ---------------------------------------------------------------------------


def test_async_ingraph_bit_identical_to_host_event_queue_fixed_cost():
    ol, ex, init = _svm_fixture()
    ref = _session(ol, ex, init).run_async(rng_streams="jax")
    ing = _session(ol, ex, init).run_async_ingraph()
    assert ref.terminated_reason == "budget_exhausted"
    # a real async trace: multiple edges complete blocks, out of lockstep
    assert len({r.edge for r in ref.records}) == ol.n_edges
    _assert_bit_identical(ref, ing)
    # and the total charge equals the simulated wall-clock per edge sum
    assert ing.total_consumed == pytest.approx(
        sum(r.total_consumed - p for r, p in
            zip(ing.records, [0.0] + [r.total_consumed
                                      for r in ing.records[:-1]])))


def test_async_ingraph_bit_identical_param_delta():
    ol, ex, init = _svm_fixture(utility="param_delta")
    ref = _session(ol, ex, init).run_async(rng_streams="jax")
    ing = _session(ol, ex, init).run_async_ingraph()
    _assert_bit_identical(ref, ing)


def test_async_ingraph_variable_cost_bit_identical_and_statistical():
    """Variable-cost mode shares the jax noise stream, so even the noisy
    paths agree bit-for-bit; vs the legacy numpy host loop the agreement
    is statistical (same charged-cost model, different streams)."""
    ol, ex, init = _svm_fixture(n=800, budget=900.0,
                                cost_model="variable", cost_noise=0.3)
    ref = _session(ol, ex, init).run_async(rng_streams="jax")
    ing = _session(ol, ex, init).run_async_ingraph()
    _assert_bit_identical(ref, ing)
    # every block's charge is at least 10% of its expected cost
    knobs = async_knobs(ol)
    prev = 0.0
    for rec in ing.records:
        charge = rec.total_consumed - prev
        expected = (rec.interval * knobs["comp"][rec.edge]
                    + knobs["comm"][rec.edge])
        assert charge >= 0.1 * expected - 1e-3
        prev = rec.total_consumed
    host = _session(ol, ex, init).run_async()
    assert host.terminated_reason == ing.terminated_reason == \
        "budget_exhausted"
    assert ing.total_consumed == pytest.approx(host.total_consumed,
                                               rel=0.35)
    assert ing.final_metric > 0.5 and host.final_metric > 0.5


def test_async_variable_noise_zero_is_bitwise_fixed():
    ol, ex, init = _svm_fixture()
    fixed = _session(ol, ex, init).run_async_ingraph()
    var0 = _session(
        dataclasses.replace(ol, cost_model="variable", cost_noise=0.0),
        ex, init).run_async_ingraph()
    _assert_bit_identical(fixed, var0)


# ---------------------------------------------------------------------------
# K-event waves: batched dispatch is an order-equivalent reformulation —
# every K > 1 program must reproduce the single-event (K=1) trajectory
# bit for bit (merge values, charged costs, arm pulls, event order)
# ---------------------------------------------------------------------------


def _assert_same_params(a, b):
    for pa, pb in zip(jax.tree.leaves(a.final_params),
                      jax.tree.leaves(b.final_params)):
        assert np.array_equal(np.asarray(pa), np.asarray(pb))


@pytest.mark.parametrize("batch_k", [2, 3])   # 3 == n_edges (full wave)
@pytest.mark.parametrize("cost_kw", [
    {},                                               # fixed cost
    {"cost_model": "variable", "cost_noise": 0.3},    # noisy charges
], ids=["fixed", "variable"])
def test_async_k_waves_bit_identical_to_single_event(batch_k, cost_kw):
    ol, ex, init = _svm_fixture(**cost_kw)
    base = _session(dataclasses.replace(ol, async_batch_k=1),
                    ex, init).run_async_ingraph()
    wave = _session(dataclasses.replace(ol, async_batch_k=batch_k),
                    ex, init).run_async_ingraph()
    assert base.terminated_reason == "budget_exhausted"
    _assert_bit_identical(base, wave)
    _assert_same_params(base, wave)


def test_async_k1_is_the_auto_default_replicated():
    """async_batch_k=0 (auto) resolves to 1 off-mesh: the default
    program IS the K=1 special case, byte for byte."""
    ol, ex, init = _svm_fixture()
    auto = _session(ol, ex, init).run_async_ingraph()     # batch_k=0
    k1 = _session(dataclasses.replace(ol, async_batch_k=1),
                  ex, init).run_async_ingraph()
    _assert_bit_identical(auto, k1)
    _assert_same_params(auto, k1)


def test_async_k_waves_same_tick_tie_break_matches_argmin_order():
    """Homogeneous fleet (heterogeneity=0): edges repeatedly finish at
    the SAME wall-clock tick.  The wave's within-gap ordering must
    reproduce argmin's lowest-index-first pops exactly — a strict-<
    gap predicate or an unstable top-k would reorder these events."""
    ol, ex, init = _svm_fixture(n_edges=4, seed=1, budget=400.0)
    ol = dataclasses.replace(ol, heterogeneity=0.0)
    base = _session(dataclasses.replace(ol, async_batch_k=1),
                    ex, init).run_async_ingraph()
    # the fixture really exercises ties: some consecutive events share
    # a wall-clock stamp
    walls = [r.wall_time for r in base.records]
    assert any(a == b for a, b in zip(walls, walls[1:]))
    for k in (2, 4):
        wave = _session(dataclasses.replace(ol, async_batch_k=k),
                        ex, init).run_async_ingraph()
        _assert_bit_identical(base, wave)
        _assert_same_params(base, wave)


def test_resolve_async_batch_k_explicit_and_auto():
    from repro.el.events import resolve_async_batch_k
    cfg = OL4ELConfig(mode="async", n_edges=3, heterogeneity=4.0)
    # auto: replicated (no mesh) stays single-event
    assert resolve_async_batch_k(cfg, mesh=None) == 1
    # explicit K clamps to the fleet size
    assert resolve_async_batch_k(
        dataclasses.replace(cfg, async_batch_k=2)) == 2
    assert resolve_async_batch_k(
        dataclasses.replace(cfg, async_batch_k=64)) == 3


def test_async_kmeans_param_delta_host_scoring():
    """No jittable F1 metric: the program runs with NaN metric history
    and the report scores final params host-side; still bit-identical
    to the reference queue."""
    train, test = make_traffic_dataset(n=600)
    exp = get_config("kmeans-traffic")
    model = build_model(exp.model)
    ol = dataclasses.replace(exp.ol4el, mode="async", policy="ol4el",
                             n_edges=2, budget=500.0, heterogeneity=2.0,
                             utility="param_delta")
    edges = partition_edges(train, 2, alpha=2.0)
    ex = ClassicExecutor(model, edges, test, batch=128, lr=1.0)
    init = model.init(jax.random.key(1))

    def sess():
        return (ELSession(ol, metric_name="f1", lr=1.0)
                .with_executor(ex, init_params=init))

    ref = sess().run_async(rng_streams="jax")
    ing = sess().run_async_ingraph()
    _assert_bit_identical(ref, ing)
    assert ing.final_metric > 0.5
    assert all(np.isnan(r.metric) for r in ing.records)


# ---------------------------------------------------------------------------
# support matrix + session plumbing
# ---------------------------------------------------------------------------


def test_async_ingraph_rejects_unsupported_combinations():
    ol, ex, init = _svm_fixture()
    with pytest.raises(ValueError, match="policy='greedy'"):
        _session(dataclasses.replace(ol, policy="greedy"), ex,
                 init).run_async_ingraph()

    class NotInGraph:
        def local_train(self, params, edge, n_iters, seed):
            return params, {}

        def evaluate(self, params):
            return {"accuracy": 0.0}

    s = ELSession(OL4ELConfig(mode="async")).with_executor(
        NotInGraph(), init_params={})
    with pytest.raises(TypeError, match="in-graph"):
        s.run_async_ingraph()
    with pytest.raises(ValueError, match="rng_streams"):
        _session(ol, ex, init).run_async(rng_streams="bogus")


def test_policies_registry_records_ingraph_modes():
    from repro.el import policies
    assert policies.ingraph_modes("ol4el") == ("sync", "async")
    assert policies.ingraph_modes("greedy") == ()
    assert policies.ingraph_modes("nope") == ()


def test_async_ingraph_program_reused_across_knob_changes():
    """ucb_c/budget/heterogeneity/cost_noise/async_alpha/seed are traced
    inputs — changing them must NOT rebuild or retrace the program."""
    ol, ex, init = _svm_fixture()
    s = _session(ol, ex, init)
    r1 = s.run_async_ingraph(max_events=64)
    prog = s._async_fastpath
    s.cfg = dataclasses.replace(s.cfg, ucb_c=0.5, budget=900.0, seed=5,
                                async_alpha=0.3)
    r2 = s.run_async_ingraph(max_events=64)
    assert s._async_fastpath is prog
    assert prog._cache_size() == 1
    assert r2.n_aggregations > 0
    assert r2.total_consumed != r1.total_consumed


def test_session_sync_cfg_coerced_for_async_ingraph():
    ol, ex, init = _svm_fixture()
    rep = _session(dataclasses.replace(ol, mode="sync"), ex,
                   init).run_async_ingraph(max_events=32)
    assert rep.mode == "async"
    assert rep.n_aggregations > 0
    # per-event records carry the event edge
    assert {r.edge for r in rep.records} <= set(range(ol.n_edges))


# ---------------------------------------------------------------------------
# async sweeps: per-cell == independent run_async_ingraph (incl. the
# async_alpha axis), mirroring test_el_sweep.py's sync acceptance
# ---------------------------------------------------------------------------


def test_async_sweep_cells_bit_identical_to_independent_runs():
    ol, ex, init = _svm_fixture()
    spec = SweepSpec(async_alpha=(0.3, 0.6), seeds=(0, 3), max_rounds=48)
    sess = _session(ol, ex, init)
    rep = sess.sweep(spec)
    assert sess._sweep_program._cache_size() == 1
    assert rep.n_cells == 4
    for i, ccfg in enumerate(spec.cell_cfgs(ol)):
        assert ccfg.mode == "async"
        ind = _session(ccfg, ex, init).run_async_ingraph(max_events=48)
        n = int(rep.out["n_rounds"][i])
        assert n == ind.n_aggregations > 0
        assert np.array_equal(
            rep.out["metric"][i][:n].astype(np.float64),
            np.array([r.metric for r in ind.records]))
        assert np.array_equal(rep.out["edge"][i][:n],
                              np.array([r.edge for r in ind.records]))
        assert np.array_equal(
            rep.out["interval"][i][:n].astype(np.float64),
            np.array([r.interval for r in ind.records]))
        assert np.array_equal(
            rep.out["consumed"][i][:n].astype(np.float64),
            np.array([r.total_consumed for r in ind.records]))
        assert np.array_equal(
            np.asarray(rep.out["arm_pulls"][i]).sum(axis=0),
            np.asarray(ind.arm_pulls))
        assert float(rep.out["wall_time"][i]) == ind.wall_time


def test_sync_sweep_cost_noise_axis_matches_independent_runs():
    """The promoted cost_noise axis (ROADMAP item): a fixed+variable
    grid runs as one compiled program, each cell bit-identical to an
    independent run_sync_ingraph with that cell's config."""
    ol, ex, init = _svm_fixture(mode="sync")
    spec = SweepSpec(cost_noise=(0.0, 0.3), seeds=(0, 1), max_rounds=48)
    rep = _session(ol, ex, init).sweep(spec)
    assert rep.n_cells == 4
    for i, ccfg in enumerate(spec.cell_cfgs(ol)):
        assert ccfg.cost_model == ("variable" if ccfg.cost_noise > 0
                                   else "fixed")
        ind = _session(ccfg, ex, init).run_sync_ingraph(max_rounds=48)
        n = int(rep.out["n_rounds"][i])
        assert n == ind.n_aggregations > 0
        assert np.array_equal(
            rep.out["metric"][i][:n].astype(np.float64),
            np.array([r.metric for r in ind.records]))
        assert np.array_equal(
            rep.out["consumed"][i][:n].astype(np.float64),
            np.array([r.total_consumed for r in ind.records]))


def test_sweep_inherited_dormant_noise_stays_dormant():
    """A fixed-cost session with a dormant cfg.cost_noise must sweep
    exactly like its single runs: only an EXPLICIT cost_noise axis flips
    cells to cost_model='variable' (review regression)."""
    cfg = OL4ELConfig(mode="sync", cost_model="fixed", cost_noise=0.3)
    cells = SweepSpec(ucb_c=(1.0, 2.0)).cell_cfgs(cfg)
    assert all(c.cost_model == "fixed" for c in cells)
    # the knob derivation then keeps the noise gated off
    from repro.el.ingraph import sync_knobs
    assert all(sync_knobs(c)["cost_noise"] == 0.0 for c in cells)
    # an explicit axis does activate it
    cells = SweepSpec(cost_noise=(0.0, 0.3)).cell_cfgs(cfg)
    assert [c.cost_model for c in cells] == ["fixed", "variable"]


def test_async_ingraph_default_horizon_does_not_recompile_per_knob():
    """With max_events=None the derived horizon is bucketed before it
    enters the compile-cache key — knob changes (budget included) must
    reuse the program (review regression)."""
    ol, ex, init = _svm_fixture()
    s = _session(ol, ex, init)
    s.run_async_ingraph()
    prog = s._async_fastpath
    s.cfg = dataclasses.replace(s.cfg, budget=900.0, ucb_c=0.5)
    rep = s.run_async_ingraph()
    assert s._async_fastpath is prog
    assert prog._cache_size() == 1
    assert rep.terminated_reason == "budget_exhausted"


def test_sweep_spec_new_axes_validation():
    with pytest.raises(ValueError, match="cost_noise"):
        SweepSpec(cost_noise=(-0.1,))
    with pytest.raises(ValueError, match="async_alpha"):
        SweepSpec(async_alpha=(0.0,))
    with pytest.raises(ValueError, match="async_alpha"):
        SweepSpec(async_alpha=(1.5,))
    with pytest.raises(ValueError, match="async_batch_k"):
        SweepSpec(async_batch_k=(-1,))
    spec = SweepSpec(async_alpha=[0.25, 0.75], cost_noise=[0.1])
    assert spec.async_alpha == (0.25, 0.75) and hash(spec)
    assert spec.n_cells == 2


def test_sweep_spec_per_batch_k_splits_the_structural_axis():
    spec = SweepSpec(async_batch_k=(1, 2), seeds=(0, 3), max_rounds=48)
    subs = spec.per_batch_k()
    assert [k for k, _ in subs] == [1, 2]
    assert all(s.async_batch_k == (k,) for k, s in subs)
    assert sum(s.n_cells for _, s in subs) == spec.n_cells == 4
    # single-valued (or absent) axis: no split at all
    assert SweepSpec(seeds=(0,)).per_batch_k()[0][1] is not None
    assert len(SweepSpec(async_batch_k=(2,)).per_batch_k()) == 1


def test_async_sweep_batch_k_axis_is_a_pure_throughput_axis():
    """async_batch_k is semi-structural: the sweep splits into one
    compiled sub-program per K, and — K being order-equivalent — the
    K=1 and K=2 blocks of the grid must be bit-identical to each other
    and to the independent single runs."""
    ol, ex, init = _svm_fixture()
    spec = SweepSpec(async_batch_k=(1, 2), seeds=(0, 3), max_rounds=48)
    sess = _session(ol, ex, init)
    rep = sess.sweep(spec)
    assert rep.n_cells == 4
    out = rep.out
    # axis order puts async_batch_k slowest: cells 0,1 are K=1 seeds
    # (0,3); cells 2,3 the same seeds at K=2
    for f in ("n_rounds", "metric", "edge", "consumed", "wall_time"):
        assert np.array_equal(out[f][:2], out[f][2:],
                              equal_nan=(f == "metric")), f
    for i, ccfg in enumerate(spec.cell_cfgs(ol)[:2]):
        ind = _session(ccfg, ex, init).run_async_ingraph(max_events=48)
        n = int(out["n_rounds"][i])
        assert n == ind.n_aggregations > 0
        assert np.array_equal(
            out["metric"][i][:n].astype(np.float64),
            np.array([r.metric for r in ind.records]))
        assert np.array_equal(out["edge"][i][:n],
                              np.array([r.edge for r in ind.records]))


def test_async_sweep_partition_specs_costs_ek_placement():
    from jax.sharding import PartitionSpec as P
    from repro.el.sweep import sweep_partition_specs
    key_spec, knobs = sweep_partition_specs(
        ("data", "model"), {"data": 4, "model": 16},
        n_cells=8, n_edges=32, mode="async")
    assert key_spec == P(("data",))
    assert knobs["costs_ek"] == P(("data",), "model", None)  # [C, E, K]
    assert knobs["async_alpha"] == P(("data",))              # [C]
    assert knobs["cost_noise"] == P(("data",))
    assert knobs["comp"] == P(("data",), "model")
