"""Dry-run integration: lower+compile on a small forced-device mesh in a
subprocess (keeps the main test process at 1 device), plus HLO collective
parsing units."""

import json
import os
import subprocess
import sys

import pytest

from repro.launch.dryrun import _type_bytes, parse_collectives

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args, out):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_DRYRUN_DEVICES="8",
               REPRO_DEBUG_MESH="2")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--out", out] + args,
        capture_output=True, text=True, env=env, timeout=900)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("qwen3-1.7b", "train_4k"),
    ("olmoe-1b-7b", "decode_32k"),
    ("mamba2-370m", "long_500k"),
])
def test_dryrun_small_mesh(tmp_path, arch, shape):
    out = str(tmp_path / "dry.jsonl")
    r = _run_dryrun(["--arch", arch, "--shape", shape, "--mesh", "pod"],
                    out)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(open(out).readline())
    assert rec["ok"], rec
    assert rec["memory"].get("argument_size_in_bytes", 0) > 0
    assert "collectives" in rec


@pytest.mark.slow
def test_dryrun_el_round_small_mesh(tmp_path):
    out = str(tmp_path / "dry_el.jsonl")
    r = _run_dryrun(["--arch", "qwen3-1.7b", "--shape", "train_4k",
                     "--step", "el_round", "--mesh", "pod"], out)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(open(out).readline())
    assert rec["ok"], rec
    assert rec["step"] == "el_round"
    assert rec["n_edges"] == 2            # debug mesh: data axis = 2


# ---------------------------------------------------------------------------
# HLO parsing units
# ---------------------------------------------------------------------------


def test_type_bytes():
    assert _type_bytes("bf16[4,128]{1,0}") == 4 * 128 * 2
    assert _type_bytes("f32[16]") == 64
    assert _type_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert _type_bytes("pred[]") == 1


def test_parse_collectives_counts_and_bytes():
    # post-optimization HLO prints operands WITHOUT types; the parser
    # meters each collective's RESULT type (== operand for all-reduce /
    # all-to-all / permute; == received payload for all-gather)
    hlo = """
  %ar = bf16[8,128]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[32,16]{1,0} all-gather(%y), dimensions={0}
  %p = f32[4]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %other = f32[4]{0} add(%a, %b)
"""
    out = parse_collectives(hlo)
    assert out["per_op"]["all-reduce"]["count"] == 1
    assert out["per_op"]["all-reduce"]["bytes"] == 8 * 128 * 2
    assert out["per_op"]["all-gather"]["bytes"] == 32 * 16 * 4  # result
    assert out["per_op"]["collective-permute"]["count"] == 1
    assert "add" not in out["per_op"]
