"""In-graph (jittable) bandit: equivalence with the host bandit + jit/vmap."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bandit import (BanditState, arm_costs, jax_bandit_init,
                               jax_bandit_update, jax_select_arm,
                               jax_selection_weights, select_arm)


def test_weights_match_host_policy_distribution():
    """After identical updates, jnp selection weights ∝ host ol4el weights."""
    costs = arm_costs(5, 10.0, 50.0)
    host = BanditState.create(5)
    dev = jax_bandit_init(5)
    rng = np.random.default_rng(0)
    for i in range(25):
        arm = i % 5
        u = rng.uniform()
        host.update(arm, u, costs[arm])
        dev = jax_bandit_update(dev, jnp.asarray(arm), jnp.asarray(u),
                                jnp.asarray(costs[arm]))
    np.testing.assert_array_equal(np.asarray(dev["counts"]), host.counts)
    np.testing.assert_allclose(np.asarray(dev["utility_sum"]),
                               host.utility_sum, rtol=1e-6)
    w = np.asarray(jax_selection_weights(dev, 500.0, jnp.asarray(costs)))
    # host weight reconstruction (same formula)
    n = np.maximum(host.counts, 1)
    ucb = host.mean_utility() + np.sqrt(2.0 * np.log(max(host.t, 2)) / n)
    density = ucb / costs
    feasible = costs <= 500.0
    d = density - density[feasible].min() + 1e-9
    freq = np.where(feasible, np.floor(500.0 / costs), 0.0)
    expect = np.where(feasible, np.maximum(d * freq, 1e-12), 0.0)
    np.testing.assert_allclose(w, expect, rtol=1e-5)


def test_jax_select_arm_jits_and_respects_budget():
    costs = jnp.asarray(arm_costs(4, 10.0, 50.0))
    state = jax_bandit_init(4)
    sel = jax.jit(jax_select_arm)
    # broke: nothing affordable
    assert int(sel(jax.random.key(0), state, 10.0, costs)) == -1
    # rich: always feasible, arm in range
    for i in range(20):
        arm = int(sel(jax.random.key(i), state, 1000.0, costs))
        assert 0 <= arm < 4
        state = jax_bandit_update(state, jnp.asarray(arm),
                                  jnp.asarray(0.5), costs[arm])
    assert int(state["t"]) == 20


def test_jax_bandit_vmaps_over_edges():
    """Async mode: one bandit per edge, vmapped selection."""
    n_edges, k = 4, 5
    costs = jnp.asarray(arm_costs(k, 10.0, 50.0))
    states = jax.vmap(lambda _: jax_bandit_init(k))(jnp.arange(n_edges))
    budgets = jnp.asarray([100.0, 200.0, 500.0, 40.0])
    rngs = jax.random.split(jax.random.key(0), n_edges)
    arms = jax.vmap(lambda r, s, b: jax_select_arm(r, s, b, costs))(
        rngs, states, budgets)
    arms = np.asarray(arms)
    assert arms[3] == -1                   # 40 < cheapest arm (60)
    assert all(0 <= a < k for a in arms[:3])
    # update all edges in one vmapped call
    states = jax.vmap(jax_bandit_update)(
        states, jnp.maximum(jnp.asarray(arms), 0),
        jnp.full((n_edges,), 0.3), jnp.full((n_edges,), 60.0))
    assert int(states["t"][0]) == 1


def test_initialization_phase_in_graph():
    costs = jnp.asarray(arm_costs(3, 1.0, 2.0))
    state = jax_bandit_init(3)
    seen = set()
    for i in range(3):
        arm = int(jax_select_arm(jax.random.key(i), state, 100.0, costs))
        seen.add(arm)
        state = jax_bandit_update(state, jnp.asarray(arm),
                                  jnp.asarray(0.5), costs[arm])
    assert seen == {0, 1, 2}
