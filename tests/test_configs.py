"""Config system: every arch resolves, exact assigned dims, smoke contract."""

import pytest

from repro.config import (ARCH_IDS, CLASSIC_IDS, INPUT_SHAPES, get_config,
                          get_smoke_config)

EXPECTED_DIMS = {
    # arch: (layers, d_model, heads, kv_heads, d_ff, vocab)
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
    "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
    "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
    "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
}

EXPECTED_MOE = {
    "deepseek-moe-16b": (64, 6, 2),      # experts, top_k, shared
    "jamba-1.5-large-398b": (16, 2, 0),
    "olmoe-1b-7b": (64, 8, 0),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_dims(arch):
    m = get_config(arch).model
    exp = EXPECTED_DIMS[arch]
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_ff,
            m.vocab_size) == exp
    assert m.source, "every config must cite its source"


@pytest.mark.parametrize("arch", list(EXPECTED_MOE))
def test_moe_dims(arch):
    m = get_config(arch).model.moe
    assert (m.num_experts, m.top_k, m.num_shared_experts) == \
        EXPECTED_MOE[arch]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_contract(arch):
    """Reduced variant: <=2 layers, d_model<=512, <=4 experts."""
    m = get_smoke_config(arch).model
    assert m.n_layers <= 2
    assert m.d_model <= 512
    assert m.moe.num_experts <= 4
    full = get_config(arch).model
    assert m.family == full.family
    # family-defining flags preserved
    assert m.qk_norm == full.qk_norm
    assert m.qkv_bias == full.qkv_bias
    assert (m.moe.enabled) == (full.moe.enabled)
    assert m.n_codebooks == full.n_codebooks
    assert (m.num_prefix_embeddings > 0) == (full.num_prefix_embeddings > 0)


def test_param_counts_match_model_names():
    """Analytic param counts land near the advertised sizes."""
    expect_b = {
        "mamba2-370m": 0.37, "deepseek-moe-16b": 16.3, "minicpm-2b": 2.7,
        "qwen2.5-14b": 14.8, "jamba-1.5-large-398b": 398.0,
        "deepseek-coder-33b": 33.3, "olmoe-1b-7b": 6.9, "qwen3-1.7b": 1.7,
    }
    for arch, b in expect_b.items():
        n = get_config(arch).model.num_params() / 1e9
        assert abs(n - b) / b < 0.15, (arch, n, b)


def test_active_params_moe():
    cfg = get_config("olmoe-1b-7b").model
    # OLMoE: ~6.9B total, ~1.3B active
    assert cfg.num_active_params() < 0.25 * cfg.num_params()


def test_input_shapes_assigned():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1
    assert INPUT_SHAPES["decode_32k"].kind == "decode"
    assert INPUT_SHAPES["long_500k"].kind == "decode"


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("nope-7b")


@pytest.mark.parametrize("arch", CLASSIC_IDS)
def test_classic_configs(arch):
    cfg = get_config(arch)
    assert cfg.model.family == "classic"
