"""The paper's SVM and K-means models."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.data import make_traffic_dataset, make_wafer_dataset
from repro.models import build_model
from repro.models.classic import cluster_f1


def test_svm_trains_above_chance():
    train, test = make_wafer_dataset(n=3000)
    model = build_model(get_config("svm-wafer").model)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    xb = jnp.asarray(train["x"])
    yb = jnp.asarray(train["y"])
    step = jax.jit(lambda p, x, y: model.local_step(p, {"x": x, "y": y},
                                                    0.05)[0])
    for _ in range(100):
        idx = rng.integers(0, len(train["y"]), 128)
        params = step(params, xb[idx], yb[idx])
    acc = model.evaluate(params, {k: jnp.asarray(v)
                                  for k, v in test.items()})["accuracy"]
    assert acc > 0.6            # chance is 0.125


def test_kmeans_lloyd_reduces_inertia():
    train, test = make_traffic_dataset(n=2000)
    model = build_model(get_config("kmeans-traffic").model)
    params = model.init(jax.random.key(0))
    x = jnp.asarray(train["x"][:512])
    i0 = float(model.inertia(params, x))
    for _ in range(20):
        params, _ = model.local_step(params, {"x": x}, 1.0)
    i1 = float(model.inertia(params, x))
    assert i1 < i0 * 0.9


def test_kmeans_assign_uses_kernel_consistently():
    train, _ = make_traffic_dataset(n=500)
    cfg = get_config("kmeans-traffic").model
    m_ref = build_model(cfg)
    m_ker = build_model(cfg, use_kernel=True)
    params = m_ref.init(jax.random.key(2))
    x = jnp.asarray(train["x"])
    a1 = np.asarray(m_ref.assign(params, x))
    a2 = np.asarray(m_ker.assign(params, x))
    assert (a1 == a2).mean() > 0.999


def test_cluster_f1_perfect_and_random():
    y = np.repeat(np.arange(3), 50)
    assert cluster_f1(y.copy(), y, 3) == pytest.approx(1.0)
    perm = np.array([2, 0, 1])[y]       # relabeled clusters, same structure
    assert cluster_f1(perm, y, 3) == pytest.approx(1.0)
    rng = np.random.default_rng(0)
    rand = rng.integers(0, 3, size=y.size)
    assert cluster_f1(rand, y, 3) < 0.6
