"""Sharding resolver: every spec must divide the actual tensor dims."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import ARCH_IDS, get_config
from repro.launch.specs import adapt_model_for_shape, input_specs
from repro.config import INPUT_SHAPES
from repro.models import build_model
from repro.sharding import cache_specs, param_specs


class FakeMesh:
    """Mesh stand-in (no devices needed to validate divisibility)."""

    def __init__(self, shape=(16, 16), axes=("data", "model")):
        import numpy as np
        self.devices = np.empty(shape, dtype=object)
        self.axis_names = axes


AXIS_SIZES = {"pod": 2, "data": 16, "model": 16}


def _check_divisible(shape_tree, spec_tree, mesh_axes):
    leaves_s = jax.tree.leaves(shape_tree)
    leaves_p = jax.tree.leaves(spec_tree,
                               is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_s) == len(leaves_p)
    for sds, spec in zip(leaves_s, leaves_p):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= AXIS_SIZES[a]
            assert sds.shape[dim] % size == 0, \
                f"shape {sds.shape} dim {dim} not divisible by {axes}"


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible_single_pod(arch, fsdp):
    cfg = get_config(arch).model
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    mesh = FakeMesh()
    specs = param_specs(cfg, mesh, shapes, fsdp=fsdp)
    _check_divisible(shapes, specs, mesh.axis_names)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible_multipod(arch):
    cfg = get_config(arch).model
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    specs = param_specs(cfg, mesh, shapes, fsdp=True)
    _check_divisible(shapes, specs, mesh.axis_names)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    shape = INPUT_SHAPES[shape_name]
    cfg = adapt_model_for_shape(get_config(arch).model, shape)
    model = build_model(cfg)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    mesh = FakeMesh()
    specs = cache_specs(cfg, mesh, cache_shape, shape.global_batch)
    _check_divisible(cache_shape, specs, mesh.axis_names)


def test_model_axis_actually_used():
    """The resolver must shard the big matrices, not replicate everything."""
    cfg = get_config("qwen3-1.7b").model
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    specs = param_specs(cfg, FakeMesh(), shapes)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_sharded = sum(1 for s in flat if any(a is not None for a in s))
    assert n_sharded >= len(flat) * 0.5


def test_long_context_cache_seq_sharded():
    """batch=1 long-context: the KV seq dim carries the edge axes."""
    shape = INPUT_SHAPES["long_500k"]
    cfg = adapt_model_for_shape(get_config("qwen3-1.7b").model, shape)
    model = build_model(cfg)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(1, shape.seq_len))
    specs = cache_specs(cfg, FakeMesh(), cache_shape, 1)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    kv = [s for kp, s in flat if any(
        getattr(k, "key", None) in ("k", "v") for k in kp)]
    assert kv, "no KV cache specs found"
    for spec in kv:
        # stacked: (None, B, S, KV, hd) -> seq dim is index 2
        # (PartitionSpec normalizes singleton tuples to a bare string)
        assert spec[2] in ("data", ("data",)), spec
