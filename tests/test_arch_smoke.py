"""Per-architecture smoke tests (deliverable f).

For each assigned arch: instantiate the REDUCED same-family variant and run
one forward + one train step + one prefill/decode step on CPU, asserting
output shapes and the absence of NaNs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.config import ARCH_IDS, get_smoke_config
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.train import init_train_state, make_train_step

from conftest import assert_finite


def _batch(cfg, b=2, s=32):
    data = SyntheticLMData.for_model(cfg.model, b, s)
    return data.batch(0, 0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg.model)
    params = m.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, aux = m.forward(params, batch["tokens"],
                            batch.get("prefix_emb"))
    mc = cfg.model
    b, s = 2, 32
    n_prefix = mc.num_prefix_embeddings
    if mc.n_codebooks > 1:
        assert logits.shape == (b, mc.n_codebooks, s, mc.vocab_size)
    else:
        assert logits.shape == (b, s + n_prefix, mc.vocab_size)
    assert_finite(logits, f"{arch} logits")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_nothing_nan(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg.model)
    state = init_train_state(m, cfg.train, jax.random.key(0))
    step = jax.jit(make_train_step(m, cfg.train))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert metrics["grad_norm"] > 0.0
    assert_finite(state.params, f"{arch} params after step")
    assert int(state.opt.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg.model)
    params = m.init(jax.random.key(0))
    mc = cfg.model
    b = 2
    cache = m.init_cache(b, 64)
    if mc.n_codebooks > 1:
        tok = jnp.ones((b, mc.n_codebooks, 1), jnp.int32)
    else:
        tok = jnp.ones((b, 1), jnp.int32)
    logits, cache = m.decode_step(params, tok, cache)
    if mc.n_codebooks > 1:
        assert logits.shape == (b, mc.n_codebooks, 1, mc.vocab_size)
    else:
        assert logits.shape == (b, 1, mc.vocab_size)
    assert int(cache["index"]) == 1
    assert_finite(logits, f"{arch} decode logits")


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-370m",
                                  "jamba-1.5-large-398b", "olmoe-1b-7b"])
def test_prefill_decode_matches_forward(arch):
    """Strong consistency: prefill+decode logits == full-forward logits."""
    cfg = get_smoke_config(arch)
    model_cfg = dataclasses.replace(cfg.model, dtype="float32")
    m = build_model(model_cfg)
    params = m.init(jax.random.key(1))
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.key(2), (b, s), 0,
                                model_cfg.vocab_size)
    full_logits, _ = m.forward(params, tokens)
    cache = m.init_cache(b, s + 8)
    _, cache = m.prefill(params, tokens[:, :-1], cache)
    dec_logits, _ = m.decode_step(params, tokens[:, -1:], cache)
    err = jnp.max(jnp.abs(full_logits[:, -1] - dec_logits[:, 0]))
    assert float(err) < 2e-3, f"{arch}: prefill/decode mismatch {err}"


def test_fused_xent_matches_baseline_loss():
    """§Perf optimization: sharded cross-entropy == gather cross-entropy."""
    import dataclasses
    from repro.config import get_smoke_config
    cfg = dataclasses.replace(get_smoke_config("qwen3-1.7b").model,
                              dtype="float32")
    m0 = build_model(cfg)
    m1 = build_model(cfg, fused_xent=True)
    params = m0.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab_size)
    l0, _ = m0.loss(params, {"tokens": toks})
    l1, _ = m1.loss(params, {"tokens": toks})
    assert abs(float(l0) - float(l1)) < 1e-5
    g0 = jax.grad(lambda p: m0.loss(p, {"tokens": toks})[0])(params)
    g1 = jax.grad(lambda p: m1.loss(p, {"tokens": toks})[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_window_slice_decode_matches_masked():
    """§Perf optimization: windowed KV slice decode == masked full-cache."""
    import dataclasses
    from repro.config import get_smoke_config
    cfg = dataclasses.replace(get_smoke_config("qwen3-1.7b").model,
                              dtype="float32", sliding_window=16)
    m0 = build_model(cfg)
    m1 = build_model(cfg, window_slice=True)
    params = m0.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 40), 0, cfg.vocab_size)
    c0, c1 = m0.init_cache(2, 48), m1.init_cache(2, 48)
    _, c0 = m0.prefill(params, toks, c0)
    _, c1 = m1.prefill(params, toks, c1)
    l0, _ = m0.decode_step(params, toks[:, -1:], c0)
    l1, _ = m1.decode_step(params, toks[:, -1:], c1)
    assert float(jnp.max(jnp.abs(l0 - l1))) < 1e-4
