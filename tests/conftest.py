"""Shared pytest fixtures.

NOTE: no XLA_FLAGS manipulation here — smoke tests and benches must see the
single real CPU device.  Multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` themselves (see
``tests/test_dryrun.py``).
"""

import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)


def assert_finite(tree, name="tree"):
    import jax.numpy as jnp
    for i, leaf in enumerate(jax.tree.leaves(tree)):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), \
            f"non-finite values in {name} leaf {i}"
