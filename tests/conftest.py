"""Shared pytest fixtures.

NOTE: no XLA_FLAGS manipulation here — smoke tests and benches must see the
single real CPU device.  Multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` themselves (see
``tests/test_dryrun.py``).
"""

import os
import sys
import types

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Optional-dependency shim: `hypothesis` is dev-only (requirements-dev.txt).
# When it is absent, install a stub whose @given marks the test skipped, so
# every module still collects and the non-property tests run.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
except ImportError:
    def _skip_given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def _passthrough_settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: self
        def __call__(self, *a, **k):
            return self

    _stub = types.ModuleType("hypothesis")
    _stub.given = _skip_given
    _stub.settings = _passthrough_settings
    _stub.strategies = _AnyStrategy()
    _stub.__stub__ = True
    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.__getattr__ = lambda name: _AnyStrategy()
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


@pytest.fixture()
def np_rng():
    return np.random.default_rng(0)


def assert_finite(tree, name="tree"):
    import jax.numpy as jnp
    for i, leaf in enumerate(jax.tree.leaves(tree)):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), \
            f"non-finite values in {name} leaf {i}"
