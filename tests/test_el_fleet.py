"""Multi-tenant EL-as-a-service: cohort bucketing (one compile per
structure), slot waves with mid-flight refill, masked-slot freezing,
priority admission, streamed deltas, shared compile cache, lifecycle —
and the correctness bar: every tenant bit-identical to an independent
``run_sync_ingraph`` / ``run_async_ingraph`` of that tenant alone."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.el import (ELSession, FleetServer, ReportReady, RoundDelta,
                      TenantRun)
from repro.el.sweep.engine import make_cell_batch
from repro.launch.classic import classic_fixture

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def svm():
    return classic_fixture("svm-wafer", samples=128, n_edges=4,
                           alpha=100.0, data_seed=0)


@pytest.fixture(scope="module")
def kmeans():
    return classic_fixture("kmeans-traffic", samples=128, n_edges=4,
                           alpha=100.0, data_seed=0)


def _cfg(fx, mode, budget, ucb_c, seed):
    return dataclasses.replace(
        fx["exp"].ol4el, mode=mode, policy="ol4el", n_edges=4,
        utility=fx["utility"], budget=float(budget), ucb_c=float(ucb_c),
        seed=int(seed))


def _tenant(fx, cfg, **kw):
    return TenantRun(
        cfg=cfg, executor=fx["executor"], metric_name=fx["metric"],
        n_samples=fx["n_samples"] if cfg.mode == "sync" else None,
        init_params=fx["init_params"], **kw)


def _ref(fx, cfg):
    """The independent single run the fleet must reproduce bit-for-bit."""
    s = (ELSession(cfg, metric_name=fx["metric"])
         .with_executor(fx["executor"], init_params=fx["init_params"],
                        n_samples=(fx["n_samples"] if cfg.mode == "sync"
                                   else None)))
    return (s.run_sync_ingraph() if cfg.mode == "sync"
            else s.run_async_ingraph())


def _records_equal(a, b):
    if len(a) != len(b):
        return False
    for ra, rb in zip(a, b):
        for x, y in zip(dataclasses.astuple(ra), dataclasses.astuple(rb)):
            if x != y and not (isinstance(x, float)
                               and np.isnan(x) and np.isnan(y)):
                return False
    return True


def _assert_reports_identical(ref, fleet):
    assert fleet.final_metric == ref.final_metric
    assert fleet.n_aggregations == ref.n_aggregations
    assert fleet.total_consumed == ref.total_consumed
    assert fleet.wall_time == ref.wall_time
    assert fleet.terminated_reason == ref.terminated_reason
    assert fleet.arm_pulls == ref.arm_pulls
    assert _records_equal(fleet.records, ref.records)
    for x, y in zip(jax.tree.leaves(ref.final_params),
                    jax.tree.leaves(fleet.final_params)):
        assert np.array_equal(np.asarray(x), np.asarray(y),
                              equal_nan=True)


def _host_view(tree):
    """Comparable host copy of a carry (PRNG keys via their raw data)."""
    return [np.asarray(jax.random.key_data(x)
                       if jax.dtypes.issubdtype(x.dtype,
                                                jax.dtypes.prng_key)
                       else x)
            for x in jax.tree.leaves(tree)]


# ---------------------------------------------------------------------------
# the steppable cell batch (fleet data plane)
# ---------------------------------------------------------------------------


def test_masked_slot_is_bit_frozen(svm):
    """Satellite bar: an inactive slot runs ZERO iterations per wave —
    bandit state, consumed budget, RNG key and history are byte-frozen,
    and its presence does not perturb the active slots either."""
    from repro.el.ingraph import sync_knobs
    ex = svm["executor"]
    cfg0 = _cfg(svm, "sync", 900.0, 1.0, 0)
    cfg1 = _cfg(svm, "sync", 1200.0, 0.5, 1)
    cb = make_cell_batch(ex.model, ex.edge_data, ex.eval_set, cfg0,
                         n_slots=2, rounds_per_wave=4, lr=ex.lr,
                         batch=ex.batch,
                         n_samples=np.asarray(svm["n_samples"], float),
                         metric_name=svm["metric"], horizon=64)
    rows = [{k: jnp.asarray(v) for k, v in sync_knobs(c).items()}
            for c in (cfg0, cfg1)]
    kst = {k: jnp.stack([rows[0][k], rows[1][k]]) for k in rows[0]}
    init = svm["init_params"]

    def carries():
        c0 = cb.init_slot(init, jax.random.key(cfg0.seed + 17), rows[0])
        c1 = cb.init_slot(init, jax.random.key(cfg1.seed + 17), rows[1])
        return cb.place(cb.broadcast(c0), c1, jnp.int32(1)), c1

    stacked, c1 = carries()
    before = _host_view(c1)
    stacked, running = cb.step(stacked, kst,
                               jnp.asarray([True, False]))
    # slot 1 (masked): bit-frozen — zero body iterations
    after = _host_view(cb.take_slot(stacked, jnp.int32(1)))
    for x, y in zip(before, after):
        assert np.array_equal(x, y, equal_nan=True)
    assert int(np.asarray(stacked["t"])[1]) == 0
    assert not bool(np.asarray(running)[1])
    # slot 0 (active): advanced, budget charged
    assert int(np.asarray(stacked["t"])[0]) == 4
    masked_view = _host_view(cb.take_slot(stacked, jnp.int32(0)))

    # the same wave with BOTH slots live: slot 0's trajectory must not
    # change — active cells are independent of their neighbors' masks
    stacked2, _ = carries()
    stacked2, _ = cb.step(stacked2, kst, jnp.asarray([True, True]))
    both_view = _host_view(cb.take_slot(stacked2, jnp.int32(0)))
    for x, y in zip(masked_view, both_view):
        assert np.array_equal(x, y, equal_nan=True)
    assert int(np.asarray(stacked2["t"])[1]) > 0   # neighbor really ran


# ---------------------------------------------------------------------------
# fleet bit-identity (the correctness bar)
# ---------------------------------------------------------------------------


def _serve(fx, cfgs, n_slots, rounds_per_wave, **server_kw):
    srv = FleetServer(n_slots=n_slots, rounds_per_wave=rounds_per_wave,
                      **server_kw)
    deltas, order = {}, []
    def sub(ev):
        if isinstance(ev, RoundDelta):
            deltas.setdefault(ev.tenant_id, []).append(ev.record)
        else:
            order.append(ev.tenant_id)
    srv.subscribe(sub)
    ids = [srv.submit(_tenant(fx, c)) for c in cfgs]
    reports = srv.drain()
    return srv, ids, reports, deltas, order


def test_sync_fleet_bit_identical_with_refill(svm):
    """3 tenants through 2 slots (forces mid-flight refill), short waves
    (forces multi-wave runs): every report — records, params, pulls —
    equals an independent run_sync_ingraph of that tenant alone, and the
    streamed deltas ARE the report's records."""
    cfgs = [_cfg(svm, "sync", 900.0, 1.0, 0),
            _cfg(svm, "sync", 1500.0, 0.5, 1),
            _cfg(svm, "sync", 600.0, 2.0, 2)]
    srv, ids, reports, deltas, _ = _serve(svm, cfgs, 2, 5)
    st = srv.stats()
    assert st["compiles"] == 1                   # one cohort, one program
    # wave-batched data plane: admits land as ONE place_many scatter per
    # admitting wave, finalizes as ONE take_many gather per finalizing
    # wave — 3 tenants with refill must NOT cost 3 dispatches a side
    assert 1 <= st["place_dispatches"] <= st["waves"]
    assert 1 <= st["gather_dispatches"] <= st["waves"]
    assert st["place_dispatches"] < len(cfgs)    # tenants batched together
    for tid, cfg in zip(ids, cfgs):
        _assert_reports_identical(_ref(svm, cfg), reports[tid])
        assert _records_equal(deltas[tid], reports[tid].records)
        assert reports[tid].n_aggregations > 5   # multi-wave really hit


def test_async_fleet_bit_identical_with_refill(kmeans):
    cfgs = [_cfg(kmeans, "async", 800.0, 1.0, 3),
            _cfg(kmeans, "async", 900.0, 0.7, 4),
            _cfg(kmeans, "async", 700.0, 1.5, 5)]
    srv, ids, reports, deltas, _ = _serve(kmeans, cfgs, 2, 5)
    st = srv.stats()
    assert st["compiles"] == 1                   # one padded horizon
    assert 1 <= st["place_dispatches"] <= st["waves"]
    assert 1 <= st["gather_dispatches"] <= st["waves"]
    for tid, cfg in zip(ids, cfgs):
        _assert_reports_identical(_ref(kmeans, cfg), reports[tid])
        assert _records_equal(deltas[tid], reports[tid].records)
        assert reports[tid].n_aggregations > 5


def test_report_ready_follows_final_delta(svm):
    cfgs = [_cfg(svm, "sync", 900.0, 1.0, 7)]
    srv = FleetServer(n_slots=1, rounds_per_wave=4)
    events = []
    srv.subscribe(events.append)
    tid = srv.submit(_tenant(svm, cfgs[0]))
    srv.drain()
    kinds = [type(e).__name__ for e in events]
    assert kinds[-1] == "ReportReady" and kinds[:-1] == \
        ["RoundDelta"] * (len(events) - 1)
    assert all(e.tenant_id == tid for e in events)


# ---------------------------------------------------------------------------
# cohorts, admission, cache
# ---------------------------------------------------------------------------


def test_cohort_bucketing_one_compile_per_structure(svm, kmeans):
    srv = FleetServer(n_slots=2, rounds_per_wave=8)
    for i in range(3):                      # one sync structure...
        srv.submit(_tenant(svm, _cfg(svm, "sync", 600.0 + 300 * i,
                                     1.0, 10 + i)))
    for i in range(2):                      # ...one async structure
        srv.submit(_tenant(kmeans, _cfg(kmeans, "async", 800.0 + 50 * i,
                                        1.0, 20 + i)))
    reports = srv.drain()
    st = srv.stats()
    assert len(reports) == 5
    assert st["cohorts"] == 2
    assert st["compiles"] == 2              # ONE program per cohort
    assert st["tenants_done"] == 5 and st["tenants_active"] == 0


def test_priority_admission_order(svm):
    """Higher priority admits first through a single slot; ties FIFO."""
    srv = FleetServer(n_slots=1, rounds_per_wave=64)
    order = []
    srv.subscribe(lambda ev: order.append(ev.tenant_id)
                  if isinstance(ev, ReportReady) else None)
    low = srv.submit(_tenant(svm, _cfg(svm, "sync", 600.0, 1.0, 0),
                             priority=0))
    high = srv.submit(_tenant(svm, _cfg(svm, "sync", 600.0, 1.0, 1),
                              priority=5))
    mid = srv.submit(_tenant(svm, _cfg(svm, "sync", 600.0, 1.0, 2),
                             priority=1))
    srv.drain()
    assert order == [high, mid, low]


def test_shared_compile_cache_with_session(svm):
    """FleetServer(cache=session.compile_cache): cohort programs and the
    session's verification runs pool one cache — and a second server on
    the same pool reuses the cohort program without recompiling."""
    cfg = _cfg(svm, "sync", 900.0, 1.0, 3)
    sess = (ELSession(cfg, metric_name=svm["metric"])
            .with_executor(svm["executor"],
                           init_params=svm["init_params"],
                           n_samples=svm["n_samples"]))
    cache = sess.compile_cache
    srv = FleetServer(n_slots=2, rounds_per_wave=8, cache=cache)
    tid = srv.submit(_tenant(svm, cfg))
    fleet_report = srv.drain()[tid]
    assert srv.compiles == 1 and len(cache) == 1
    ref = sess.run_sync_ingraph()            # lands in the SAME pool
    assert len(cache) == 2
    _assert_reports_identical(ref, fleet_report)

    srv2 = FleetServer(n_slots=2, rounds_per_wave=8, cache=cache)
    hits_before = cache.hits
    tid2 = srv2.submit(_tenant(svm, _cfg(svm, "sync", 600.0, 2.0, 9)))
    srv2.drain()
    assert srv2.compiles == 0                # cohort program came cached
    assert cache.hits > hits_before

    srv.close()                              # shared pool NOT cleared
    assert len(cache) == 2


def test_server_close_releases_and_refuses(svm):
    srv = FleetServer(n_slots=2, rounds_per_wave=8)
    tid = srv.submit(_tenant(svm, _cfg(svm, "sync", 600.0, 1.0, 4)))
    srv.drain()
    srv.close()
    assert srv.report(tid) is not None       # delivered reports survive
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(_tenant(svm, _cfg(svm, "sync", 600.0, 1.0, 5)))
    srv.close()                              # idempotent


def test_duplicate_tenant_id_rejected(svm):
    srv = FleetServer(n_slots=2)
    srv.submit(_tenant(svm, _cfg(svm, "sync", 600.0, 1.0, 0),
                       tenant_id="dup"))
    with pytest.raises(ValueError, match="dup"):
        srv.submit(_tenant(svm, _cfg(svm, "sync", 900.0, 1.0, 1),
                           tenant_id="dup"))


# ---------------------------------------------------------------------------
# mesh-sharded fleet (subprocess: forced 4-device host, 2x2 debug mesh)
# ---------------------------------------------------------------------------

_MESH_FLEET_SCRIPT = textwrap.dedent("""
    import dataclasses, sys
    import jax, numpy as np
    assert jax.device_count() == 4, jax.devices()
    from repro.el import ELSession, FleetServer, TenantRun
    from repro.launch.classic import classic_fixture
    from repro.launch.mesh import make_debug_mesh

    mode = sys.argv[1]
    arch = "svm-wafer" if mode == "sync" else "kmeans-traffic"
    fx = classic_fixture(arch, samples=128, n_edges=4, alpha=100.0,
                         data_seed=0)
    cfgs = [dataclasses.replace(
                fx["exp"].ol4el, mode=mode, policy="ol4el", n_edges=4,
                utility=fx["utility"], budget=b, ucb_c=u, seed=s)
            for b, u, s in [(800.0, 1.0, 0), (900.0, 0.5, 1),
                            (700.0, 2.0, 2)]]
    ns = fx["n_samples"] if mode == "sync" else None

    srv = FleetServer(n_slots=2, rounds_per_wave=5,
                      mesh=make_debug_mesh(2, 2))
    ids = [srv.submit(TenantRun(
               cfg=c, executor=fx["executor"], metric_name=fx["metric"],
               n_samples=ns, init_params=fx["init_params"]))
           for c in cfgs]
    reports = srv.drain()

    for tid, c in zip(ids, cfgs):
        s = (ELSession(c, metric_name=fx["metric"])
             .with_executor(fx["executor"],
                            init_params=fx["init_params"], n_samples=ns))
        ref = (s.run_sync_ingraph() if mode == "sync"
               else s.run_async_ingraph())
        r = reports[tid]
        assert r.n_aggregations == ref.n_aggregations > 0
        assert r.total_consumed == ref.total_consumed
        assert r.wall_time == ref.wall_time
        assert r.arm_pulls == ref.arm_pulls
        for a, b in zip(ref.records, r.records):
            ta, tb = dataclasses.astuple(a), dataclasses.astuple(b)
            assert all(x == y or (isinstance(x, float) and np.isnan(x)
                                  and np.isnan(y))
                       for x, y in zip(ta, tb)), (ta, tb)
        for pa, pb in zip(jax.tree.leaves(ref.final_params),
                          jax.tree.leaves(r.final_params)):
            assert np.array_equal(np.asarray(pa), np.asarray(pb))
    print("FLEET-MESH-BIT-IDENTICAL", mode,
          [reports[t].n_aggregations for t in ids])
""")


def _run_mesh_fleet(mode: str):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"))
    return subprocess.run(
        [sys.executable, "-c", _MESH_FLEET_SCRIPT, mode],
        capture_output=True, text=True, env=env, timeout=900)


@pytest.mark.slow
def test_sync_fleet_on_debug_mesh_bit_identical_subprocess():
    r = _run_mesh_fleet("sync")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "FLEET-MESH-BIT-IDENTICAL sync" in r.stdout


@pytest.mark.slow
def test_async_fleet_on_debug_mesh_bit_identical_subprocess():
    r = _run_mesh_fleet("async")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "FLEET-MESH-BIT-IDENTICAL async" in r.stdout
