"""repro.obs.prof — the performance observatory's static half: HLO
collective census parsing, ProgramProfile extraction via AOT lowering,
declarative CollectiveContract checks (census + donation aliasing), and
the profile's journey through the session/fleet wiring into
``ELReport.telemetry["profile"]`` and the ProgramCache."""

import dataclasses
import os
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.el import ELSession, FleetServer, TenantRun
from repro.launch.classic import classic_fixture
from repro.obs import prof as obs_prof

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def svm():
    return classic_fixture("svm-wafer", samples=128, n_edges=4,
                           alpha=100.0, data_seed=0)


def _cfg(fx, mode, budget, seed=0):
    return dataclasses.replace(
        fx["exp"].ol4el, mode=mode, policy="ol4el", n_edges=4,
        utility=fx["utility"], budget=float(budget), seed=seed)


def _session(fx, cfg, init=None):
    return (ELSession(cfg, metric_name=fx["metric"])
            .with_executor(fx["executor"],
                           init_params=(fx["init_params"]
                                        if init is None else init),
                           n_samples=(fx["n_samples"]
                                      if cfg.mode == "sync" else None)))


# -- HLO census parsing -----------------------------------------------------


def test_type_bytes():
    assert obs_prof._type_bytes("f32[4,8]") == 4 * 8 * 4
    assert obs_prof._type_bytes("f32[8]{0}") == 32
    # tuple results sum their elements
    assert obs_prof._type_bytes("(f32[8]{0}, u32[2])") == 32 + 8
    assert obs_prof._type_bytes("pred[]") == 1
    assert obs_prof._type_bytes("token[]") == 0


def test_parse_collectives_synthetic_hlo():
    hlo = textwrap.dedent("""\
        ENTRY %main {
          %ag1 = f32[4,480]{1,0} all-gather(f32[1,480]{1,0} %p), dimensions={0}
          %ag2 = f32[4,480]{1,0} all-gather(f32[1,480]{1,0} %q), dimensions={0}
          %ar = f32[10]{0} all-reduce(f32[10]{0} %r), to_apply=%sum
          %add = f32[10]{0} add(f32[10]{0} %ar, f32[10]{0} %r)
        }
    """)
    census = obs_prof.parse_collectives(hlo)
    assert census["per_op"]["all-gather"]["count"] == 2
    assert census["per_op"]["all-gather"]["bytes"] == 2 * 4 * 480 * 4
    assert census["per_op"]["all-reduce"]["count"] == 1
    assert census["per_op"]["all-reduce"]["bytes"] == 40
    assert census["bytes_per_device"] == 2 * 4 * 480 * 4 + 40


def test_parse_collectives_counts_start_once_skips_done():
    hlo = ("  %s = f32[16]{0} all-gather-start(f32[4]{0} %x)\n"
           "  %d = f32[16]{0} all-gather-done(f32[16]{0} %s)\n")
    census = obs_prof.parse_collectives(hlo)
    # the async -start form is the collective; -done is bookkeeping
    assert census["per_op"]["all-gather"]["count"] == 1
    assert obs_prof.parse_collectives("no collectives here")["per_op"] == {}


# -- ProgramProfile + contracts (pure) --------------------------------------


def _profile(**kw):
    return obs_prof.ProgramProfile(**kw)


def test_profile_census_accessors_and_json():
    p = _profile(collectives={"all-gather": {"count": 2, "bytes": 100}},
                 collective_bytes=100, alias_bytes=0, flops=1e6)
    assert p.collective_count("all-gather") == 2
    assert p.collective_count("all-reduce") == 0
    assert p.total_collectives == 2
    d = p.to_json()
    assert d["collectives"]["all-gather"]["count"] == 2
    assert d["errors"] == []
    assert "all-gather=2" in p.summary()


def test_collective_contract_check_and_enforce():
    p = _profile(collectives={"all-gather": {"count": 2, "bytes": 100}},
                 alias_bytes=0)
    ok = obs_prof.CollectiveContract(
        "ok", counts={"all-gather": 2, "all-reduce": 0}, alias_bytes=0)
    assert ok.check(p) == []
    ok.enforce(p)   # no raise

    rng = obs_prof.CollectiveContract(
        "rng", counts={"all-gather": (1, 16)})
    assert rng.check(p) == []
    bad_rng = obs_prof.CollectiveContract(
        "bad", counts={"all-gather": (3, 16)})
    assert any("outside [3, 16]" in m for m in bad_rng.check(p))

    bad_exact = obs_prof.CollectiveContract(
        "bad", counts={"all-reduce": 1})
    with pytest.raises(obs_prof.ContractViolation, match="all-reduce"):
        bad_exact.enforce(p)

    alias = obs_prof.CollectiveContract("alias", alias_bytes=1920)
    assert any("1920" in m for m in alias.check(p))
    # an unavailable alias analysis is itself a violation
    assert any("unavailable" in m
               for m in alias.check(_profile(alias_bytes=None)))


def test_default_contract_shapes():
    # no mesh: a replicated program may issue NO collectives, alias 0
    c = obs_prof.default_contract()
    assert c.counts == {op: 0 for op in obs_prof.COLLECTIVES}
    assert c.alias_bytes == 0
    assert "replicated" in c.name

    # multi-device mesh: gather-before-reduce (the mesh is only read
    # for .devices, so a 2x2 stand-in exercises the sharded branch)
    mesh = types.SimpleNamespace(devices=np.empty((2, 2), dtype=object))
    c = obs_prof.default_contract(mesh=mesh, mode="sync")
    assert c.counts["all-gather"] == obs_prof.DEFAULT_GATHER_RANGE
    assert c.counts["all-reduce"] == 0
    assert c.counts["reduce-scatter"] == 0
    assert "sync-sharded" in c.name

    # donation: the whole param tree must be aliased
    c = obs_prof.default_contract(mesh=mesh, donated=True,
                                  param_bytes=1920)
    assert c.alias_bytes == 1920 and c.name.endswith("-donated")
    # donated but size unknown: aliasing unconstrained rather than wrong
    assert obs_prof.default_contract(donated=True).alias_bytes is None


def test_param_tree_bytes():
    tree = {"w": jax.ShapeDtypeStruct((4, 59), jnp.float32),
            "b": np.zeros((3,), np.int32)}
    assert obs_prof.param_tree_bytes(tree) == 4 * 59 * 4 + 3 * 4


# -- live extraction (AOT lower/compile on the real backend) ----------------


def test_profile_jit_tiny_fn():
    jfn = jax.jit(lambda x: (x @ x.T).sum())
    prof = obs_prof.profile_jit(jfn, jnp.ones((8, 8), jnp.float32))
    # single-device: census must be empty, nothing aliased
    assert prof.total_collectives == 0
    assert prof.collective_bytes == 0
    assert prof.hlo_lines and prof.hlo_lines > 0
    assert prof.backend == jax.default_backend()
    assert not prof.donated
    if not prof.errors:      # backends may withhold individual analyses
        assert prof.flops is not None and prof.flops > 0
        assert prof.peak_live_bytes == (prof.argument_bytes
                                        + prof.output_bytes
                                        + prof.temp_bytes
                                        - prof.alias_bytes)


# -- session wiring: profiles attach, cache once, contracts gate ------------


def test_session_sync_profile_attaches_and_caches_once(svm):
    s = _session(svm, _cfg(svm, "sync", budget=600.0))
    rep = s.run_sync_ingraph(max_rounds=16, profile=True, contract=True)
    prof = rep.telemetry["profile"]
    assert prof["collectives"] == {}        # 1 device: no collectives
    assert prof["alias_bytes"] == 0         # nothing donated
    assert prof["donated"] is False
    assert s.compile_cache.stats()["profiled"] == 1
    # the second dispatch reuses the stored profile (no re-AOT)
    rep2 = s.run_sync_ingraph(max_rounds=16, profile=True)
    assert rep2.telemetry["profile"] == prof
    assert s.compile_cache.stats()["profiled"] == 1
    # profiling stays opt-in: an unprofiled run carries no profile key
    rep3 = _session(svm, _cfg(svm, "sync", budget=600.0)).run_sync_ingraph(
        max_rounds=16)
    assert "profile" not in (rep3.telemetry or {})


def test_session_async_profile_attaches(svm):
    s = _session(svm, _cfg(svm, "async", budget=600.0))
    rep = s.run_async_ingraph(max_events=32, profile=True, contract=True)
    prof = rep.telemetry["profile"]
    assert prof["collectives"] == {} and prof["alias_bytes"] == 0


def test_session_contract_violation_raises_before_results_leak(svm):
    s = _session(svm, _cfg(svm, "sync", budget=600.0))
    impossible = obs_prof.CollectiveContract(
        "impossible", counts={"all-gather": (5, 99)})
    with pytest.raises(obs_prof.ContractViolation, match="impossible"):
        s.run_sync_ingraph(max_rounds=16, contract=impossible)


def test_session_donated_profile_satisfies_alias_contract(svm):
    init = jax.tree.map(jnp.array, svm["init_params"])   # donatable copy
    s = _session(svm, _cfg(svm, "sync", budget=600.0), init=init)
    rep = s.run_sync_ingraph(max_rounds=16, donate=True, profile=True,
                             contract=True)
    prof = rep.telemetry["profile"]
    assert prof["donated"] is True
    assert prof["alias_bytes"] == obs_prof.param_tree_bytes(
        svm["init_params"])


# -- fleet wiring: cohort profiles land on tenant reports -------------------


def test_fleet_profile_attaches_to_tenant_reports(svm):
    server = FleetServer(n_slots=2, rounds_per_wave=4, profile=True)
    for s, b in enumerate((600.0, 900.0)):
        server.submit(TenantRun(cfg=_cfg(svm, "sync", budget=b, seed=s),
                                executor=svm["executor"],
                                tenant_id=f"t{s}",
                                metric_name=svm["metric"],
                                n_samples=svm["n_samples"],
                                init_params=svm["init_params"],
                                max_rounds=16))
    reports = server.drain()
    server.close()
    assert len(reports) == 2
    for rep in reports.values():
        prof = rep.telemetry["profile"]
        # the cohort step donates its stacked carry
        assert prof["donated"] is True
        assert prof["errors"] == []


# -- 2x2 sharded contract (subprocess: forced 4-device host) ----------------

_SHARDED_CONTRACT_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax, numpy as np
    assert jax.device_count() == 4, jax.devices()
    from repro.config import get_config
    from repro.data import make_wafer_dataset, partition_edges
    from repro.el import ELSession
    from repro.federated import ClassicExecutor
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model
    from repro.obs import prof as obs_prof

    train, test = make_wafer_dataset(n=512, seed=0)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    ol = dataclasses.replace(
        exp.ol4el, mode="sync", policy="ol4el", n_edges=4, budget=600.0,
        heterogeneity=4.0, utility="eval_gain", seed=0)
    edges = partition_edges(train, 4, alpha=1.0, seed=0)
    ex = ClassicExecutor(model, edges, test, batch=32, lr=0.05)
    init = model.init(jax.random.key(0))
    param_bytes = obs_prof.param_tree_bytes(init)

    sess = (ELSession(ol, metric_name="accuracy", lr=0.05)
            .with_executor(ex, init_params=init,
                           n_samples=[len(e["y"]) for e in edges]))
    # contract=True enforces the sync-sharded-donated default contract
    # at dispatch time; a partial-sum reordering or dropped aliasing
    # makes this line raise ContractViolation
    rep = sess.run_sync_ingraph(max_rounds=24, mesh=make_debug_mesh(2, 2),
                                donate=True, profile=True, contract=True)
    prof = rep.telemetry["profile"]
    assert prof["collectives"].get("all-gather", {}).get("count", 0) >= 1, \\
        prof["collectives"]
    for op in ("all-reduce", "reduce-scatter", "all-to-all"):
        assert op not in prof["collectives"], prof["collectives"]
    assert prof["alias_bytes"] == param_bytes, \\
        (prof["alias_bytes"], param_bytes)
    assert prof["collective_bytes"] > 0
    print("CONTRACT-OK", prof["collectives"]["all-gather"]["count"],
          prof["alias_bytes"])
""")


@pytest.mark.slow
def test_sync_sharded_2x2_contract_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"))
    r = subprocess.run([sys.executable, "-c", _SHARDED_CONTRACT_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "CONTRACT-OK" in r.stdout
