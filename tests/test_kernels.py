"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in interpret mode on CPU (TPU is the lowering target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kmeans_assign.ops import assign_with_dist
from repro.kernels.kmeans_assign.ref import assign_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_reference


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, s, h, kv, d, window, dtype)
    (1, 128, 4, 4, 64, 0, jnp.float32),
    (2, 256, 4, 2, 64, 0, jnp.float32),
    (1, 256, 8, 1, 64, 0, jnp.float32),      # MQA
    (1, 128, 4, 4, 128, 0, jnp.float32),
    (1, 128, 2, 2, 256, 0, jnp.float32),     # gemma head_dim
    (2, 256, 4, 2, 64, 128, jnp.float32),    # sliding window
    (1, 256, 4, 4, 64, 64, jnp.float32),     # small window
    (1, 128, 4, 2, 64, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,kv,d,window,dtype", FLASH_CASES)
def test_flash_attention_vs_ref(b, s, h, kv, d, window, dtype):
    ks = jax.random.split(jax.random.key(s + h + d + window), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = flash_attention(q, k, v, True, window, True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grad_routes_through_oracle():
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, 0, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v) ** 2)

    g = jax.grad(f)(q, k, v)
    g_ref = jax.grad(f_ref)(q, k, v)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, s, h, p, n, chunk, dtype)
    (2, 128, 4, 32, 16, 32, jnp.float32),
    (1, 256, 2, 64, 128, 128, jnp.float32),
    (1, 64, 8, 64, 64, 32, jnp.float32),
    (2, 128, 2, 128, 128, 64, jnp.float32),  # jamba head_dim
    (1, 128, 4, 32, 16, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,p,n,chunk,dtype", SSD_CASES)
def test_ssd_vs_ref(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.key(s * h + p), 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(dtype)
    a = -jnp.exp(0.5 * jax.random.normal(ks[2], (h,)))
    da = (dt.astype(jnp.float32) * a).astype(jnp.float32)
    bm = jax.random.normal(ks[3], (b, s, n), dtype)
    cm = jax.random.normal(ks[4], (b, s, n), dtype)
    xs = (x.astype(jnp.float32) * dt[..., None].astype(jnp.float32)
          ).astype(dtype)
    y, state = ssd(xs, da, bm, cm, chunk, True)
    y_ref, state_ref = ssd_reference(xs, da, bm, cm, chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                               atol=tol, rtol=tol)


def test_ssd_state_matches_recurrence():
    """Chunked SSD final state == step-by-step recurrence."""
    from repro.models.mamba2 import ssd_recurrent_step
    b, s, h, p, n = 1, 64, 2, 16, 8
    ks = jax.random.split(jax.random.key(7), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    da = -jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    bm = jax.random.normal(ks[2], (b, s, n))
    cm = jax.random.normal(ks[3], (b, s, n))
    _, state_chunked = ssd_reference(x, da, bm, cm, 16)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y_t, state = ssd_recurrent_step(state, x[:, t], da[:, t], bm[:, t],
                                        cm[:, t])
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(state_chunked),
                               np.asarray(state), atol=1e-4, rtol=1e-4)
    # outputs of the dual form match the recurrence too
    y_chunked, _ = ssd_reference(x, da, bm, cm, 16)
    np.testing.assert_allclose(np.asarray(y_chunked),
                               np.asarray(jnp.stack(ys, axis=1)),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# kmeans assign
# ---------------------------------------------------------------------------

KM_CASES = [
    (100, 8, 3, jnp.float32),
    (1000, 64, 3, jnp.float32),
    (513, 59, 8, jnp.float32),       # wafer dims, non-multiple of block
    (256, 16, 32, jnp.float32),
    (300, 64, 3, jnp.bfloat16),
]


@pytest.mark.parametrize("n,d,k,dtype", KM_CASES)
def test_kmeans_assign_vs_ref(n, d, k, dtype):
    ks = jax.random.split(jax.random.key(n + d + k), 2)
    x = jax.random.normal(ks[0], (n, d), dtype)
    c = jax.random.normal(ks[1], (k, d), dtype)
    a, d2 = assign_with_dist(x, c, interpret=True)
    a_ref, d2_ref = assign_ref(x, c)
    # bf16 rounding can flip genuinely-tied assignments; compare distances
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2_ref),
                               atol=1e-2, rtol=1e-2)
    if dtype == jnp.float32:
        assert (np.asarray(a) == np.asarray(a_ref)).mean() > 0.999
