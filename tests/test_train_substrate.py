"""Optimizer, schedules, checkpointing, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import TrainConfig, get_smoke_config
from repro.data import (SyntheticLMData, make_traffic_dataset,
                        make_wafer_dataset, partition_edges)
from repro.train import (checkpoint, init_opt_state, init_train_state,
                         lr_schedule, make_train_step)
from repro.train import checkpoint as ck
from repro.train.optimizer import apply_updates, clip_by_global_norm


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def test_cosine_schedule_shape():
    tc = TrainConfig(schedule="cosine", warmup_steps=10, total_steps=100,
                     peak_lr=1.0, min_lr_ratio=0.1)
    assert float(lr_schedule(tc, 0)) == pytest.approx(0.1)
    assert float(lr_schedule(tc, 9)) == pytest.approx(1.0)
    assert float(lr_schedule(tc, 99)) == pytest.approx(0.1, abs=1e-2)


def test_wsd_schedule_plateau_and_decay():
    tc = TrainConfig(schedule="wsd", warmup_steps=10, total_steps=100,
                     peak_lr=1.0, min_lr_ratio=0.1, decay_start_frac=0.8)
    plateau = [float(lr_schedule(tc, s)) for s in range(10, 80)]
    assert all(abs(v - 1.0) < 1e-6 for v in plateau)
    assert float(lr_schedule(tc, 99)) < 0.2


@given(step=st.integers(0, 2000))
@settings(max_examples=40, deadline=None)
def test_property_lr_positive_bounded(step):
    for sched in ("cosine", "wsd", "constant"):
        tc = TrainConfig(schedule=sched, warmup_steps=17, total_steps=1000,
                         peak_lr=3e-4)
        lr = float(lr_schedule(tc, step))
        assert 0.0 < lr <= 3e-4 + 1e-9


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_adamw_reduces_quadratic():
    tc = TrainConfig(optimizer="adamw", peak_lr=0.1, schedule="constant",
                     warmup_steps=1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(tc, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = apply_updates(tc, params, grads, opt)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_sgd_momentum_state():
    tc = TrainConfig(optimizer="sgd", momentum=0.9, peak_lr=0.01,
                     schedule="constant", warmup_steps=1, weight_decay=0.0,
                     grad_clip=0.0)
    params = {"w": jnp.ones(3)}
    opt = init_opt_state(tc, params)
    params2, opt2, m = apply_updates(tc, params, {"w": jnp.ones(3)}, opt)
    assert float(params2["w"][0]) < 1.0
    assert int(opt2.step) == 1


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_trainstate(tmp_path):
    cfg = get_smoke_config("olmoe-1b-7b")
    from repro.models import build_model
    m = build_model(cfg.model)
    state = init_train_state(m, cfg.train, jax.random.key(0))
    path = str(tmp_path / "state.npz")
    ck.save(path, state, step=7)
    back = ck.restore(path, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    assert ck.latest_step(path) == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "x.npz")
    ck.save(path, {"a": jnp.zeros((2, 3))})
    with pytest.raises(ValueError):
        ck.restore(path, {"a": jnp.zeros((3, 2))})


def test_checkpoint_missing_leaf_raises(tmp_path):
    path = str(tmp_path / "y.npz")
    ck.save(path, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError):
        ck.restore(path, {"a": jnp.zeros(2), "b": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_lm_data_deterministic_and_edge_distinct():
    d = SyntheticLMData(vocab=128, seq_len=16, batch_size=4)
    b1 = d.batch(0, 5)["tokens"]
    b2 = d.batch(0, 5)["tokens"]
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    b3 = d.batch(1, 5)["tokens"]
    assert not np.array_equal(np.asarray(b1), np.asarray(b3))
    assert int(b1.max()) < 128 and int(b1.min()) >= 0


def test_edge_marginals_differ():
    """Non-IID: different edges have different token marginals."""
    d = SyntheticLMData(vocab=64, seq_len=256, batch_size=8)
    h = []
    for e in range(2):
        toks = np.asarray(d.batch(e, 0)["tokens"]).ravel()
        h.append(np.bincount(toks, minlength=64) / toks.size)
    assert np.abs(h[0] - h[1]).sum() > 0.2


def test_partition_edges_covers_and_noniid():
    train, _ = make_wafer_dataset(n=2000)
    parts = partition_edges(train, 4, alpha=0.3)
    total = sum(len(p["y"]) for p in parts)
    assert total == len(train["y"])
    # non-IID: per-edge class distributions differ
    dists = [np.bincount(p["y"], minlength=8) / max(len(p["y"]), 1)
             for p in parts]
    assert np.abs(dists[0] - dists[1]).sum() > 0.2


def test_classic_datasets_shapes():
    train, test = make_wafer_dataset(n=1000)
    assert train["x"].shape[1] == 59
    assert int(train["y"].max()) == 7
    train, test = make_traffic_dataset(n=1000)
    assert train["x"].shape[1] == 64
    assert int(train["y"].max()) == 2


def test_bf16_optimizer_state_trains():
    """§Perf It.4: bf16 Adam moments — state dtype honored, loss still falls
    (update math stays fp32)."""
    import dataclasses
    from repro.config import get_smoke_config
    from repro.data import SyntheticLMData
    from repro.models import build_model

    cfg = get_smoke_config("qwen3-1.7b")
    tc = dataclasses.replace(cfg.train, opt_state_dtype="bfloat16")
    m = build_model(cfg.model)
    state = init_train_state(m, tc, jax.random.key(0))
    assert jax.tree.leaves(state.opt.mu)[0].dtype == jnp.bfloat16
    data = SyntheticLMData.for_model(cfg.model, 2, 64)
    step = jax.jit(make_train_step(m, tc))
    losses = []
    for i in range(5):
        state, metrics = step(state, data.batch(0, i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)
