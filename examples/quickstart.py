"""Quickstart: build an assigned architecture, train it a few steps, and
decode from it — the public API in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-1.7b]
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.config import get_smoke_config
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.train import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    # 1. resolve an assigned architecture (reduced smoke variant for CPU)
    exp = get_smoke_config(args.arch)
    print(f"arch={exp.model.name} family={exp.model.family} "
          f"params={exp.model.num_params() / 1e6:.1f}M")

    # 2. build + train
    model = build_model(exp.model)
    state = init_train_state(model, exp.train, jax.random.key(0))
    data = SyntheticLMData.for_model(exp.model, batch_size=4, seq_len=64)
    step = jax.jit(make_train_step(model, exp.train))
    for i in range(args.steps):
        state, metrics = step(state, data.batch(0, i))
        print(f"  step {i}: loss={float(metrics['loss']):.4f}")

    # 3. serve: prefill a prompt, decode 8 tokens greedily
    prompt = data.batch(0, 999)["tokens"][:, :16]
    cache = model.init_cache(4, 32)
    logits, cache = model.prefill(state.params, prompt, cache)
    tok = jnp.argmax(logits[..., -1, :], -1)
    out = [tok]
    for _ in range(8):
        inp = (tok.reshape(4, exp.model.n_codebooks, 1)
               if exp.model.n_codebooks > 1 else tok.reshape(4, 1))
        logits, cache = model.decode_step(state.params, inp, cache)
        tok = jnp.argmax(logits[..., -1, :], -1)
        out.append(tok)
    print("decoded ids:", [int(t.reshape(-1)[0]) for t in out])


if __name__ == "__main__":
    main()
