"""Serving example: batched request decoding with KV/SSM caches across
three architecture families (dense GQA, pure SSM, hybrid MoE).

    PYTHONPATH=src python examples/serve_batched.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve


def main():
    for arch in ["qwen3-1.7b", "mamba2-370m", "jamba-1.5-large-398b"]:
        print(f"\n=== {arch} (smoke variant) ===")
        serve.main(["--arch", arch, "--smoke", "--batch", "4",
                    "--prompt-len", "32", "--tokens", "16"])


if __name__ == "__main__":
    main()
