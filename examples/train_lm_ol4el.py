"""End-to-end driver: train a ~100M-parameter LM with the OL4EL
edge-cloud loop — the paper's technique applied to LM pretraining.

Four simulated heterogeneous edges, per-round global-update intervals
chosen by the budget-limited bandit, masked local-SGD rounds with
parameter aggregation, budget accounting, and checkpointing.

    PYTHONPATH=src python examples/train_lm_ol4el.py \
        --preset 100m --rounds 100         # full driver (slow on CPU)
    PYTHONPATH=src python examples/train_lm_ol4el.py \
        --preset 25m --rounds 60           # CPU-friendly evidence run
"""

import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, OL4ELConfig, TrainConfig
from repro.core.coordinator import CloudCoordinator
from repro.data import SyntheticLMData
from repro.federated import init_el_state, make_el_round
from repro.models import build_model
from repro.train import checkpoint

PRESETS = {
    # ~100M params: 12L x 640d, llama-like, 32k vocab
    "100m": ModelConfig(name="lm-100m", vocab_size=32768, d_model=640,
                        n_layers=12, n_heads=10, n_kv_heads=10, d_ff=1720,
                        dtype="float32", remat=False),
    # ~25M: CPU-friendly
    "25m": ModelConfig(name="lm-25m", vocab_size=16384, d_model=384,
                       n_layers=8, n_heads=6, n_kv_heads=6, d_ff=1024,
                       dtype="float32", remat=False),
    # ~5M: smoke
    "5m": ModelConfig(name="lm-5m", vocab_size=4096, d_model=192,
                      n_layers=4, n_heads=4, n_kv_heads=4, d_ff=512,
                      dtype="float32", remat=False),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=list(PRESETS))
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--heterogeneity", type=float, default=4.0)
    ap.add_argument("--budget", type=float, default=50_000.0)
    ap.add_argument("--max-interval", type=int, default=6)
    ap.add_argument("--policy", default="ol4el")
    ap.add_argument("--ckpt", default="results/lm_ol4el.npz")
    args = ap.parse_args()

    mc = PRESETS[args.preset]
    print(f"model={mc.name} params={mc.num_params() / 1e6:.1f}M "
          f"edges={args.edges} H={args.heterogeneity}")
    tc = TrainConfig(optimizer="adamw", peak_lr=3e-4, schedule="cosine",
                     warmup_steps=20, total_steps=args.rounds * 3,
                     global_batch=args.batch, seq_len=args.seq)
    ol = OL4ELConfig(max_interval=args.max_interval, mode="async",
                     policy=args.policy, budget=args.budget,
                     comp_cost=10.0, comm_cost=40.0,
                     heterogeneity=args.heterogeneity, n_edges=args.edges,
                     utility="loss_delta")

    model = build_model(mc)
    coord = CloudCoordinator(ol, args.edges, lr=tc.peak_lr)
    state = init_el_state(model, tc, args.edges, jax.random.key(0))
    data = SyntheticLMData.for_model(mc, args.batch, args.seq)
    el_round = jax.jit(make_el_round(model, tc, h_max=ol.max_interval,
                                     mode="async"))

    step_counter = np.zeros(args.edges, np.int64)
    prev_loss, t_start = None, time.time()
    history = []
    for rnd in range(args.rounds):
        intervals = []
        for e in range(args.edges):
            i = coord.decide(e)
            if i < 0:
                print(f"round {rnd}: budgets exhausted -> stop")
                break
            intervals.append(i)
        if len(intervals) < args.edges:
            break
        batches = {"tokens": jnp.stack([
            jnp.stack([data.batch(e, int(step_counter[e]) + s)["tokens"]
                       for s in range(ol.max_interval)])
            for e in range(args.edges)])}
        state, metrics = el_round(state, batches,
                                  jnp.asarray(intervals, jnp.int32),
                                  jnp.ones(args.edges, jnp.float32))
        loss = float(metrics["mean_loss"])
        for e in range(args.edges):
            step_counter[e] += intervals[e]
            cost = coord.realized_cost(e, intervals[e])
            coord.charge(e, cost)
            u = 0.0 if prev_loss is None else prev_loss - loss
            coord.observe(e, intervals[e], u, cost)
        prev_loss = loss
        history.append((rnd, loss, list(intervals),
                        coord.total_consumed()))
        if rnd % 10 == 0 or rnd == args.rounds - 1:
            print(f"round {rnd:4d} loss={loss:.4f} intervals={intervals} "
                  f"consumed={coord.total_consumed():.0f} "
                  f"({time.time() - t_start:.0f}s)", flush=True)

    checkpoint.save(args.ckpt, state, step=len(history))
    print(f"done: {len(history)} rounds, final loss "
          f"{history[-1][1]:.4f}, checkpoint -> {args.ckpt}")
    # bandit summary
    arms = coord.bandits[0].counts if coord.cfg.mode == "sync" else \
        sum(b.counts for b in coord.bandits)
    print("arm pull counts (interval 1..K):", list(map(int, arms)))


if __name__ == "__main__":
    main()
