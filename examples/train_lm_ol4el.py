"""End-to-end driver: train a ~100M-parameter LM with the OL4EL
edge-cloud loop — the paper's technique applied to LM pretraining.

Simulated heterogeneous edges, per-block global-update intervals chosen
by the budget-limited bandit, local-SGD blocks with staleness-aware
merging, budget accounting, and checkpointing — all through the
``repro.el.ELSession`` façade.

    PYTHONPATH=src python examples/train_lm_ol4el.py \
        --preset 100m --rounds 100         # full driver (slow on CPU)
    PYTHONPATH=src python examples/train_lm_ol4el.py \
        --preset 25m --rounds 60           # CPU-friendly evidence run
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.config import ModelConfig, OL4ELConfig, TrainConfig
from repro.el import ELSession
from repro.federated import LMExecutor
from repro.models import build_model
from repro.train import checkpoint

PRESETS = {
    # ~100M params: 12L x 640d, llama-like, 32k vocab
    "100m": ModelConfig(name="lm-100m", vocab_size=32768, d_model=640,
                        n_layers=12, n_heads=10, n_kv_heads=10, d_ff=1720,
                        dtype="float32", remat=False),
    # ~25M: CPU-friendly
    "25m": ModelConfig(name="lm-25m", vocab_size=16384, d_model=384,
                       n_layers=8, n_heads=6, n_kv_heads=6, d_ff=1024,
                       dtype="float32", remat=False),
    # ~5M: smoke
    "5m": ModelConfig(name="lm-5m", vocab_size=4096, d_model=192,
                      n_layers=4, n_heads=4, n_kv_heads=4, d_ff=512,
                      dtype="float32", remat=False),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=list(PRESETS))
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--heterogeneity", type=float, default=4.0)
    ap.add_argument("--budget", type=float, default=50_000.0)
    ap.add_argument("--max-interval", type=int, default=6)
    ap.add_argument("--policy", default="ol4el")
    ap.add_argument("--ckpt", default="results/lm_ol4el.npz")
    args = ap.parse_args()

    mc = PRESETS[args.preset]
    print(f"model={mc.name} params={mc.num_params() / 1e6:.1f}M "
          f"edges={args.edges} H={args.heterogeneity}")
    tc = TrainConfig(optimizer="adamw", peak_lr=3e-4, schedule="cosine",
                     warmup_steps=20, total_steps=args.rounds * 3,
                     global_batch=args.batch, seq_len=args.seq)
    ol = OL4ELConfig(max_interval=args.max_interval, mode="async",
                     policy=args.policy, budget=args.budget,
                     comp_cost=10.0, comm_cost=40.0,
                     heterogeneity=args.heterogeneity, n_edges=args.edges,
                     utility="loss_delta")

    model = build_model(mc)
    ex = LMExecutor(model, mc, tc, batch=args.batch, seq_len=args.seq)

    t_start = time.time()

    def progress(rec):
        if rec.n_aggregations % 10 == 0:
            print(f"event {rec.n_aggregations:4d} loss={rec.metric:.4f} "
                  f"edge={rec.edge} interval={rec.interval:.0f} "
                  f"consumed={rec.total_consumed:.0f} "
                  f"({time.time() - t_start:.0f}s)", flush=True)

    session = (ELSession(ol, metric_name="loss", lr=tc.peak_lr)
               .with_executor(ex)
               .with_policy(args.policy)
               .on_round(progress))
    report = session.run(max_events=args.rounds * args.edges)

    print(f"done: {report.n_aggregations} aggregations, final loss "
          f"{report.final_metric:.4f}, consumed "
          f"{report.total_consumed:.0f}/{args.edges * args.budget:.0f} "
          f"({report.terminated_reason})")
    print("arm pull counts (interval 1..K):", report.arm_pulls)
    if args.ckpt:
        checkpoint.save(args.ckpt, report.final_params,
                        step=report.n_aggregations)
        print(f"saved checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
