"""The paper's testbed experiment (§V) end to end: heterogeneous edges
collaboratively train an SVM on wafer data under resource budgets,
comparing OL4EL-sync / OL4EL-async / AC-sync / Fixed-I.

    PYTHONPATH=src python examples/el_svm_testbed.py [--heterogeneity 6]
"""

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.config import get_config
from repro.data import make_wafer_dataset, partition_edges
from repro.el import ELSession
from repro.federated import ClassicExecutor
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--heterogeneity", type=float, default=6.0)
    ap.add_argument("--edges", type=int, default=3)
    ap.add_argument("--budget", type=float, default=5000.0)
    ap.add_argument("--samples", type=int, default=8000)
    ap.add_argument("--ingraph", action="store_true",
                    help="run the sync rows through the compiled fast path")
    args = ap.parse_args()

    train, test = make_wafer_dataset(n=args.samples)
    exp = get_config("svm-wafer")
    model = build_model(exp.model)
    edges = partition_edges(train, args.edges, alpha=1.0)
    print(f"edges={args.edges} H={args.heterogeneity} "
          f"budget={args.budget}/edge  "
          f"data={[len(e['y']) for e in edges]}")

    print(f"{'algorithm':16s} {'accuracy':>9s} {'aggregations':>13s} "
          f"{'consumed':>9s}")
    for policy, mode in [("ol4el", "sync"), ("ol4el", "async"),
                         ("ac_sync", "sync"), ("fixed_i", "sync"),
                         ("ucb_bv", "async")]:
        ol = dataclasses.replace(
            exp.ol4el, mode=mode, policy=policy, n_edges=args.edges,
            budget=args.budget, heterogeneity=args.heterogeneity,
            utility="eval_gain",
            cost_model="variable" if policy == "ucb_bv" else "fixed",
            cost_noise=0.2 if policy == "ucb_bv" else 0.0)
        ex = ClassicExecutor(model, edges, test, batch=64, lr=0.05)
        session = (ELSession(ol, metric_name="accuracy", lr=0.05)
                   .with_executor(ex,
                                  init_params=model.init(jax.random.key(0)),
                                  n_samples=[len(e["y"]) for e in edges]))
        use_fastpath = (args.ingraph and mode == "sync"
                        and policy == "ol4el")
        res = session.run_sync_ingraph() if use_fastpath else session.run()
        print(f"{policy + '-' + mode:16s} {res.final_metric:9.4f} "
              f"{res.n_aggregations:13d} {res.total_consumed:9.0f}")


if __name__ == "__main__":
    main()
