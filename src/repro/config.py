"""Configuration system for the repro framework.

Plain dataclasses (no external deps) with:
  * ``ModelConfig``     -- architecture description (unified across dense /
    MoE / SSM / hybrid / multimodal families).
  * ``TrainConfig``     -- optimizer / schedule / batching.
  * ``OL4ELConfig``     -- the paper's scheduler knobs (arms, budgets, costs).
  * ``MeshConfig``      -- logical mesh description used by launch/.
  * ``ExperimentConfig``-- top-level bundle, what ``--arch`` resolves to.

Every assigned architecture lives in ``repro/configs/<id>.py`` exposing a
``get_config()`` that returns an ``ExperimentConfig`` with the exact assigned
dimensions, plus ``get_smoke_config()`` returning the reduced variant used by
CPU smoke tests (<=2 layers, d_model<=512, <=4 experts).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Layer kinds understood by the unified decoder stack.
ATTN = "attn"
MAMBA = "mamba"

# FFN kinds.
DENSE_FFN = "dense"
MOE_FFN = "moe"
NO_FFN = "none"


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (fine-grained, shared+routed)."""

    num_experts: int = 0                 # routed experts
    num_shared_experts: int = 0          # always-on experts (DeepSeekMoE)
    top_k: int = 2
    expert_ffn_dim: int = 0              # d_ff of each routed expert
    shared_ffn_dim: int = 0              # total d_ff of the shared experts
    capacity_factor: float = 1.25        # dispatch capacity multiplier
    router_aux_loss: float = 0.01        # load-balance loss weight
    router_z_loss: float = 1e-3          # router logit z-loss weight
    dispatch: str = "cumsum"             # cumsum (baseline) | sort (§Perf)
    dispatch_groups: int = 0             # >1: group-local routing (§Perf)

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class MambaConfig:
    """Mamba2 / SSD sub-config."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128                # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description."""

    name: str = "model"
    family: str = "dense"                # dense|moe|ssm|hybrid|vlm|audio|classic
    vocab_size: int = 32000
    d_model: int = 512
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 8                  # GQA; == n_heads -> MHA, 1 -> MQA
    d_ff: int = 2048
    head_dim: int = 0                    # 0 -> d_model // n_heads
    max_seq_len: int = 8192
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    qkv_bias: bool = False               # Qwen2.5-style QKV bias
    qk_norm: bool = False                # Qwen3-style per-head q/k RMSNorm
    tie_embeddings: bool = False
    act_fn: str = "silu"                 # silu (SwiGLU) | gelu (GeGLU)
    sliding_window: int = 0              # 0 = full causal attention
    # Layer pattern. Empty -> all layers are ``attn``. Otherwise a pattern of
    # ATTN/MAMBA strings which is tiled across n_layers (Jamba-style).
    layer_pattern: Tuple[str, ...] = ()
    # FFN pattern, tiled like layer_pattern.  Empty -> all DENSE_FFN (or
    # NO_FFN for pure-ssm models with d_ff == 0).
    ffn_pattern: Tuple[str, ...] = ()
    moe: MoEConfig = field(default_factory=MoEConfig)
    mamba: MambaConfig = field(default_factory=MambaConfig)
    # Multimodal stub frontends: number of prefix embedding positions that
    # arrive pre-computed (e.g. SigLIP patches).  0 = pure token model.
    num_prefix_embeddings: int = 0
    # Audio codebooks (MusicGen): >1 means input ids are [B, n_codebooks, S]
    # (summed embeddings) and the LM head predicts n_codebooks streams.
    n_codebooks: int = 1
    # First-k layers replace MoE with a dense FFN (DeepSeekMoE layer 0).
    first_k_dense: int = 0
    dtype: str = "bfloat16"
    remat: bool = True                   # activation checkpoint each layer
    scan_layers: bool = True             # stack params + lax.scan over layers
    source: str = ""                     # provenance citation

    # -- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind list of length n_layers."""
        if not self.layer_pattern:
            return tuple([ATTN] * self.n_layers)
        reps = -(-self.n_layers // len(self.layer_pattern))
        return tuple((self.layer_pattern * reps)[: self.n_layers])

    def ffn_kinds(self) -> Tuple[str, ...]:
        if not self.ffn_pattern:
            base = NO_FFN if self.d_ff == 0 and not self.moe.enabled else (
                MOE_FFN if self.moe.enabled else DENSE_FFN)
            kinds = [base] * self.n_layers
        else:
            reps = -(-self.n_layers // len(self.ffn_pattern))
            kinds = list((self.ffn_pattern * reps)[: self.n_layers])
        for i in range(min(self.first_k_dense, self.n_layers)):
            if kinds[i] == MOE_FFN:
                kinds[i] = DENSE_FFN
        return tuple(kinds)

    def block_pattern(self) -> Tuple[Tuple[str, str], ...]:
        """(layer_kind, ffn_kind) pairs, one per layer."""
        return tuple(zip(self.layer_kinds(), self.ffn_kinds()))

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, V = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = V * d                                    # embeddings
        if not self.tie_embeddings:
            total += d * V * self.n_codebooks            # lm head(s)
        for kind, ffn in self.block_pattern():
            total += d                                    # pre-norm scale
            if kind == ATTN:
                total += d * self.n_heads * hd            # q
                total += 2 * d * self.n_kv_heads * hd     # k, v
                total += self.n_heads * hd * d            # o
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
            else:  # mamba
                di = self.mamba.d_inner(d)
                nh = self.mamba.n_heads(d)
                ds = self.mamba.d_state
                total += d * (2 * di + 2 * ds + nh)       # in_proj (x,z,B,C,dt)
                total += self.mamba.d_conv * (di + 2 * ds)  # conv
                total += nh * 2 + di                      # A_log, D, dt_bias-ish
                total += di * d                           # out_proj
                total += di                               # gated norm
            if ffn != NO_FFN:
                total += d                                # post-norm scale
            if ffn == DENSE_FFN:
                total += 3 * d * self.d_ff                # gate/up/down
            elif ffn == MOE_FFN:
                m = self.moe
                total += d * m.num_experts                # router
                total += m.num_experts * 3 * d * m.expert_ffn_dim
                if m.num_shared_experts:
                    total += 3 * d * m.shared_ffn_dim
        total += d                                        # final norm
        return total

    def num_active_params(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if not self.moe.enabled:
            return self.num_params()
        m = self.moe
        dense_equiv = dataclasses.replace(self, moe=MoEConfig())
        inactive_per_moe_layer = (
            (m.num_experts - m.top_k) * 3 * self.d_model * m.expert_ffn_dim)
        n_moe_layers = sum(1 for _, f in self.block_pattern() if f == MOE_FFN)
        return self.num_params() - n_moe_layers * inactive_per_moe_layer


# ---------------------------------------------------------------------------
# Training / serving / scheduler configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"             # adamw | sgd
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"             # cosine | wsd | constant
    warmup_steps: int = 100
    decay_start_frac: float = 0.8        # WSD: fraction of steps before decay
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    momentum: float = 0.9
    grad_clip: float = 1.0
    opt_state_dtype: str = "float32"     # bf16: halves Adam moment memory
    global_batch: int = 8
    seq_len: int = 512
    seed: int = 0
    z_loss: float = 0.0


@dataclass(frozen=True)
class OL4ELConfig:
    """Scheduler knobs — the paper's §IV parameters."""

    max_interval: int = 10               # arms = intervals {1..max_interval}
    mode: str = "async"                  # sync | async
    cost_model: str = "fixed"            # fixed | variable
    policy: str = "ol4el"                # ol4el | ucb_bv | fixed_i | ac_sync |
                                         # greedy | eps_greedy | uniform
    fixed_interval: int = 4              # for the Fixed-I baseline
    budget: float = 5000.0               # per-edge resource budget (units)
    comp_cost: float = 10.0              # base cost of one local iteration
    comm_cost: float = 50.0              # base cost of one global update
    heterogeneity: float = 1.0           # H = fastest/slowest speed ratio
    cost_noise: float = 0.0              # rel. std for variable-cost mode
    utility: str = "param_delta"         # param_delta | eval_gain | loss_delta
    async_alpha: float = 0.5             # async staleness-mix base rate
    async_batch_k: int = 0               # K-event wave width for the async
                                         # engine; 0 = auto (1 replicated,
                                         # mesh-tuned when sharded)
    ucb_c: float = 2.0                   # exploration constant (sqrt(c ln t / n))
    eps: float = 0.1                     # for eps_greedy ablation
    n_edges: int = 4
    seed: int = 0
    # fleet-dynamics scenario (repro.el.scenarios.ScenarioSpec) — churn /
    # straggler / drift schedules injected into the compiled programs.
    # None (default) builds today's programs bit-for-bit.
    scenario: Optional[Any] = None


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def edge_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def n_edges(self) -> int:
        n = 1
        for ax, s in zip(self.axes, self.shape):
            if ax in ("pod", "data"):
                n *= s
        return n


@dataclass(frozen=True)
class ExperimentConfig:
    model: ModelConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    ol4el: OL4ELConfig = field(default_factory=OL4ELConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    notes: str = ""


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS: Tuple[str, ...] = (
    "mamba2-370m",
    "deepseek-moe-16b",
    "minicpm-2b",
    "qwen2.5-14b",
    "musicgen-medium",
    "jamba-1.5-large-398b",
    "paligemma-3b",
    "deepseek-coder-33b",
    "olmoe-1b-7b",
    "qwen3-1.7b",
)

# Paper-native workloads (selectable just like archs).
CLASSIC_IDS: Tuple[str, ...] = ("svm-wafer", "kmeans-traffic")


def _module_for(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ExperimentConfig:
    """Resolve ``--arch <id>`` to its full ExperimentConfig."""
    if arch not in ARCH_IDS and arch not in CLASSIC_IDS:
        raise KeyError(
            f"unknown arch {arch!r}; known: {ARCH_IDS + CLASSIC_IDS}")
    return importlib.import_module(_module_for(arch)).get_config()


def get_smoke_config(arch: str) -> ExperimentConfig:
    """Reduced variant of the same family for CPU smoke tests."""
    if arch not in ARCH_IDS and arch not in CLASSIC_IDS:
        raise KeyError(
            f"unknown arch {arch!r}; known: {ARCH_IDS + CLASSIC_IDS}")
    return importlib.import_module(_module_for(arch)).get_smoke_config()


def list_archs() -> List[str]:
    return list(ARCH_IDS)
