"""DEPRECATED shim — the event-driven EL simulator is now ``ELSession``.

The host-driven sync/async loops (the paper's §V testbed analogue) moved
to :mod:`repro.el.session`; this module keeps the historical
``ELSimulator`` constructor signature and result types importable so old
call sites keep working::

    from repro.federated import ELSimulator, SimResult   # still fine

    sim = ELSimulator(executor, cfg, init_params, ...)
    result = sim.run()        # delegates to ELSession.run()

New code should use::

    from repro.el import ELSession
    report = ELSession(cfg).with_executor(executor, ...).run()

Behavioural fix carried by the move (previously a bug here): in
``variable`` cost mode the async loop used to schedule a block's finish
time from one ``realized_cost`` draw but charge a *second* independent
draw when the block completed, so charged budget disagreed with simulated
wall-clock.  The session engine draws once per block and reuses it for
both.
"""

from __future__ import annotations

import warnings
from typing import Any, Optional

import numpy as np

from repro.config import OL4ELConfig
from repro.el.report import ELReport, RoundRecord

Params = Any

# Legacy names: SimResult was the pre-ELReport result dataclass with the
# same fields; RoundRecord moved unchanged.
SimResult = ELReport

__all__ = ["ELSimulator", "SimResult", "RoundRecord"]


class ELSimulator:
    """Deprecated adapter over :class:`repro.el.ELSession`."""

    def __init__(self, executor, cfg: OL4ELConfig,
                 init_params: Params,
                 n_samples: Optional[np.ndarray] = None,
                 metric_name: str = "accuracy",
                 lr: float = 0.1,
                 async_alpha: Optional[float] = None):
        warnings.warn(
            "ELSimulator is deprecated; use repro.el.ELSession",
            DeprecationWarning, stacklevel=2)
        from repro.el.session import ELSession
        self.cfg = cfg
        self.ex = executor
        self.session = ELSession(
            cfg, metric_name=metric_name, lr=lr, async_alpha=async_alpha
        ).with_executor(executor, init_params=init_params,
                        n_samples=n_samples)

    @property
    def coord(self):
        # eager like the old simulator: the coordinator (budgets, costs,
        # bandits) is inspectable/adjustable before run(), and the next
        # run consumes exactly this instance
        return self.session.coordinator()

    def run_sync(self, max_rounds: int = 10_000,
                 eval_every: int = 1) -> SimResult:
        return self.session.run_sync(max_rounds=max_rounds,
                                     eval_every=eval_every)

    def run_async(self, max_events: Optional[int] = None,
                  eval_every: int = 1) -> SimResult:
        # None derives the event horizon from budget/cost (no silent
        # truncation), matching ELSession.run_async
        return self.session.run_async(max_events=max_events,
                                      eval_every=eval_every)

    def run(self, **kw) -> SimResult:
        return self.session.run(**kw)
