"""Event-driven edge-cloud learning simulator (§V testbed/docker analogue).

Reproduces the paper's experimental harness: N heterogeneous edge servers
with per-edge resource budgets train a shared model under a coordination
strategy.  Supports

  * synchronous rounds (cloud waits for all edges; wall-clock advances by
    the slowest edge — the straggler effect the paper studies), and
  * asynchronous event-driven execution (per-edge completion events; the
    cloud merges one edge at a time with staleness-discounted mixing).

Costs are metered exactly like the paper's simulator: integer-ish time
units per local iteration (scaled per-edge by the heterogeneity factor) and
per global update, optionally with i.i.d. noise (variable-cost mode).

The simulator drives any executor exposing
    ``local_train(params, edge, n_iters, rng) -> (params, info)``
    ``evaluate(params) -> {metric_name: value}``
so the same harness runs SVM, K-means and (small) LMs.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.config import OL4ELConfig
from repro.core.coordinator import CloudCoordinator
from repro.core.utility import UtilityEstimator, param_l2_delta
from repro.federated.aggregation import (staleness_alpha, staleness_mix,
                                         weighted_average)

Params = Any


@dataclasses.dataclass
class RoundRecord:
    wall_time: float
    total_consumed: float
    metric: float
    utility: float
    interval: float            # mean interval this event/round
    edge: int                  # -1 for sync rounds
    n_aggregations: int


@dataclasses.dataclass
class SimResult:
    records: List[RoundRecord]
    final_metric: float
    n_aggregations: int
    total_consumed: float
    wall_time: float
    terminated_reason: str

    def metric_at_consumption(self, budget_frac: float,
                              total_budget: float) -> float:
        """Metric achieved by the time a consumption level is reached."""
        target = budget_frac * total_budget
        best = 0.0
        for r in self.records:
            if r.total_consumed <= target:
                best = r.metric
        return best


class ELSimulator:
    def __init__(self, executor, cfg: OL4ELConfig,
                 init_params: Params,
                 n_samples: Optional[np.ndarray] = None,
                 metric_name: str = "accuracy",
                 lr: float = 0.1,
                 async_alpha: float = 0.5):
        self.ex = executor
        self.cfg = cfg
        self.coord = CloudCoordinator(cfg, cfg.n_edges, lr=lr)
        self.global_params = init_params
        self.metric_name = metric_name
        self.n_samples = (np.ones(cfg.n_edges) if n_samples is None
                          else np.asarray(n_samples, np.float64))
        self.utility = UtilityEstimator(cfg.utility)
        self.async_alpha = async_alpha
        self.rng = np.random.default_rng(cfg.seed + 17)

    # -- shared helpers -------------------------------------------------------

    def _snapshot(self, params: Params, want_metric: bool) -> Dict[str, Any]:
        snap: Dict[str, Any] = {"params": params}
        if want_metric or self.utility.kind == "eval_gain":
            m = self.ex.evaluate(params)
            snap["metric"] = m[self.metric_name]
        else:
            snap["metric"] = float("nan")
        snap["loss"] = snap.get("loss", 0.0)
        return snap

    # -- synchronous ----------------------------------------------------------

    def run_sync(self, max_rounds: int = 10_000,
                 eval_every: int = 1) -> SimResult:
        cfg = self.cfg
        records: List[RoundRecord] = []
        wall = 0.0
        n_agg = 0
        prev = self._snapshot(self.global_params, want_metric=True)
        reason = "max_rounds"
        for rnd in range(max_rounds):
            interval = self.coord.decide()
            if interval < 0 or self.coord.all_exhausted():
                reason = "budget_exhausted"
                break
            edge_params: List[Params] = []
            round_costs = np.zeros(cfg.n_edges)
            for e in range(cfg.n_edges):
                p_e, _ = self.ex.local_train(
                    self.global_params, e, interval,
                    self.rng.integers(1 << 31))
                edge_params.append(p_e)
                round_costs[e] = self.coord.realized_cost(e, interval)
            # Time-budget semantics (paper §V.A: budget = remaining battery/
            # service time): synchronous edges BLOCK on the slowest edge, so
            # every edge's clock — and therefore its budget — advances by
            # the straggler's round time.  This is the straggler penalty
            # async avoids.
            slot = float(round_costs.max())
            for e in range(cfg.n_edges):
                self.coord.charge(e, slot)
            wall += slot
            self.global_params = weighted_average(edge_params,
                                                  self.n_samples)
            n_agg += 1
            new = self._snapshot(self.global_params,
                                 want_metric=(n_agg % eval_every == 0))
            u = self.utility(prev, new)
            # sync: ONE bandit fed the worst-case (binding) cost
            self.coord.observe(0, interval, u, float(round_costs.max()))
            if self.ac_update_needed():
                self._update_ac(edge_params, prev["params"], interval)
            prev = new
            records.append(RoundRecord(
                wall, self.coord.total_consumed(), new["metric"], u,
                interval, -1, n_agg))
        return self._result(records, reason)

    # -- asynchronous -----------------------------------------------------------

    def run_async(self, max_events: int = 50_000,
                  eval_every: int = 1) -> SimResult:
        cfg = self.cfg
        records: List[RoundRecord] = []
        n_agg = 0
        prev = self._snapshot(self.global_params, want_metric=True)
        # per-edge in-flight state: (finish_time, edge, interval, params_at_fetch)
        heap: List[Tuple[float, int, int]] = []
        fetch_version = np.zeros(cfg.n_edges)     # global version when fetched
        version = 0
        edge_params: List[Params] = [self.global_params] * cfg.n_edges
        active = np.ones(cfg.n_edges, bool)
        for e in range(cfg.n_edges):
            i = self.coord.decide(e)
            if i < 0:
                active[e] = False
                continue
            cost = self.coord.realized_cost(e, i)
            heapq.heappush(heap, (cost, e, i))
            fetch_version[e] = version
        wall = 0.0
        reason = "max_events"
        for _ in range(max_events):
            if not heap:
                reason = "budget_exhausted"
                break
            wall, e, interval = heapq.heappop(heap)
            # edge e finishes `interval` local iterations and uploads
            p_e, _ = self.ex.local_train(edge_params[e], e, interval,
                                         self.rng.integers(1 << 31))
            cost = self.coord.realized_cost(e, interval)
            self.coord.charge(e, cost)
            # staleness in *epochs*: with E concurrent contributors the
            # expected raw staleness is ~E versions, so normalize by E —
            # otherwise the mixing rate vanishes as the fleet grows and
            # scaling with edge count (paper Fig. 5) is destroyed.
            staleness = (version - fetch_version[e]) / max(cfg.n_edges, 1)
            alpha = staleness_alpha(self.async_alpha, staleness)
            self.global_params = staleness_mix(self.global_params, p_e,
                                               alpha)
            version += 1
            n_agg += 1
            new = self._snapshot(self.global_params,
                                 want_metric=(n_agg % eval_every == 0))
            u = self.utility(prev, new)
            self.coord.observe(e, interval, u, cost)
            prev = new
            records.append(RoundRecord(
                wall, self.coord.total_consumed(), new["metric"], u,
                float(interval), e, n_agg))
            # edge fetches the fresh global model, schedules its next block
            edge_params[e] = self.global_params
            fetch_version[e] = version
            nxt = self.coord.decide(e)
            if nxt > 0 and not self.coord.exhausted(e):
                next_cost = self.coord.expected_cost(e, nxt)
                heapq.heappush(heap, (wall + next_cost, e, nxt))
            else:
                active[e] = False
        return self._result(records, reason)

    def run(self, **kw) -> SimResult:
        if self.cfg.mode == "sync":
            return self.run_sync(**kw)
        return self.run_async(**kw)

    # -- AC-sync estimator plumbing ----------------------------------------------

    def ac_update_needed(self) -> bool:
        return self.coord.ac is not None

    def _update_ac(self, edge_params: List[Params], prev_global: Params,
                   tau: int) -> None:
        local_deltas = np.array([param_l2_delta(prev_global, p)
                                 for p in edge_params])
        global_delta = param_l2_delta(prev_global, self.global_params)
        self.coord.ac.update_estimates(local_deltas, global_delta, tau)

    # -- results ----------------------------------------------------------------

    def _result(self, records: List[RoundRecord], reason: str) -> SimResult:
        final = self.ex.evaluate(self.global_params)[self.metric_name]
        return SimResult(
            records=records,
            final_metric=float(final),
            n_aggregations=len(records),
            total_consumed=self.coord.total_consumed(),
            wall_time=records[-1].wall_time if records else 0.0,
            terminated_reason=reason,
        )
