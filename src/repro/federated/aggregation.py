"""Model aggregation primitives (cloud-side global updates)."""

from __future__ import annotations

from typing import Any, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def weighted_average(params_list: Sequence[Params],
                     weights: Sequence[float]) -> Params:
    """Synchronous global update: weighted average of edge models."""
    w = np.asarray(weights, np.float64)
    w = w / w.sum()

    def avg(*leaves):
        acc = sum(wi * leaf.astype(jnp.float32)
                  for wi, leaf in zip(w, leaves))
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *params_list)


def staleness_mix(global_params: Params, edge_params: Params,
                  alpha: float) -> Params:
    """Asynchronous global update: G <- (1-a) G + a theta_e, with a the
    staleness-discounted mixing rate."""
    a = float(alpha)

    def mix(g, e):
        out = (1.0 - a) * g.astype(jnp.float32) + a * e.astype(jnp.float32)
        return out.astype(g.dtype)

    return jax.tree.map(mix, global_params, edge_params)


def staleness_alpha(base: float, staleness: float) -> float:
    """Polynomial staleness discount  a = base / (1 + s)."""
    return base / (1.0 + max(staleness, 0.0))
