"""Executors: the ML data-plane the EL runtime drives.

Both satisfy the typed ``repro.el.EdgeExecutor`` Protocol (structurally —
``local_train`` / ``evaluate`` / ``init_params``).

``ClassicExecutor`` — SVM / K-means local training on per-edge (non-IID)
datasets, jitted per interval length via lax.scan over stacked minibatches.
It also satisfies ``repro.el.InGraphExecutor`` (raw per-edge arrays + a
jittable model), which is what lets ``ELSession.run_sync_ingraph`` stage
a whole run into one XLA program.

``LMExecutor`` — small language models through the same interface (params
only; per-edge optimizer moments are ephemeral within a local block, the
standard local-SGD simplification).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.data.classic_data import minibatches
from repro.data.pipeline import SyntheticLMData
from repro.train.optimizer import init_opt_state
from repro.train.state import TrainState, make_train_step

Params = Any


class ClassicExecutor:
    """SVM / K-means on per-edge datasets."""

    def __init__(self, model, edge_data: List[Dict[str, np.ndarray]],
                 eval_set: Dict[str, np.ndarray], batch: int = 64,
                 lr: float = 0.05):
        self.model = model
        self.edge_data = edge_data
        self.eval_set = {k: jnp.asarray(v) for k, v in eval_set.items()}
        self.batch = batch
        self.lr = lr

        def scan_steps(params: Params, xs: jax.Array, ys: jax.Array
                       ) -> Params:
            def body(p, xy):
                x, y = xy
                p, _ = self.model.local_step(p, {"x": x, "y": y}, self.lr)
                return p, None
            params, _ = jax.lax.scan(body, params, (xs, ys))
            return params

        self._scan_steps = jax.jit(scan_steps)

    def init_params(self, seed: int = 0) -> Params:
        return self.model.init(jax.random.key(seed))

    def sample_batches(self, edge: int, n_iters: int, seed: int
                       ) -> Tuple[jax.Array, jax.Array]:
        data = self.edge_data[edge]
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, len(data["y"]), size=(n_iters, self.batch))
        return jnp.asarray(data["x"][idx]), jnp.asarray(data["y"][idx])

    def local_train(self, params: Params, edge: int, n_iters: int,
                    seed: int) -> Tuple[Params, Dict]:
        xs, ys = self.sample_batches(edge, n_iters, seed)
        return self._scan_steps(params, xs, ys), {}

    def evaluate(self, params: Params) -> Dict[str, float]:
        return self.model.evaluate(params, self.eval_set)


class LMExecutor:
    """Small LMs under the same EL interface (loss-based metric)."""

    def __init__(self, model, model_cfg: ModelConfig, train_cfg: TrainConfig,
                 batch: int = 4, seq_len: int = 64, seed: int = 0):
        self.model = model
        self.train_cfg = train_cfg
        self.data = SyntheticLMData.for_model(model_cfg, batch, seq_len,
                                              seed=seed)
        train_step = make_train_step(model, train_cfg)

        def scan_steps(state: TrainState, edge: jax.Array, start: jax.Array,
                       n_iters: jax.Array, h_max: int) -> TrainState:
            def body(s, i):
                b = self.data.batch(edge, start + i)
                s2, _ = train_step(s, b)
                s = jax.tree.map(
                    lambda a, bb: jnp.where(i < n_iters, bb, a), s, s2)
                return s, None
            state, _ = jax.lax.scan(body, state, jnp.arange(h_max))
            return state

        self._scan = {}
        self._scan_fn = scan_steps
        self._step_counter = np.zeros(64, np.int64)
        self._eval_batch = self.data.batch(999, 0)

        def eval_loss(params):
            _, m = model.loss(params, self._eval_batch)
            return m["ce_loss"]

        self._eval = jax.jit(eval_loss)

    def init_params(self, seed: int = 0) -> Params:
        return self.model.init(jax.random.key(seed))

    def local_train(self, params: Params, edge: int, n_iters: int,
                    seed: int) -> Tuple[Params, Dict]:
        h_max = int(n_iters)
        if h_max not in self._scan:
            self._scan[h_max] = jax.jit(
                partial(self._scan_fn, h_max=h_max))
        state = TrainState(params, init_opt_state(self.train_cfg, params))
        start = int(self._step_counter[edge])
        self._step_counter[edge] += h_max
        state = self._scan[h_max](state, jnp.asarray(edge),
                                  jnp.asarray(start), jnp.asarray(n_iters))
        return state.params, {}

    def evaluate(self, params: Params) -> Dict[str, float]:
        loss = float(self._eval(params))
        return {"loss": loss, "neg_loss": -loss}
