from repro.federated.aggregation import (staleness_alpha, staleness_mix,
                                         weighted_average)
from repro.federated.executors import ClassicExecutor, LMExecutor
from repro.federated.local_sgd import (ELMeshState, el_state_specs,
                                       init_el_state, make_el_round)
from repro.federated.simulator import ELSimulator, SimResult

__all__ = [
    "weighted_average", "staleness_mix", "staleness_alpha",
    "ClassicExecutor", "LMExecutor", "ELSimulator", "SimResult",
    "ELMeshState", "init_el_state", "make_el_round", "el_state_specs",
]
