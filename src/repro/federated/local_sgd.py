"""The OL4EL data plane on a TPU mesh: masked local-SGD rounds.

TPU-native adaptation of the paper's protocol (DESIGN.md §2): every edge
server is one slice of the (`pod`,`data`) mesh axes holding a full model
replica sharded over `model`.  Model/optimizer state carries a leading
edge dimension sharded over the edge axes, so

  * a *local iteration* touches only `model`-axis collectives, and
  * a *global aggregation* is a single parameter mean over the edge dim —
    one all-reduce over (`pod`,`data`), exactly the collective the OL4EL
    bandit meters.

``el_round`` executes one coordination round for all edges at once:
``lax.scan`` over ``h_max`` potential local steps with per-edge masking
(edge *i* applies updates only while ``step < intervals[i]``), then a
participation-weighted parameter aggregation.  The per-edge intervals come
from the host-side CloudCoordinator between rounds (cloud = control plane,
mesh = data plane).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, TrainConfig
from repro.sharding import edge_axes, param_specs
from repro.train.optimizer import init_opt_state
from repro.train.state import TrainState, make_train_step

Params = Any


class ELMeshState(NamedTuple):
    """Per-edge replicated training state: every leaf has a leading edge
    dim (sharded over the pod/data axes)."""
    params: Params
    opt: Any


def init_el_state(model, train_cfg: TrainConfig, n_edges: int,
                  rng: jax.Array) -> ELMeshState:
    rngs = jax.random.split(rng, n_edges)

    def one(r):
        p = model.init(r)
        return ELMeshState(p, init_opt_state(train_cfg, p))

    return jax.vmap(one)(rngs)


def make_el_round(model, train_cfg: TrainConfig, h_max: int,
                  mode: str = "sync"):
    """Build the jittable round function.

    el_round(state, batches, intervals, weights) with
      state:     ELMeshState, leading edge dim E on every leaf
      batches:   pytree; tokens [E, h_max, B_e, S]
      intervals: [E] int32 (1..h_max), from the cloud bandit
      weights:   [E] f32 aggregation weights (sync: data sizes;
                 async emulation: staleness discounts)
    Returns (new_state, metrics).
    """
    train_step = make_train_step(model, train_cfg)

    def per_edge(state_e: TrainState, batches_e, interval_e):
        def body(carry, xs):
            i, batch = xs
            state = carry
            new_state, metrics = train_step(state, batch)
            take = i < interval_e
            state = jax.tree.map(
                lambda a, b: jnp.where(take, b, a), state, new_state)
            return state, jnp.where(take, metrics["loss"], 0.0)

        state_e, losses = lax.scan(
            body, state_e, (jnp.arange(h_max), batches_e))
        mean_loss = losses.sum() / jnp.maximum(interval_e, 1)
        return state_e, mean_loss

    def el_round(state: ELMeshState, batches, intervals: jax.Array,
                 weights: jax.Array
                 ) -> Tuple[ELMeshState, Dict[str, jax.Array]]:
        edge_states = TrainState(state.params, state.opt)
        new_states, losses = jax.vmap(per_edge)(edge_states, batches,
                                                intervals)
        w = (weights / jnp.sum(weights)).astype(jnp.float32)
        # global update: one parameter all-reduce over the edge axes
        agg = jax.tree.map(
            lambda p: jnp.einsum("e...,e->...", p.astype(jnp.float32), w)
            .astype(p.dtype),
            new_states.params)
        n_edges = intervals.shape[0]
        if mode == "sync":
            # every edge restarts from the fresh global model
            new_params = jax.tree.map(
                lambda a: jnp.repeat(a[None], n_edges, axis=0), agg)
        else:
            # async emulation: edges blend toward the global model with
            # interval-dependent (staleness) rates
            alpha = (1.0 / (1.0 + (intervals - 1).astype(jnp.float32)))

            def blend(pe, g):
                a = alpha.reshape((-1,) + (1,) * (pe.ndim - 1))
                out = (pe.astype(jnp.float32) * (1.0 - a)
                       + g.astype(jnp.float32)[None] * a)
                return out.astype(pe.dtype)

            new_params = jax.tree.map(blend, new_states.params, agg)
        metrics = {
            "mean_loss": jnp.sum(losses * w),
            "mean_interval": jnp.mean(intervals.astype(jnp.float32)),
        }
        return ELMeshState(new_params, new_states.opt), metrics

    return el_round


def make_el_program(model, train_cfg: TrainConfig, n_edges: int,
                    h_max: int, n_rounds: int, data_fn,
                    comp_costs, comm_costs, mode: str = "async",
                    ucb_c: float = 2.0):
    """Beyond-paper: the ENTIRE OL4EL loop as one jittable program.

    The paper (and our host coordinator) round-trips to the cloud between
    rounds; on a TPU pod that host sync costs ~ms per round.  Here arm
    selection (in-graph bandit), the masked local-SGD round, budget
    accounting and bandit updates all live inside one ``lax.scan`` — the
    whole collaboration compiles to a single pjit program.

    data_fn(edge_ids [E], round_idx, step_idx [h_max]) -> batch pytree with
    leading dims [E, h_max, ...]; must be jax-pure (the synthetic pipeline
    is).  Returns ``program(el_state, bandit_states, budgets, rng)`` ->
    (el_state, bandit_states, budgets, history).
    """
    from repro.core.bandit import jax_bandit_update, jax_select_arm

    el_round = make_el_round(model, train_cfg, h_max, mode=mode)
    comp = jnp.asarray(comp_costs, jnp.float32)        # [E]
    comm = jnp.asarray(comm_costs, jnp.float32)        # [E]
    arms_cost = (jnp.arange(1, h_max + 1, dtype=jnp.float32)[None, :]
                 * comp[:, None] + comm[:, None])      # [E, K]

    def program(el_state: ELMeshState, bandit_states, budgets: jax.Array,
                rng: jax.Array):
        def round_body(carry, rnd_idx):
            el_state, bstates, budgets, rng, prev_loss = carry
            rng, sub = jax.random.split(rng)
            sel_rngs = jax.random.split(sub, n_edges)
            arms = jax.vmap(
                lambda r, s, b, c: jax_select_arm(r, s, b, c, ucb_c))(
                sel_rngs, bstates, budgets, arms_cost)          # [E]
            active = arms >= 0
            intervals = jnp.where(active, arms + 1, 1)
            if mode == "sync":
                # one shared decision: the first active edge's arm
                first = jnp.argmax(active)
                intervals = jnp.full((n_edges,), intervals[first])
                active = jnp.broadcast_to(active[first], (n_edges,))
            batches = data_fn(jnp.arange(n_edges), rnd_idx,
                              jnp.arange(h_max))
            weights = active.astype(jnp.float32)
            safe_w = jnp.where(jnp.any(active), weights,
                               jnp.ones_like(weights))
            new_state, metrics = el_round(el_state, batches, intervals,
                                          safe_w)
            any_active = jnp.any(active)
            el_state = jax.tree.map(
                lambda old, new: jnp.where(any_active, new, old),
                el_state, new_state)
            loss = metrics["mean_loss"]
            utility = jnp.where(jnp.isfinite(prev_loss),
                                prev_loss - loss, 0.0)
            cost_e = (intervals.astype(jnp.float32) * comp + comm)
            budgets = budgets - jnp.where(active, cost_e, 0.0)
            bstates = jax.vmap(jax_bandit_update)(
                bstates, arms, jnp.full((n_edges,), utility), cost_e)
            carry = (el_state, bstates, budgets, rng, loss)
            return carry, {"loss": loss, "intervals": intervals,
                           "active": active, "budgets": budgets}

        init = (el_state, bandit_states, budgets, rng,
                jnp.asarray(jnp.inf, jnp.float32))
        (el_state, bandit_states, budgets, _, _), hist = lax.scan(
            round_body, init, jnp.arange(n_rounds))
        return el_state, bandit_states, budgets, hist

    return program


def el_state_specs(model_cfg: ModelConfig, mesh: Mesh,
                   state_shape: ELMeshState) -> ELMeshState:
    """PartitionSpecs: leading edge dim over (pod,data); params sharded by
    the per-arch resolver; optimizer moments mirror the params."""
    ea = edge_axes(mesh)

    def strip_lead(shape_tree):
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), shape_tree)

    p_specs = param_specs(model_cfg, mesh, strip_lead(state_shape.params))
    p_specs = jax.tree.map(lambda s: P(ea, *s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))

    # optimizer moments mirror the param specs (ZeRO-style: fully sharded
    # like their params); the step counter replicates.  SGD-without-momentum
    # keeps scalar nu placeholders -> replicated.
    mu_specs = p_specs
    nu_shape = state_shape.opt.nu
    same_struct = (jax.tree_util.tree_structure(nu_shape)
                   == jax.tree_util.tree_structure(state_shape.params))
    p_leaf_shapes = [x.shape for x in jax.tree.leaves(state_shape.params)]
    nu_leaf_shapes = [x.shape for x in jax.tree.leaves(nu_shape)]
    if same_struct and p_leaf_shapes == nu_leaf_shapes:
        nu_specs = p_specs
    else:   # stacked scalar placeholders [E]: shard the edge dim only
        nu_specs = jax.tree.map(
            lambda x: P(ea, *([None] * (x.ndim - 1))) if x.ndim else P(),
            nu_shape)
    opt_specs = type(state_shape.opt)(step=P(), mu=mu_specs, nu=nu_specs)
    return ELMeshState(params=p_specs, opt=opt_specs)
