"""Public K-means assignment op (forward-only; the E-step has no grad)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import interpret_default
from repro.kernels.kmeans_assign.kernel import assign_fwd


def assign_with_dist(x: jax.Array, centers: jax.Array,
                     block_n: int = 256,
                     interpret: Optional[bool] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    interp = interpret_default() if interpret is None else interpret
    n = x.shape[0]
    bn = min(block_n, max(n, 1))
    pad = (-n) % bn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    a, d2 = assign_fwd(x, centers, block_n=bn, interpret=interp)
    return a[:n], d2[:n]


def assign(x: jax.Array, centers: jax.Array,
           interpret: Optional[bool] = None) -> jax.Array:
    return assign_with_dist(x, centers, interpret=interpret)[0]
