from repro.kernels.kmeans_assign import ops, ref
from repro.kernels.kmeans_assign.ops import assign

__all__ = ["assign", "ops", "ref"]
