"""K-means E-step (assignment) — Pallas TPU kernel.

The paper's K-means workload spends its FLOPs in the E-step: pairwise
squared distances point x centroid + argmin.  Tiling: grid over point
blocks (bn = 256 rows); the full centroid tile [K, D] stays resident in
VMEM across the grid (K <= a few hundred for the paper's K=3..64 range).
Distances use the matmul expansion ||x||^2 - 2 x.c + ||c||^2 so the inner
product runs on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, out_ref, dist_ref):
    x = x_ref[...].astype(jnp.float32)               # [bn, D]
    c = c_ref[...].astype(jnp.float32)               # [K, D]
    xc = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [bn, K]
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)      # [bn, 1]
    c2 = jnp.sum(c * c, axis=-1)[None, :]            # [1, K]
    d2 = x2 - 2.0 * xc + c2                          # [bn, K]
    out_ref[...] = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=-1)


def assign_fwd(x: jax.Array, centers: jax.Array, block_n: int = 256,
               interpret: bool = False):
    """x: [N, D]; centers: [K, D] -> (assignments [N] i32, min_d2 [N] f32).

    N is padded to a block multiple by the ops wrapper.
    """
    n, d = x.shape
    k = centers.shape[0]
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        functools.partial(_assign_kernel),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),   # centroids resident
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x, centers)
