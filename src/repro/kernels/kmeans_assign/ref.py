"""Pure-jnp oracle for the K-means assignment kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def assign_ref(x: jax.Array, centers: jax.Array):
    """x: [N, D]; centers: [K, D] -> (assignments [N], min_d2 [N])."""
    x32 = x.astype(jnp.float32)
    c32 = centers.astype(jnp.float32)
    d2 = (jnp.sum(x32 ** 2, -1, keepdims=True)
          - 2.0 * x32 @ c32.T
          + jnp.sum(c32 ** 2, -1)[None, :])
    return jnp.argmin(d2, -1).astype(jnp.int32), jnp.min(d2, -1)
