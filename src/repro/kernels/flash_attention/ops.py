"""Public flash-attention op: Pallas forward, oracle-gradient backward.

The backward pass is not the bottleneck this repo optimizes (the dry-run
and serving paths are forward-only), so grads route through the jnp oracle
via ``jax.custom_vjp`` — a standard arrangement that keeps training
correct while the forward uses the TPU kernel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import interpret_default
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    interpret: Optional[bool] = None):
    interp = interpret_default() if interpret is None else interpret
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=interp)


def _fwd(q, k, v, causal, window, interpret):
    out = flash_attention(q, k, v, causal, window, interpret)
    return out, (q, k, v)


def _bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
