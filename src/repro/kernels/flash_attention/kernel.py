"""Causal GQA flash attention — Pallas TPU kernel.

Tiling (per DESIGN.md §5): the grid is (batch, q_heads, Sq/bq, Sk/bk) with
the KV axis innermost — TPU executes the last grid axis sequentially per
core, so fp32 online-softmax accumulators (m, l, acc) live in VMEM scratch
and carry across KV blocks.  Per step the kernel holds one Q block
[bq, D], one K/V block [bk, D] in VMEM and runs two MXU matmuls
([bq,D]x[D,bk] and [bq,bk]x[bk,D]); bq=bk=128 keeps every matmul dim a
multiple of the 128-lane MXU width for head_dim in {64,128,256}.

GQA is expressed in the K/V index_map (query head h reads kv head
h // group) — no KV replication in HBM or VMEM.  The sliding-window mask
reuses the causal-mask path with a lower bound.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, bq: int, bk: int, n_kv_blocks: int,
                 window: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :]                       # [bq, D]
    k = k_ref[0, :, 0, :]                       # [bk, D]
    v = v_ref[0, :, 0, :]                       # [bk, D]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # [bq, bk]

    q_pos = iq * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                         # [bq]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)             # rescale factor
    p = jnp.exp(s - m_cur[:, None])             # [bq, bk]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_cur

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: [B, S, H, D]; k, v: [B, S, KV, D] -> [B, S, H, D]."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_q, n_kv = s // bq, s // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel, scale=scale, bq=bq, bk=bk, n_kv_blocks=n_kv,
        window=window, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h_, iq, ik, group=group:
                         (b_, ik, h_ // group, 0)),
            pl.BlockSpec((1, bk, 1, d),
                         lambda b_, h_, iq, ik, group=group:
                         (b_, ik, h_ // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d),
                               lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running denom l
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
