"""Pure-jnp oracle for flash attention (GQA, causal, sliding window)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: [B, S, H, D]; k, v: [B, S, KV, D] -> [B, S, H, D]."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.asarray(d, jnp.float32))
    pos = jnp.arange(s)
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= pos[None, :] <= pos[:, None]
    if window > 0:
        ok &= pos[None, :] > pos[:, None] - window
    logits = jnp.where(ok, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, d)
