"""Mamba-2 SSD (state-space duality) — Pallas TPU kernel.

Chunked dual form: the grid is (batch, heads, S/chunk) with the chunk axis
innermost/sequential, carrying the running SSM state [P, N] in fp32 VMEM
scratch across chunks (the inter-chunk recurrence).  Per chunk the kernel
does the intra-chunk dense work on the MXU:

    G     = C_blk @ B_blk^T                    [L, L]   (MXU)
    Ydiag = (G * decay) @ X_blk                [L, P]   (MXU)
    Yoff  = (exp(a_cs) * (C_blk @ state^T))    [L, P]   (MXU)
    state = exp(a_last) * state + X^T @ (B_blk * decay_states)   (MXU)

with L = chunk length (128 — MXU-aligned), P = head_dim, N = d_state.
VMEM per step: X [L,P] + B/C [L,N] + state [P,N] + [L,L] temporaries —
~300 KB at (L=128, P=64, N=128), comfortably inside the ~16 MB VMEM.

B/C are shared across heads (ngroups=1): their index_map ignores the head
grid coordinate, so the same VMEM block is reused across the head axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # [L, P]
    da = da_ref[0, :, 0].astype(jnp.float32)         # [L]
    b = b_ref[0].astype(jnp.float32)                 # [L, N]
    c = c_ref[0].astype(jnp.float32)                 # [L, N]

    a_cs = jnp.cumsum(da)                            # [L]
    # intra-chunk decay matrix: exp(a_cs[i] - a_cs[j]) for i >= j
    seg = a_cs[:, None] - a_cs[None, :]              # [L, L]
    il = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jl = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(jl <= il, jnp.exp(seg), 0.0)   # [L, L]

    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, L]
    y_diag = jax.lax.dot_general(g * decay, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # off-diagonal: contribution of the carried state
    state = state_ref[...]                           # [P, N]
    c_state = jax.lax.dot_general(c, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_off = jnp.exp(a_cs)[:, None] * c_state         # [L, P]

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: state' = exp(a_last) * state + X^T (B * decay_states)
    a_last = a_cs[-1]
    decay_states = jnp.exp(a_last - a_cs)            # [L]
    bw = b * decay_states[:, None]                   # [L, N]
    upd = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, N]
    state_ref[...] = jnp.exp(a_last) * state + upd

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...]


def ssd_fwd(x: jax.Array, da: jax.Array, b_mat: jax.Array, c_mat: jax.Array,
            chunk: int, interpret: bool = False):
    """x: [B,S,H,P] (pre-scaled by dt); da: [B,S,H]; b/c: [B,S,N].

    Returns (y [B,S,H,P], final_state [B,H,P,N] fp32).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(bsz, h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda b_, h_, ic: (b_, ic, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, ic: (b_, ic, h_)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ic: (b_, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ic: (b_, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda b_, h_, ic: (b_, ic, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, da, b_mat, c_mat)
    return y, final_state
