from repro.kernels.ssd_scan import ops, ref
from repro.kernels.ssd_scan.ops import ssd

__all__ = ["ssd", "ops", "ref"]
