"""Public SSD op: Pallas forward, oracle-gradient backward."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels import interpret_default
from repro.kernels.ssd_scan.kernel import ssd_fwd
from repro.kernels.ssd_scan.ref import ssd_reference


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ssd(x, da, b_mat, c_mat, chunk: int,
        interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    interp = interpret_default() if interpret is None else interpret
    return ssd_fwd(x, da, b_mat, c_mat, chunk, interpret=interp)


def _fwd(x, da, b_mat, c_mat, chunk, interpret):
    out = ssd(x, da, b_mat, c_mat, chunk, interpret)
    return out, (x, da, b_mat, c_mat)


def _bwd(chunk, interpret, res, g):
    x, da, b_mat, c_mat = res
    _, vjp = jax.vjp(
        lambda x_, da_, b_, c_: ssd_reference(x_, da_, b_, c_, chunk),
        x, da, b_mat, c_mat)
    return vjp(g)


ssd.defvjp(_fwd, _bwd)
