"""Oracle for the SSD kernel — re-exports the model-layer chunked
reference (single source of truth for SSD semantics)."""

from repro.models.mamba2 import segsum, ssd_reference

__all__ = ["ssd_reference", "segsum"]
