"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three files:
  kernel.py -- ``pl.pallas_call`` body with explicit BlockSpec VMEM tiling
               (TPU is the target; CPU validation runs interpret=True),
  ops.py    -- the jit'd public wrapper (custom_vjp where training needs
               gradients; backward routes through the jnp oracle),
  ref.py    -- the pure-jnp oracle used by the allclose test sweeps.

Kernels:
  flash_attention -- causal GQA flash attention w/ sliding window
  ssd_scan        -- Mamba-2 chunked SSD (intra-chunk MXU matmuls,
                     sequential inter-chunk state carry)
  kmeans_assign   -- K-means E-step (the paper's own workload hot spot)
"""


def interpret_default() -> bool:
    """Pallas kernels execute natively only on TPU; elsewhere interpret."""
    import jax
    return jax.default_backend() != "tpu"
