"""paligemma-3b [vlm] — SigLIP + gemma decoder [arXiv:2407.07726].

18L d_model=2048 8H (GQA kv=1 == MQA) d_ff=16384 vocab=257216.
Gemma-style: head_dim=256, GeGLU MLP.  The SigLIP vision tower + projector
is a stub — ``input_specs()`` provides 256 precomputed patch embeddings per
image which are prepended to the text tokens (assignment carve-out).
"""

from repro.config import ModelConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="paligemma-3b",
        family="vlm",
        vocab_size=257216,
        d_model=2048,
        n_layers=18,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,                  # gemma: head_dim != d_model/n_heads
        d_ff=16384,
        act_fn="gelu",
        tie_embeddings=True,           # gemma ties embeddings
        num_prefix_embeddings=256,     # SigLIP 224px -> 256 patches
        max_seq_len=8192,
        source="arXiv:2407.07726 (PaliGemma)",
    )
    return experiment(model, notes="vision frontend stubbed per assignment")


def get_smoke_config():
    return smoke_experiment(get_config())
