"""minicpm-2b [dense] — WSD schedule, llama-like arch [arXiv:2404.06395].

40L d_model=2304 36H (GQA kv=36 == MHA) d_ff=5760 vocab=122753.
"""

import dataclasses

from repro.config import ModelConfig, TrainConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="minicpm-2b",
        family="dense",
        vocab_size=122753,
        d_model=2304,
        n_layers=40,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        tie_embeddings=True,           # MiniCPM ties input/output embeddings
        max_seq_len=32768,
        source="arXiv:2404.06395 (MiniCPM)",
    )
    # The paper's signature Warmup-Stable-Decay schedule.
    train = TrainConfig(schedule="wsd", decay_start_frac=0.9,
                        warmup_steps=100)
    return experiment(model, train=train,
                      notes="WSD schedule exercised by train substrate")


def get_smoke_config():
    return smoke_experiment(get_config())
