"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Repeating 8-layer block with one attention layer (position 4), MoE on every
other layer (odd positions) — the Jamba block design.
"""

from repro.config import (ATTN, DENSE_FFN, MAMBA, MOE_FFN, MambaConfig,
                          MoEConfig, ModelConfig)
from repro.configs._base import experiment, smoke_experiment


def get_config():
    # Jamba block: [m, m, m, m, a, m, m, m]; FFN alternates dense / MoE.
    layer_pattern = (MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA)
    ffn_pattern = (DENSE_FFN, MOE_FFN) * 4
    model = ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        vocab_size=65536,
        d_model=8192,
        n_layers=72,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        layer_pattern=layer_pattern,
        ffn_pattern=ffn_pattern,
        moe=MoEConfig(
            num_experts=16,
            top_k=2,
            expert_ffn_dim=24576,
            capacity_factor=1.25,
            router_aux_loss=0.01,
        ),
        mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=128,
                          chunk_size=128),
        max_seq_len=524288,
        source="arXiv:2403.19887 (Jamba) / Jamba-1.5 model card",
    )
    return experiment(
        model,
        notes="hybrid: 9 attn layers of 72; long_500k native (SSM majority, "
              "attention KV sharded over edge axes)")


def get_smoke_config():
    # Keep the hybrid character: one mamba + one attn layer, MoE on layer 1.
    cfg = get_config()
    return smoke_experiment(
        cfg,
        layer_pattern=(MAMBA, ATTN),
        ffn_pattern=(DENSE_FFN, MOE_FFN),
        n_layers=2,
    )
