"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024, attention-free, d_ff=0, vocab=50280, ssm_state=128.
Mamba2-370m uses expand=2 (d_inner=2048), head_dim=64 -> 32 SSD heads.
"""

from repro.config import MAMBA, MambaConfig, ModelConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="mamba2-370m",
        family="ssm",
        vocab_size=50280,
        d_model=1024,
        n_layers=48,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,                       # attn-free, no separate FFN block
        layer_pattern=(MAMBA,),
        mamba=MambaConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                          chunk_size=128),
        tie_embeddings=True,          # GPT-NeoX tokenizer family ties embs
        max_seq_len=524288,           # SSM: unbounded context, state is O(1)
        source="arXiv:2405.21060 (Transformers are SSMs: SSD / Mamba-2)",
    )
    return experiment(model, notes="pure-SSM arch; long_500k runs natively")


def get_smoke_config():
    return smoke_experiment(get_config())
