"""kmeans-traffic [classic] — the paper's own unsupervised workload (§V.A).

K-means (K=3) over features of 20,000 traffic surveillance images.
``family="classic"``: d_model = feature dim, vocab_size = K clusters.
The paper does not state the feature dimension; we use 64-d image features
(recorded as an assumption in DESIGN.md §7).
"""

from repro.config import ModelConfig, OL4ELConfig, TrainConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="kmeans-traffic",
        family="classic",
        d_model=64,                    # feature dimension (assumed)
        vocab_size=3,                  # K = 3 clusters (paper)
        n_layers=1,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        dtype="float32",
        scan_layers=False,
        remat=False,
        source="OL4EL paper §V.A (YouTube Live traffic images, K=3)",
    )
    train = TrainConfig(optimizer="sgd", peak_lr=1.0, schedule="constant",
                        global_batch=256, total_steps=500, weight_decay=0.0,
                        grad_clip=0.0)
    ol4el = OL4ELConfig(budget=5000.0, comp_cost=10.0, comm_cost=50.0,
                        max_interval=10, utility="param_delta")
    return experiment(model, train=train, ol4el=ol4el,
                      notes="paper-native unsupervised task")


def get_smoke_config():
    return smoke_experiment(get_config(), d_model=16, vocab_size=3,
                            n_layers=1, n_heads=0, n_kv_heads=0, d_ff=0)
