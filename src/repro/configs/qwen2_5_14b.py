"""qwen2.5-14b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B family].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""

from repro.config import ModelConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        vocab_size=152064,
        d_model=5120,
        n_layers=48,
        n_heads=40,
        n_kv_heads=8,
        d_ff=13824,
        qkv_bias=True,                 # Qwen2.5 uses attention QKV bias
        rope_theta=1000000.0,
        max_seq_len=131072,
        source="hf:Qwen/Qwen2.5-0.5B model card (family config, 14B scale)",
    )
    return experiment(model)


def get_smoke_config():
    return smoke_experiment(get_config())
