"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16 == MHA) expert d_ff=1024 vocab=50304,
MoE 64e top-8, no shared experts, every layer MoE.
"""

from repro.config import MoEConfig, ModelConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        vocab_size=50304,
        d_model=2048,
        n_layers=16,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        qk_norm=True,                  # OLMoE uses QK-norm
        moe=MoEConfig(
            num_experts=64,
            top_k=8,
            expert_ffn_dim=1024,
            capacity_factor=1.25,
            router_aux_loss=0.01,
        ),
        max_seq_len=4096,
        source="arXiv:2409.02060 (OLMoE)",
    )
    return experiment(model)


def get_smoke_config():
    return smoke_experiment(get_config())
