"""deepseek-coder-33b [dense] — llama-arch [arXiv:2401.14196].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""

from repro.config import ModelConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        vocab_size=32256,
        d_model=7168,
        n_layers=62,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        rope_theta=100000.0,
        max_seq_len=16384,
        source="arXiv:2401.14196 (DeepSeek-Coder)",
    )
    return experiment(model)


def get_smoke_config():
    return smoke_experiment(get_config())
