"""svm-wafer [classic] — the paper's own supervised workload (§V.A).

Multiclass (one-vs-rest) linear SVM over 59-dimensional wafer-image
features, 8 classes, 20,000 samples.  ``family="classic"`` models reuse
ModelConfig fields: d_model = feature dim, vocab_size = number of classes.
"""

from repro.config import ModelConfig, OL4ELConfig, TrainConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="svm-wafer",
        family="classic",
        d_model=59,                    # feature dimension (paper: 59)
        vocab_size=8,                  # classes (paper: 8)
        n_layers=1,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        dtype="float32",
        scan_layers=False,
        remat=False,
        source="OL4EL paper §V.A (wafer images, smart manufacturing)",
    )
    train = TrainConfig(optimizer="sgd", peak_lr=0.05, schedule="constant",
                        global_batch=64, total_steps=2000, weight_decay=1e-4,
                        grad_clip=0.0)
    ol4el = OL4ELConfig(budget=5000.0, comp_cost=10.0, comm_cost=50.0,
                        max_interval=10, utility="eval_gain")
    return experiment(model, train=train, ol4el=ol4el,
                      notes="paper-native supervised task")


def get_smoke_config():
    return smoke_experiment(get_config(), d_model=59, vocab_size=8,
                            n_layers=1, n_heads=0, n_kv_heads=0, d_ff=0)
