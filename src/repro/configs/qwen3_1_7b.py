"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, head_dim=128.
"""

from repro.config import ModelConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        vocab_size=151936,
        d_model=2048,
        n_layers=28,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,                  # Qwen3 fixes head_dim at 128
        d_ff=6144,
        qk_norm=True,                  # Qwen3 per-head q/k RMSNorm
        tie_embeddings=True,
        rope_theta=1000000.0,
        max_seq_len=32768,
        source="hf:Qwen/Qwen3-8B model card (family config, 1.7B scale)",
    )
    return experiment(model)


def get_smoke_config():
    return smoke_experiment(get_config())
