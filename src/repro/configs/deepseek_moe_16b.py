"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066].

28L d_model=2048 16H (GQA kv=16 == MHA) expert d_ff=1408 vocab=102400,
MoE 64e top-6.  Layer 0 uses a dense FFN (DeepSeekMoE design).
"""

from repro.config import MoEConfig, ModelConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        vocab_size=102400,
        d_model=2048,
        n_layers=28,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,                     # assigned d_ff (fine-grained expert dim)
        moe=MoEConfig(
            num_experts=64,
            num_shared_experts=2,
            top_k=6,
            expert_ffn_dim=1408,
            shared_ffn_dim=2 * 1408,   # 2 shared experts of the same grain
            capacity_factor=1.25,
            router_aux_loss=0.01,
        ),
        first_k_dense=1,               # first layer dense (paper design)
        max_seq_len=32768,
        source="arXiv:2401.06066 (DeepSeekMoE)",
    )
    return experiment(model, notes="expert-parallel: 64 experts / 16 chips")


def get_smoke_config():
    return smoke_experiment(get_config())
