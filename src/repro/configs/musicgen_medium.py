"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284].

48L d_model=1536 24H (GQA kv=24 == MHA) d_ff=6144 vocab=2048.
4 EnCodec codebooks: input ids [B, 4, S] (embeddings summed), 4 LM heads.
The conv/EnCodec frontend is a stub — ``input_specs()`` provides token ids
directly (the backbone is the deliverable per the assignment carve-out).
"""

from repro.config import ModelConfig
from repro.configs._base import experiment, smoke_experiment


def get_config():
    model = ModelConfig(
        name="musicgen-medium",
        family="audio",
        vocab_size=2048,
        d_model=1536,
        n_layers=48,
        n_heads=24,
        n_kv_heads=24,
        d_ff=6144,
        n_codebooks=4,
        act_fn="gelu",
        max_seq_len=32768,
        source="arXiv:2306.05284 (MusicGen)",
    )
    return experiment(model, notes="audio backbone; EnCodec frontend stubbed")


def get_smoke_config():
    return smoke_experiment(get_config())
