"""Per-architecture configuration files (one per assigned arch).

Each module exposes ``get_config()`` (exact assigned dimensions) and
``get_smoke_config()`` (reduced same-family variant: <=2 layers,
d_model<=512, <=4 experts) per the assignment contract.
"""
