"""Shared helpers for arch config files."""

from __future__ import annotations

import dataclasses

from repro.config import (ExperimentConfig, MeshConfig, ModelConfig,
                          MoEConfig, OL4ELConfig, TrainConfig)


def experiment(model: ModelConfig, *, train: TrainConfig | None = None,
               ol4el: OL4ELConfig | None = None,
               notes: str = "") -> ExperimentConfig:
    return ExperimentConfig(
        model=model,
        train=train or TrainConfig(),
        ol4el=ol4el or OL4ELConfig(),
        mesh=MeshConfig(),
        notes=notes,
    )


def reduce_for_smoke(model: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a full config to the CPU smoke-test contract.

    Same family / same flags, but: 2 layers, d_model<=512, <=4 experts,
    small vocab and short context so a forward+train step runs in seconds.
    """
    moe = model.moe
    if moe.enabled:
        moe = dataclasses.replace(
            moe,
            num_experts=min(moe.num_experts, 4),
            num_shared_experts=min(moe.num_shared_experts, 1),
            top_k=min(moe.top_k, 2),
            expert_ffn_dim=min(moe.expert_ffn_dim or 128, 128),
            shared_ffn_dim=min(moe.shared_ffn_dim or 128, 128),
        )
    d_model = min(model.d_model, 256)
    n_heads = min(model.n_heads, 4)
    n_kv = min(model.n_kv_heads, n_heads)
    if model.n_kv_heads == 1:
        n_kv = 1
    mamba = dataclasses.replace(
        model.mamba, head_dim=32, d_state=16, chunk_size=32)
    defaults = dict(
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=(64 if model.head_dim else 0),
        d_ff=min(model.d_ff, 512),
        vocab_size=min(model.vocab_size, 512),
        max_seq_len=256,
        moe=moe,
        mamba=mamba,
        num_prefix_embeddings=min(model.num_prefix_embeddings, 8),
        first_k_dense=min(model.first_k_dense, 1),
        sliding_window=min(model.sliding_window, 64) if model.sliding_window
        else 0,
        scan_layers=True,
        remat=False,
        name=model.name + "-smoke",
    )
    defaults.update(overrides)
    return dataclasses.replace(model, **defaults)


def smoke_experiment(full: ExperimentConfig, **overrides) -> ExperimentConfig:
    model = reduce_for_smoke(full.model, **overrides)
    train = dataclasses.replace(
        full.train, global_batch=2, seq_len=64, total_steps=4,
        warmup_steps=1)
    ol4el = dataclasses.replace(full.ol4el, n_edges=2, budget=500.0)
    return ExperimentConfig(model=model, train=train, ol4el=ol4el,
                            mesh=MeshConfig(shape=(1, 1)), notes=full.notes)
