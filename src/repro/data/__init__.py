from repro.data.pipeline import SyntheticLMData, lm_batch
from repro.data.classic_data import (make_traffic_dataset, make_wafer_dataset,
                                     partition_edges)

__all__ = ["SyntheticLMData", "lm_batch", "make_wafer_dataset",
           "make_traffic_dataset", "partition_edges"]
