"""Synthetic stand-ins for the paper's datasets (not public).

* Wafer (SVM): 20,000 samples, 59-dim features, 8 classes — anisotropic
  Gaussian class clusters with partial overlap so linear-SVM accuracy
  saturates below 100% (matching the paper's accuracy curves' shape).
* Traffic (K-means): 20,000 samples, 64-dim image-feature-like mixture with
  K=3 unequal clusters.

``partition_edges`` produces the non-IID per-edge splits (Dirichlet over
class proportions), the standard way to emulate heterogeneous silo data.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


def make_wafer_dataset(n: int = 20000, d: int = 59, n_classes: int = 8,
                       seed: int = 0, test_frac: float = 0.2
                       ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    means = rng.normal(0.0, 0.55, size=(n_classes, d))
    # shared anisotropy so classes overlap in some directions
    basis = rng.normal(0.0, 1.0, size=(d, d))
    scales = np.exp(rng.normal(0.0, 0.4, size=d))
    y = rng.integers(0, n_classes, size=n)
    x = means[y] + rng.normal(0.0, 1.0, size=(n, d)) * scales
    x = x @ (basis / np.sqrt(d))
    x = (x - x.mean(0)) / (x.std(0) + 1e-6)
    n_test = int(n * test_frac)
    idx = rng.permutation(n)
    tr, te = idx[n_test:], idx[:n_test]
    return ({"x": x[tr].astype(np.float32), "y": y[tr].astype(np.int32)},
            {"x": x[te].astype(np.float32), "y": y[te].astype(np.int32)})


def make_traffic_dataset(n: int = 20000, d: int = 64, k: int = 3,
                         seed: int = 0, test_frac: float = 0.2
                         ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed + 1)
    weights = np.array([0.5, 0.3, 0.2])[:k]
    weights = weights / weights.sum()
    means = rng.normal(0.0, 0.35, size=(k, d))
    y = rng.choice(k, size=n, p=weights)
    x = means[y] + rng.normal(0.0, 1.0, size=(n, d))
    n_test = int(n * test_frac)
    idx = rng.permutation(n)
    tr, te = idx[n_test:], idx[:n_test]
    return ({"x": x[tr].astype(np.float32), "y": y[tr].astype(np.int32)},
            {"x": x[te].astype(np.float32), "y": y[te].astype(np.int32)})


def partition_edges(data: Dict[str, np.ndarray], n_edges: int,
                    alpha: float = 1.0, seed: int = 0
                    ) -> List[Dict[str, np.ndarray]]:
    """Dirichlet non-IID split of (x, y) across edge servers."""
    rng = np.random.default_rng(seed + 2)
    y = data["y"]
    n_classes = int(y.max()) + 1
    edge_indices: List[List[int]] = [[] for _ in range(n_edges)]
    for cls in range(n_classes):
        cls_idx = np.where(y == cls)[0]
        rng.shuffle(cls_idx)
        props = rng.dirichlet([alpha] * n_edges)
        cuts = (np.cumsum(props) * len(cls_idx)).astype(int)[:-1]
        for e, part in enumerate(np.split(cls_idx, cuts)):
            edge_indices[e].extend(part.tolist())
    out = []
    for e in range(n_edges):
        idx = np.asarray(edge_indices[e], dtype=np.int64)
        rng.shuffle(idx)
        if len(idx) == 0:                        # never leave an edge empty
            idx = rng.integers(0, len(y), size=8)
        out.append({k: v[idx] for k, v in data.items()})
    return out


def minibatches(rng: np.random.Generator, data: Dict[str, np.ndarray],
                batch: int):
    """Infinite minibatch iterator (with replacement)."""
    n = len(data["y"])
    while True:
        idx = rng.integers(0, n, size=batch)
        yield {k: v[idx] for k, v in data.items()}
