"""Deterministic synthetic LM data pipeline.

Token streams are generated per (edge, step) from counter-based PRNG keys,
so every edge server sees a reproducible, *statistically distinct* stream —
the non-IID setting the paper's EL problem assumes.  Each edge draws tokens
from a Zipf distribution over a per-edge permutation of the vocab: the
marginal distributions differ across edges while global statistics match.

All generation is jax-jittable (used inside training loops) with a numpy
mirror for host-side tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


def _zipf_logits(vocab: int, alpha: float = 1.2) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def lm_batch(rng: jax.Array, batch: int, seq_len: int, vocab: int,
             edge_id: int | jax.Array = 0, alpha: float = 1.2,
             n_codebooks: int = 1) -> jax.Array:
    """Sample a token batch for one edge. Shape [B, S] or [B, CB, S]."""
    logits = _zipf_logits(vocab, alpha)
    perm_rng = jax.random.fold_in(jax.random.key(1234), edge_id)
    perm = jax.random.permutation(perm_rng, vocab)
    shape = ((batch, seq_len) if n_codebooks == 1
             else (batch, n_codebooks, seq_len))
    draws = jax.random.categorical(rng, logits, shape=shape)
    return perm[draws].astype(jnp.int32)


@dataclasses.dataclass
class SyntheticLMData:
    """Counter-based synthetic stream: ``batch(edge, step)`` is pure."""

    vocab: int
    seq_len: int
    batch_size: int
    n_codebooks: int = 1
    n_prefix: int = 0
    d_model: int = 0
    seed: int = 0
    alpha: float = 1.2

    def batch(self, edge_id: int | jax.Array, step: int | jax.Array
              ) -> Dict[str, jax.Array]:
        rng = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.seed), edge_id), step)
        tokens = lm_batch(rng, self.batch_size, self.seq_len, self.vocab,
                          edge_id, self.alpha, self.n_codebooks)
        out = {"tokens": tokens}
        if self.n_prefix:
            rng2 = jax.random.fold_in(rng, 7)
            out["prefix_emb"] = 0.02 * jax.random.normal(
                rng2, (self.batch_size, self.n_prefix, self.d_model),
                jnp.float32)
        return out

    @classmethod
    def for_model(cls, cfg: ModelConfig, batch_size: int, seq_len: int,
                  seed: int = 0) -> "SyntheticLMData":
        return cls(vocab=cfg.vocab_size, seq_len=seq_len,
                   batch_size=batch_size, n_codebooks=cfg.n_codebooks,
                   n_prefix=cfg.num_prefix_embeddings, d_model=cfg.d_model,
                   seed=seed)
