"""Dependency-free checkpointing: pytrees <-> .npz files.

Paths are serialized as '/'-joined key strings; restore rebuilds into a
template pytree (shape/dtype validated), so it round-trips params, opt
state, EL runtime state, and bandit state alike.
"""

from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save(path: str, tree: Any, step: int | None = None) -> None:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _path_str(kp) or "_root"
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat["bf16:" + key] = arr.astype(np.float32)
        else:
            flat[key] = arr
    if step is not None:
        flat["_ckpt_step"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **flat)


def restore(path: str, template: Any) -> Any:
    """Load a checkpoint into the structure of ``template``."""
    data = np.load(path if path.endswith(".npz") else path + ".npz",
                   allow_pickle=False)
    stored: Dict[str, np.ndarray] = {}
    bf16 = set()
    for k in data.files:
        if k.startswith("bf16:"):
            stored[k[5:]] = data[k]
            bf16.add(k[5:])
        else:
            stored[k] = data[k]

    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for kp, leaf in leaves:
        key = _path_str(kp) or "_root"
        if key not in stored:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = stored[key]
        want = jnp.asarray(leaf)
        if key in bf16:
            arr = arr.astype(jnp.bfloat16)
        got = jnp.asarray(arr).astype(want.dtype)
        if got.shape != want.shape:
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {got.shape} "
                f"vs template {want.shape}")
        out.append(got)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out)


def latest_step(path: str) -> int | None:
    try:
        data = np.load(path if path.endswith(".npz") else path + ".npz")
    except FileNotFoundError:
        return None
    if "_ckpt_step" in data.files:
        return int(data["_ckpt_step"])
    return None
