from repro.train.optimizer import (OptState, apply_updates, init_opt_state,
                                   lr_schedule)
from repro.train.state import (TrainState, init_train_state, make_decode_step,
                               make_eval_step, make_prefill_step,
                               make_train_step)

__all__ = [
    "OptState", "apply_updates", "init_opt_state", "lr_schedule",
    "TrainState", "init_train_state", "make_train_step", "make_eval_step",
    "make_prefill_step", "make_decode_step",
]
