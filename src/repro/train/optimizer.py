"""Optimizers and LR schedules (no optax dependency — built in JAX).

Supports AdamW and SGD(+momentum) with global-norm gradient clipping, and
three schedules: cosine, constant, and MiniCPM's Warmup-Stable-Decay (WSD)
[arXiv:2404.06395] — warmup, a long constant plateau, then a short decay
tail starting at ``decay_start_frac`` of total steps.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig

Params = Any


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Learning rate at ``step`` (0-based), as a traced scalar."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.asarray(max(cfg.warmup_steps, 1), jnp.float32)
    total = jnp.asarray(max(cfg.total_steps, 1), jnp.float32)
    peak = jnp.asarray(cfg.peak_lr, jnp.float32)
    floor = peak * cfg.min_lr_ratio
    warmup_lr = peak * jnp.minimum(step + 1.0, warm) / warm
    if cfg.schedule == "constant":
        post = peak
    elif cfg.schedule == "wsd":
        decay_start = total * cfg.decay_start_frac
        frac = jnp.clip((step - decay_start)
                        / jnp.maximum(total - decay_start, 1.0), 0.0, 1.0)
        post = peak - (peak - floor) * frac            # linear decay tail
    else:  # cosine
        frac = jnp.clip((step - warm) / jnp.maximum(total - warm, 1.0),
                        0.0, 1.0)
        post = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warm, warmup_lr, post)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


class OptState(NamedTuple):
    step: jax.Array
    mu: Params            # first moment (adamw) / momentum buffer (sgd)
    nu: Params            # second moment (adamw) / unused zeros (sgd)


def init_opt_state(cfg: TrainConfig, params: Params) -> OptState:
    mdt = jnp.dtype(cfg.opt_state_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, mdt), params)
    if cfg.optimizer == "sgd" and cfg.momentum == 0.0:
        # no buffers needed; keep shape-compatible empty moments
        zeros_nu = jax.tree.map(lambda p: jnp.zeros((), mdt), params)
    else:
        zeros_nu = zeros
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros_nu)


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    if max_norm <= 0:
        return grads, gnorm
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def apply_updates(cfg: TrainConfig, params: Params, grads: Params,
                  opt_state: OptState
                  ) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state.step
    lr = lr_schedule(cfg, step)
    if cfg.optimizer == "sgd":
        if cfg.momentum > 0.0:
            mu = jax.tree.map(lambda m, g: cfg.momentum * m + g,
                              opt_state.mu, grads)
            update = mu
        else:
            mu, update = opt_state.mu, grads
        new_params = jax.tree.map(
            lambda p, u: (p - lr * (u + cfg.weight_decay
                                    * p.astype(jnp.float32))).astype(p.dtype),
            params, update)
        new_state = OptState(step + 1, mu, opt_state.nu)
    else:  # adamw
        b1, b2 = cfg.beta1, cfg.beta2
        mdt = jnp.dtype(cfg.opt_state_dtype)
        # moments stored in opt_state_dtype; update math in fp32
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g).astype(mdt),
            opt_state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * jnp.square(g)).astype(mdt),
            opt_state.nu, grads)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, m, v):
            m = m.astype(jnp.float32)
            v = v.astype(jnp.float32)
            mhat = m / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + 1e-8)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        new_state = OptState(step + 1, mu, nu)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
