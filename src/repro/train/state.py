"""Train state + step factories."""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.train.optimizer import OptState, apply_updates, init_opt_state

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt: OptState


def init_train_state(model, train_cfg: TrainConfig,
                     rng: jax.Array) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=init_opt_state(train_cfg, params))


def make_train_step(model, train_cfg: TrainConfig):
    """Standard synchronous train step: grad -> clip -> update."""

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state.params, batch)
        params, opt, opt_metrics = apply_updates(
            train_cfg, state.params, grads, state.opt)
        metrics = dict(metrics, **opt_metrics)
        return TrainState(params, opt), metrics

    return train_step


def make_eval_step(model):
    def eval_step(params: Params, batch: Dict[str, jax.Array]
                  ) -> Dict[str, jax.Array]:
        _, metrics = model.loss(params, batch)
        return metrics

    return eval_step


def make_prefill_step(model, last_only: bool = False):
    def prefill_step(params: Params, batch: Dict[str, jax.Array]
                     ) -> jax.Array:
        logits, _ = model.forward(params, batch["tokens"],
                                  batch.get("prefix_emb"),
                                  last_only=last_only)
        return logits

    return prefill_step


def make_decode_step(model):
    def decode_step(params: Params, tokens: jax.Array, cache: Any
                    ) -> Tuple[jax.Array, Any]:
        return model.decode_step(params, tokens, cache)

    return decode_step
