"""Learning-utility estimators (§III.A).

The paper defines utility either (a) via a model-specific metric on a small
test set uploaded to the cloud, or (b) via the difference between global
parameters at consecutive slots — smaller difference = higher utility
(their K-means example uses the negative center shift).

All estimators map onto a common interface:
    ``utility(prev_snapshot, new_snapshot) -> float``
where snapshots carry whatever the estimator needs (params and/or metric).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np


def param_l2_delta(prev_params: Any, new_params: Any) -> float:
    """Global L2 distance between parameter pytrees."""
    import jax
    total = 0.0
    for a, b in zip(jax.tree.leaves(prev_params), jax.tree.leaves(new_params)):
        d = np.asarray(a, np.float32) - np.asarray(b, np.float32)
        total += float(np.sum(d * d))
    return float(np.sqrt(total))


@dataclasses.dataclass
class UtilityEstimator:
    """kind: 'param_delta' | 'eval_gain' | 'loss_delta'."""

    kind: str = "param_delta"
    scale: float = 1.0

    def __call__(self, prev: Dict[str, Any], new: Dict[str, Any]) -> float:
        if self.kind == "param_delta":
            # smaller parameter movement => closer to convergence => higher
            # utility (paper §III.A): u = 1 / (1 + ||Δθ||)
            delta = param_l2_delta(prev["params"], new["params"])
            return self.scale / (1.0 + delta)
        if self.kind == "eval_gain":
            return self.scale * (new["metric"] - prev["metric"])
        if self.kind == "loss_delta":
            return self.scale * (prev["loss"] - new["loss"])
        raise ValueError(f"unknown utility kind {self.kind!r}")
