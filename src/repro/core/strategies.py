"""Coordination strategies: OL4EL policies + the paper's baselines.

``ACSync`` implements the AC-sync baseline — the adaptive-communication
control of Wang et al., INFOCOM'18 [12] ("When edge meets learning") which
the paper compares against.  It picks the aggregation interval tau* that
maximizes estimated progress per resource unit, using online estimates of
smoothness (beta), gradient divergence (delta) and gradient scale (rho)
derived from parameter movements:

    h(tau)     = delta/beta * ((eta*beta + 1)^tau - 1) - eta*delta*tau
    score(tau) = [eta*(1 - beta*eta/2) - rho*h(tau)/tau] * tau
                 / (tau*c_comp + c_comm)
    tau*       = argmax_{1<=tau<=K, affordable} score(tau)

This is their convergence-bound objective re-expressed per resource unit;
estimates are refreshed every aggregation (their Algorithm 2 structure,
black-box parameter-delta estimators instead of raw gradients so it also
drives K-means).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

POLICIES = ("ol4el", "ucb_bv", "greedy", "freq_only", "eps_greedy",
            "uniform", "fixed_i", "ac_sync",
            # task-allocation competitors (repro.el.scenarios.baselines):
            # greedy max-interval assignment and delay/energy-balanced
            # pacing — host rules in repro.el.policies, traced twins in
            # the scenario engine's in-graph policy switch
            "task_alloc", "delay_energy")


@dataclasses.dataclass
class ACSync:
    """Adaptive-tau controller (baseline [12])."""

    eta: float                      # local learning rate
    max_interval: int
    beta: float = 1.0               # smoothness estimate
    delta: float = 0.1              # gradient-divergence estimate
    rho: float = 1.0                # loss-Lipschitz estimate
    ema: float = 0.5

    def update_estimates(self, local_deltas: np.ndarray,
                         global_delta: float, tau: int) -> None:
        """Refresh (beta, delta, rho) from parameter movements.

        local_deltas: per-edge ||theta_e - theta_global|| after tau local
        steps; global_delta: ||theta_new_global - theta_old_global||.
        Gradient proxies: g_e ~ local_delta / (eta * tau).
        """
        tau = max(tau, 1)
        g_local = local_deltas / (self.eta * tau)
        g_global = global_delta / (self.eta * tau)
        div = float(np.mean(np.abs(g_local - g_global)))
        self.delta = (1 - self.ema) * self.delta + self.ema * max(div, 1e-6)
        self.rho = (1 - self.ema) * self.rho + self.ema * max(
            float(g_global), 1e-6)
        # smoothness proxy: relative change of gradient magnitude
        beta_hat = max(float(np.std(g_local) /
                             (np.mean(np.abs(g_local)) + 1e-9)), 1e-3)
        self.beta = (1 - self.ema) * self.beta + self.ema * beta_hat

    def h(self, tau: np.ndarray) -> np.ndarray:
        eb = self.eta * self.beta + 1.0
        return (self.delta / self.beta * (eb ** tau - 1.0)
                - self.eta * self.delta * tau)

    def select_tau(self, residual_budget: float, comp_cost: float,
                   comm_cost: float) -> int:
        taus = np.arange(1, self.max_interval + 1, dtype=np.float64)
        cost = taus * comp_cost + comm_cost
        feasible = cost <= residual_budget + 1e-12
        if not feasible.any():
            return -1
        progress = (self.eta * (1.0 - self.beta * self.eta / 2.0)
                    - self.rho * self.h(taus) / taus)
        score = np.where(feasible, progress * taus / cost, -np.inf)
        return int(np.argmax(score)) + 1
