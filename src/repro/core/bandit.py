"""Budget-limited multi-armed bandits — the paper's §IV core.

Arms are *global update intervals* I in {1..K}.  Pulling arm I costs
``I * c_comp + c_comm`` resource units and yields the learning utility
observed at the next global aggregation.  The bandit must maximize average
utility before the per-edge budget runs out.

This module owns the sufficient statistics (``BanditState``) and the
in-graph (jittable) bandit; the host selection rules themselves live as
first-class objects in ``repro.el.policies`` (``select_arm`` below is a
thin compatibility shim over that registry).

Policies:

  * ``ol4el``     — the paper's 3-step fixed-cost procedure (§IV.B.1),
                    built on KUBE [Tran-Thanh et al., AAAI'12]:
                    (1) *utility-cost ordering*: UCB of utility per cost,
                    (2) *frequency calculation*: f_i = floor(B_res / c_i),
                    (3) *probabilistic selection*: P(i) ∝ density_i · f_i
                    over feasible arms.
                    Interpretation note (recorded in DESIGN.md): the paper's
                    text says "probability proportional to the frequency";
                    taken literally utility would never influence selection,
                    so we couple the step-1 ordering quantity (UCB density)
                    with the step-2 frequency — the literal variant is
                    available as ``freq_only`` and compared in benchmarks.
  * ``ucb_bv``    — variable-cost UCB-BV1 [Ding et al., AAAI'13] (§IV.B.2):
                    D_i = ū_i/c̄_i + (1+1/λ)·ε_i / (λ − ε_i),
                    ε_i = sqrt(ln(t−1)/n_i), λ = lower bound on E[cost].
  * ``greedy``    — argmax UCB density (the pure fractional-KUBE solution).
  * ``freq_only`` — the literal reading, P(i) ∝ f_i.
  * ``eps_greedy``— ε-greedy on density (ablation).
  * ``uniform``   — uniform over feasible arms (ablation).
  * ``fixed_i``   — the paper's Fixed-I baseline (constant interval).

State is kept in plain numpy (the bandit is the *cloud control plane*; the
data plane — local iterations + aggregation collectives — is the JAX
``el_round`` in ``repro.federated``).  All functions are vectorizable over
a leading edge dimension for the async mode (one bandit per edge).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class BanditState:
    """Sufficient statistics for one bandit over K arms."""

    counts: np.ndarray        # [K] pulls
    utility_sum: np.ndarray   # [K]
    cost_sum: np.ndarray      # [K] observed costs (variable-cost mode)
    t: int                    # total pulls

    @classmethod
    def create(cls, n_arms: int) -> "BanditState":
        return cls(np.zeros(n_arms, np.int64), np.zeros(n_arms),
                   np.zeros(n_arms), 0)

    def copy(self) -> "BanditState":
        return BanditState(self.counts.copy(), self.utility_sum.copy(),
                           self.cost_sum.copy(), self.t)

    @property
    def n_arms(self) -> int:
        return len(self.counts)

    def mean_utility(self) -> np.ndarray:
        return self.utility_sum / np.maximum(self.counts, 1)

    def mean_cost(self, fallback: Optional[np.ndarray] = None) -> np.ndarray:
        m = self.cost_sum / np.maximum(self.counts, 1)
        if fallback is not None:
            m = np.where(self.counts > 0, m, fallback)
        return m

    def update(self, arm: int, utility: float, cost: float) -> None:
        self.counts[arm] += 1
        self.utility_sum[arm] += utility
        self.cost_sum[arm] += cost
        self.t += 1


def arm_costs(n_arms: int, comp_cost: float, comm_cost: float) -> np.ndarray:
    """Expected cost of interval-arm I (1-based): I*comp + comm."""
    intervals = np.arange(1, n_arms + 1, dtype=np.float64)
    return intervals * comp_cost + comm_cost


def _ucb(state: BanditState, ucb_c: float) -> np.ndarray:
    """Upper confidence bound of mean utility (unplayed arms -> +inf)."""
    n = np.maximum(state.counts, 1)
    bonus = np.sqrt(ucb_c * np.log(max(state.t, 2)) / n)
    ucb = state.mean_utility() + bonus
    return np.where(state.counts > 0, ucb, np.inf)


def select_arm(state: BanditState, residual_budget: float,
               costs: np.ndarray, policy: str = "ol4el",
               rng: Optional[np.random.Generator] = None,
               ucb_c: float = 2.0, eps: float = 0.1,
               fixed_arm: int = 3) -> int:
    """Choose an arm. Returns -1 when no arm is affordable (terminate).

    Compatibility shim over the first-class policy objects in
    ``repro.el.policies`` (where the selection rules now live); prefer
    ``policies.get(name).select(...)`` in new code.
    """
    from repro.el import policies as el_policies
    rng = rng or np.random.default_rng(0)
    pol = el_policies.get(policy, ucb_c=ucb_c, eps=eps, fixed_arm=fixed_arm)
    return pol.select(state, residual_budget, costs, rng)


# ---------------------------------------------------------------------------
# In-graph (jittable) bandit — beyond-paper: lets the WHOLE OL4EL round,
# including arm selection, live inside one pjit program (no host round-trip
# between rounds).  Same math as select_arm(policy="ol4el"); state is a
# dict of arrays so it vmaps over edges for the async mode.
# ---------------------------------------------------------------------------


def jax_bandit_init(n_arms: int):
    import jax.numpy as jnp
    return {
        "counts": jnp.zeros((n_arms,), jnp.int32),
        "utility_sum": jnp.zeros((n_arms,), jnp.float32),
        "cost_sum": jnp.zeros((n_arms,), jnp.float32),
        "t": jnp.zeros((), jnp.int32),
    }


def jax_selection_weights(state, residual_budget, costs, ucb_c: float = 2.0):
    """OL4EL 3-step selection weights (density x frequency), jnp version.

    Unplayed feasible arms get all the mass (initialization phase).
    Returns [K] nonnegative weights; all-zero means no arm affordable.
    """
    import jax.numpy as jnp
    counts = state["counts"]
    feasible = costs <= residual_budget + 1e-12
    untried = feasible & (counts == 0)
    n = jnp.maximum(counts, 1)
    t = jnp.maximum(state["t"], 2).astype(jnp.float32)
    mean_u = state["utility_sum"] / n
    bonus = jnp.sqrt(ucb_c * jnp.log(t) / n)
    ucb = mean_u + bonus
    density = ucb / jnp.maximum(costs, 1e-9)
    d = density - jnp.min(jnp.where(feasible, density, jnp.inf)) + 1e-9
    freq = jnp.where(feasible, jnp.floor(residual_budget / costs), 0.0)
    w = jnp.where(feasible, jnp.maximum(d * freq, 1e-12), 0.0)
    # initialization phase: uniform over untried feasible arms
    w = jnp.where(jnp.any(untried), untried.astype(jnp.float32), w)
    return w


def jax_select_arm(rng, state, residual_budget, costs, ucb_c: float = 2.0):
    """Sample an arm in-graph. Returns -1 when nothing is affordable."""
    import jax.numpy as jnp
    from jax import random
    w = jax_selection_weights(state, residual_budget, costs, ucb_c)
    total = jnp.sum(w)
    logits = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-30)), -jnp.inf)
    arm = random.categorical(rng, logits)
    return jnp.where(total > 0, arm, -1)


def jax_bandit_update(state, arm, utility, cost):
    import jax.numpy as jnp
    valid = arm >= 0
    arm_c = jnp.maximum(arm, 0)
    return {
        "counts": state["counts"].at[arm_c].add(
            jnp.where(valid, 1, 0)),
        "utility_sum": state["utility_sum"].at[arm_c].add(
            jnp.where(valid, utility, 0.0)),
        "cost_sum": state["cost_sum"].at[arm_c].add(
            jnp.where(valid, cost, 0.0)),
        "t": state["t"] + jnp.where(valid, 1, 0),
    }


def regret_oracle(mean_utility: np.ndarray, costs: np.ndarray,
                  budget: float) -> float:
    """Best fixed-arm average-utility benchmark: play the best
    utility-per-cost arm until the budget runs out (the budget-limited MAB
    oracle for i.i.d. rewards)."""
    density = mean_utility / costs
    best = int(np.argmax(density))
    pulls = int(budget // costs[best])
    return pulls * float(mean_utility[best])
