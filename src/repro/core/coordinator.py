"""The cloud-server coordinator (§III): budgets, bandits, decisions.

Owns one bandit (sync) or one bandit per edge (async), the per-edge budget
accounting, the per-edge heterogeneous cost model, and the strategy switch
(OL4EL policies vs. Fixed-I vs. AC-sync).  The coordinator is control-plane
only — pure python/numpy; the data plane runs in JAX.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.config import OL4ELConfig
from repro.core.bandit import BanditState, arm_costs


def edge_speed_factors(n_edges: int, heterogeneity: float) -> np.ndarray:
    """Per-edge compute-time multipliers in [1, H] (paper's H = ratio of
    fastest to slowest processing speed). Edge 0 is fastest."""
    if n_edges == 1:
        return np.ones(1)
    return 1.0 + (heterogeneity - 1.0) * np.arange(n_edges) / (n_edges - 1)


@dataclasses.dataclass
class EdgeAccount:
    budget: float
    consumed: float = 0.0

    @property
    def residual(self) -> float:
        return self.budget - self.consumed


class CloudCoordinator:
    """Decides per-edge global-update intervals under budget constraints."""

    def __init__(self, cfg: OL4ELConfig, n_edges: Optional[int] = None,
                 lr: float = 0.1, policy=None):
        from repro.el import policies as el_policies
        self.cfg = cfg
        self.n_edges = n_edges or cfg.n_edges
        self.rng = np.random.default_rng(cfg.seed)
        self.speed = edge_speed_factors(self.n_edges, cfg.heterogeneity)
        self.comp_cost = cfg.comp_cost * self.speed          # [E]
        self.comm_cost = np.full(self.n_edges, cfg.comm_cost)
        self.accounts = [EdgeAccount(cfg.budget) for _ in range(self.n_edges)]
        k = cfg.max_interval
        if cfg.mode == "sync":
            self.bandits = [BanditState.create(k)]
        else:
            self.bandits = [BanditState.create(k)
                            for _ in range(self.n_edges)]
        # the collaboration strategy is a first-class object (registry:
        # repro.el.policies); pass policy= to inject a configured instance
        self.policy = policy if policy is not None else el_policies.get(
            cfg.policy, ucb_c=cfg.ucb_c, eps=cfg.eps,
            fixed_arm=cfg.fixed_interval - 1, eta=lr, max_interval=k)
        self.ac = getattr(self.policy, "ac", None)
        self.history: List[Dict] = []

    # -- cost model ----------------------------------------------------------

    def expected_cost(self, edge: int, interval: int) -> float:
        return interval * self.comp_cost[edge] + self.comm_cost[edge]

    def realized_cost(self, edge: int, interval: int) -> float:
        """Draw the actual cost (variable-cost mode adds i.i.d. noise).

        AC-sync pays an extra estimation overhead: its tau-control needs
        per-round gradient/divergence statistics computed AT THE EDGES
        (Wang et al. Algorithm 2) — the paper's §V.B.1 explanation for why
        OL4EL-sync (all control computed on the cloud) beats AC-sync.
        """
        c = self.expected_cost(edge, interval)
        if self.cfg.policy == "ac_sync":
            c += self.comp_cost[edge]          # one extra local computation
        if self.cfg.cost_model == "variable" and self.cfg.cost_noise > 0:
            c *= max(0.1, 1.0 + self.cfg.cost_noise * self.rng.standard_normal())
        return c

    def _bandit_for(self, edge: int) -> BanditState:
        return self.bandits[0] if self.cfg.mode == "sync" \
            else self.bandits[edge]

    def _costs_for(self, edge: int) -> np.ndarray:
        if self.cfg.mode == "sync":
            # sync: one shared arm; a round costs every edge its own amount —
            # feasibility must respect the *tightest* account.
            worst = int(np.argmax(self.comp_cost))
            return arm_costs(self.cfg.max_interval,
                             float(self.comp_cost[worst]),
                             float(self.comm_cost[worst]))
        return arm_costs(self.cfg.max_interval, float(self.comp_cost[edge]),
                         float(self.comm_cost[edge]))

    def _residual_for(self, edge: int) -> float:
        if self.cfg.mode == "sync":
            return min(a.residual for a in self.accounts)
        return self.accounts[edge].residual

    # -- decisions -------------------------------------------------------------

    def decide(self, edge: int = 0) -> int:
        """Pick the global-update interval for ``edge`` (1-based interval).
        Returns -1 when the edge's budget affords no arm (terminate)."""
        arm = self.policy.select(self._bandit_for(edge),
                                 self._residual_for(edge),
                                 self._costs_for(edge), self.rng)
        return -1 if arm < 0 else arm + 1

    def observe(self, edge: int, interval: int, utility: float,
                cost: float) -> None:
        """Report the realized (utility, cost) of a finished interval."""
        self._bandit_for(edge).update(interval - 1, utility, cost)

    def charge(self, edge: int, cost: float) -> None:
        self.accounts[edge].consumed += cost

    # -- termination -------------------------------------------------------------

    def exhausted(self, edge: int) -> bool:
        min_cost = float(self.comp_cost[edge] + self.comm_cost[edge])
        return self.accounts[edge].residual < min_cost

    def all_exhausted(self) -> bool:
        if self.cfg.mode == "sync":
            return any(self.exhausted(e) for e in range(self.n_edges))
        return all(self.exhausted(e) for e in range(self.n_edges))

    def total_consumed(self) -> float:
        return sum(a.consumed for a in self.accounts)
