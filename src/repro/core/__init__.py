"""The paper's primary contribution: OL4EL — budget-limited-MAB scheduling
of edge-cloud collaborative learning (bandits, utilities, coordinator,
strategy zoo)."""

from repro.core.bandit import BanditState, arm_costs, select_arm
from repro.core.coordinator import CloudCoordinator, edge_speed_factors
from repro.core.strategies import ACSync, POLICIES
from repro.core.utility import UtilityEstimator, param_l2_delta

__all__ = [
    "BanditState", "arm_costs", "select_arm", "CloudCoordinator",
    "edge_speed_factors", "ACSync", "POLICIES", "UtilityEstimator",
    "param_l2_delta",
]
