"""The paper's own workloads as JAX models: linear SVM and K-means.

Both expose the same functional surface the EL runtime drives:
  ``init(rng) -> params``
  ``local_step(params, batch, lr) -> (params, metrics)``  (one local iteration)
  ``evaluate(params, eval_set) -> metrics``               (cloud-side utility)

SVM  — multiclass one-vs-rest squared-hinge linear SVM (paper: 59-dim wafer
       features, 8 classes; metric = prediction accuracy).
K-means — minibatch Lloyd steps (paper: traffic images, K=3; metric = F1
       of cluster assignments vs. ground truth after greedy cluster->class
       matching; utility = negative center shift between slots — the
       paper's own example of a model-specific utility).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Linear multiclass SVM (one-vs-rest, squared hinge)
# ---------------------------------------------------------------------------


class LinearSVM:
    def __init__(self, cfg: ModelConfig, reg: float = 1e-4):
        self.cfg = cfg
        self.d = cfg.d_model
        self.n_classes = cfg.vocab_size
        self.reg = reg

    def init(self, rng: jax.Array) -> Params:
        return {
            "w": jnp.zeros((self.d, self.n_classes), jnp.float32),
            "b": jnp.zeros((self.n_classes,), jnp.float32),
        }

    def scores(self, params: Params, x: jax.Array) -> jax.Array:
        return x @ params["w"] + params["b"]

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x, y = batch["x"], batch["y"]
        s = self.scores(params, x)                       # [B, C]
        y_pm = 2.0 * jax.nn.one_hot(y, self.n_classes) - 1.0
        margin = jnp.maximum(0.0, 1.0 - y_pm * s)
        hinge = jnp.mean(jnp.sum(margin ** 2, axis=-1))
        l2 = self.reg * jnp.sum(params["w"] ** 2)
        loss = hinge + l2
        acc = jnp.mean((jnp.argmax(s, -1) == y).astype(jnp.float32))
        return loss, {"loss": loss, "accuracy": acc}

    def local_step(self, params: Params, batch: Dict[str, jax.Array],
                   lr: float) -> Tuple[Params, Dict[str, jax.Array]]:
        (loss, metrics), grads = jax.value_and_grad(
            self.loss, has_aux=True)(params, batch)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return params, metrics

    def evaluate(self, params: Params, eval_set: Dict[str, jax.Array]
                 ) -> Dict[str, float]:
        s = self.scores(params, eval_set["x"])
        acc = jnp.mean((jnp.argmax(s, -1) == eval_set["y"])
                       .astype(jnp.float32))
        return {"accuracy": float(acc)}


# ---------------------------------------------------------------------------
# K-means (minibatch Lloyd)
# ---------------------------------------------------------------------------


class KMeans:
    """Minibatch-Lloyd K-means.

    ``impl`` selects the E-step engine, following the ``models/layers``
    convention: ``"jnp"`` (the pure-XLA distance expansion) or
    ``"pallas"`` — the ``repro.kernels.kmeans_assign`` Pallas kernel
    (native on TPU, interpret mode elsewhere; oracle-tested against the
    jnp path in tests/test_kernels.py).  The kernel is vmap-safe, so the
    compiled EL programs' per-edge local blocks route through it too.
    ``use_kernel=True`` is the deprecated spelling of ``impl="pallas"``.
    """

    def __init__(self, cfg: ModelConfig, blend: float = 0.5,
                 use_kernel: bool = False, impl: str = "jnp"):
        if impl not in ("jnp", "pallas"):
            raise ValueError(f"KMeans impl={impl!r}; expected 'jnp' or "
                             "'pallas'")
        self.cfg = cfg
        self.d = cfg.d_model
        self.k = cfg.vocab_size
        self.blend = blend           # minibatch-Lloyd blending rate
        self.impl = "pallas" if use_kernel else impl

    @property
    def use_kernel(self) -> bool:   # pre-impl= spelling, kept for callers
        return self.impl == "pallas"

    def init(self, rng: jax.Array) -> Params:
        return {"centers": jax.random.normal(rng, (self.k, self.d),
                                             jnp.float32)}

    def assign(self, params: Params, x: jax.Array) -> jax.Array:
        if self.impl == "pallas":
            from repro.kernels.kmeans_assign import ops as ka_ops
            return ka_ops.assign(x, params["centers"])
        d2 = (jnp.sum(x ** 2, -1, keepdims=True)
              - 2.0 * x @ params["centers"].T
              + jnp.sum(params["centers"] ** 2, -1)[None, :])
        return jnp.argmin(d2, axis=-1)

    def inertia(self, params: Params, x: jax.Array) -> jax.Array:
        d2 = (jnp.sum(x ** 2, -1, keepdims=True)
              - 2.0 * x @ params["centers"].T
              + jnp.sum(params["centers"] ** 2, -1)[None, :])
        return jnp.mean(jnp.min(d2, axis=-1))

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        loss = self.inertia(params, batch["x"])
        return loss, {"loss": loss}

    def local_step(self, params: Params, batch: Dict[str, jax.Array],
                   lr: float = 1.0) -> Tuple[Params, Dict[str, jax.Array]]:
        """One minibatch Lloyd step (blend new centroids into old)."""
        x = batch["x"]
        a = self.assign(params, x)                       # [B]
        onehot = jax.nn.one_hot(a, self.k, dtype=jnp.float32)   # [B, K]
        counts = onehot.sum(0)                            # [K]
        sums = onehot.T @ x                               # [K, d]
        new = sums / jnp.maximum(counts[:, None], 1.0)
        has = (counts > 0)[:, None]
        rate = self.blend * jnp.asarray(lr, jnp.float32)
        centers = jnp.where(
            has, (1.0 - rate) * params["centers"] + rate * new,
            params["centers"])
        inert = self.inertia({"centers": centers}, x)
        return {"centers": centers}, {"loss": inert}

    def evaluate(self, params: Params, eval_set: Dict[str, jax.Array]
                 ) -> Dict[str, float]:
        """Macro F1 after greedy cluster->class matching (paper metric)."""
        x = np.asarray(eval_set["x"])
        y = np.asarray(eval_set["y"])
        a = np.asarray(self.assign(params, jnp.asarray(x)))
        f1 = cluster_f1(a, y, self.k)
        inert = float(self.inertia(params, jnp.asarray(x)))
        return {"f1": f1, "inertia": inert}


def cluster_f1(assignments: np.ndarray, labels: np.ndarray, k: int) -> float:
    """Greedy majority cluster->class mapping, then macro F1."""
    n_classes = int(labels.max()) + 1
    mapping = np.zeros(k, np.int64)
    for c in range(k):
        members = labels[assignments == c]
        mapping[c] = np.bincount(members, minlength=n_classes).argmax() \
            if members.size else 0
    pred = mapping[assignments]
    f1s = []
    for cls in range(n_classes):
        tp = np.sum((pred == cls) & (labels == cls))
        fp = np.sum((pred == cls) & (labels != cls))
        fn = np.sum((pred != cls) & (labels == cls))
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        f1s.append(0.0 if prec + rec == 0 else 2 * prec * rec / (prec + rec))
    return float(np.mean(f1s))
