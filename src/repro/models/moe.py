"""Mixture-of-Experts FFN with token-choice top-k routing.

Sort-free capacity dispatch (pjit-friendly, O(T*k) index tensors, no
[T, E, C] one-hot):

  1. router logits -> softmax -> top-k experts per token (renormalized),
  2. position-in-expert via exclusive cumsum of expert one-hots,
  3. tokens scattered into an [E*C, d] buffer (dropped tokens fall into a
     sentinel row), expert SwiGLU as a single [E, C, ...] einsum —
     the expert dim shards over the ``model`` mesh axis (expert parallelism;
     XLA inserts the dispatch all-to-alls),
  4. gather back + gate-weighted combine; optional shared experts (dense).

Aux losses follow the standard load-balance formulation
``E * sum_e f_e * P_e`` plus a router z-loss.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import _act, dense_init, init_rms_norm

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    p = {
        "norm": init_rms_norm(d),
        "router": dense_init(ks[0], (d, m.num_experts)),
        "we_gate": dense_init(ks[1], (m.num_experts, d, m.expert_ffn_dim),
                              in_axis_size=d),
        "we_up": dense_init(ks[2], (m.num_experts, d, m.expert_ffn_dim),
                            in_axis_size=d),
        "we_down": dense_init(ks[3], (m.num_experts, m.expert_ffn_dim, d),
                              in_axis_size=m.expert_ffn_dim),
    }
    if m.num_shared_experts > 0:
        p["ws_gate"] = dense_init(ks[4], (d, m.shared_ffn_dim))
        p["ws_up"] = dense_init(ks[5], (d, m.shared_ffn_dim))
        p["ws_down"] = dense_init(ks[6], (m.shared_ffn_dim, d))
    return p


def capacity(num_tokens: int, cfg: ModelConfig) -> int:
    """Per-expert dispatch capacity.

    Large token counts use the standard ``T*k/E * capacity_factor``
    dropping rule; small counts (decode steps, tiny smoke batches) get the
    worst-case ``T*k`` so decode is DROPLESS — otherwise a one-token step
    could silently drop its own expert contribution and decode would not
    match the full forward pass.
    """
    m = cfg.moe
    c = int(num_tokens * m.top_k / m.num_experts * m.capacity_factor)
    if num_tokens * m.top_k <= 4096:
        return max(c, num_tokens * m.top_k)
    return max(c, m.top_k)


def moe_ffn(params: Params, cfg: ModelConfig, x: jax.Array
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, d] -> (y [B, S, d], aux {load_balance_loss, z_loss, ...})."""
    m = cfg.moe
    b, s, d = x.shape
    if m.dispatch_groups > 1 and (b * s) % m.dispatch_groups == 0:
        # grouped dispatch (§Perf): tokens are routed within
        # ``dispatch_groups`` independent groups aligned with the data
        # shards, so the dispatch buffer is [G, E, cap/G, d] with G sharded
        # over `data` — the partitioner moves only token payloads
        # (all-to-all) instead of replicating the whole [E, cap, d] buffer
        # across the mesh.  Capacity becomes per-group (standard
        # t5x/MaxText semantics; drop pattern differs from flat dispatch
        # only under capacity pressure).
        g = m.dispatch_groups
        xg = x.reshape(g, (b * s) // g, 1, d)
        yg, auxg = jax.vmap(
            lambda xe: _moe_ffn_flat(params, cfg, xe))(xg)
        aux = {k: (jnp.max(v) if k == "expert_frac_max" else jnp.mean(v))
               for k, v in auxg.items()}
        return yg.reshape(b, s, d), aux
    return _moe_ffn_flat(params, cfg, x)


def _moe_ffn_flat(params: Params, cfg: ModelConfig, x: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.num_experts
    cap = capacity(t, cfg)
    dtype = x.dtype
    xf = x.reshape(t, d)

    # ---- routing (fp32) --------------------------------------------------
    logits = (xf.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)                  # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses -------------------------------------------------------
    flat_e = expert_idx.reshape(t * k)                            # [T*k]
    counts = jax.ops.segment_sum(jnp.ones_like(flat_e, jnp.float32),
                                 flat_e, num_segments=e)          # [E]
    frac_routed = counts / (t * k)                                # f_e
    mean_prob = probs.mean(axis=0)                                # P_e
    lb_loss = e * jnp.sum(frac_routed * mean_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {
        "load_balance_loss": lb_loss,
        "router_z_loss": z_loss,
        "expert_frac_max": frac_routed.max(),
    }

    # ---- position-in-expert ------------------------------------------------
    flat_gate = gate.reshape(t * k).astype(dtype)
    flat_tok = jnp.repeat(jnp.arange(t), k)                       # [T*k]
    if m.dispatch == "sort":
        # beyond-paper optimization (EXPERIMENTS.md §Perf): stable argsort
        # by expert id gives each assignment's rank within its expert with
        # O(T*k) memory instead of the O(T*k*E) one-hot cumsum.  Stable
        # sort preserves token order within an expert, so keep/drop
        # decisions are bit-identical to the cumsum path (tested).
        sort_idx = jnp.argsort(flat_e, stable=True)               # [T*k]
        sorted_e = flat_e[sort_idx]
        starts = jnp.cumsum(counts.astype(jnp.int32)) \
            - counts.astype(jnp.int32)                            # [E]
        pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
        pos_in_e = jnp.zeros((t * k,), jnp.int32).at[sort_idx].set(
            pos_sorted)
    else:
        # paper-era dense dispatch: exclusive running count per expert
        oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # [T*k, E]
        pos = jnp.cumsum(oh, axis=0) - oh                         # exclusive
        pos_in_e = jnp.sum(pos * oh, axis=-1)                     # [T*k]
    keep = pos_in_e < cap
    # dropped tokens go to the sentinel row E*cap
    dst = jnp.where(keep, flat_e * cap + pos_in_e, e * cap)

    # ---- dispatch ----------------------------------------------------------
    buf = jnp.zeros((e * cap + 1, d), dtype)
    buf = buf.at[dst].set(xf[flat_tok])
    xb = buf[: e * cap].reshape(e, cap, d)                        # [E, C, d]

    # ---- expert FFN (expert dim shards over `model`) -----------------------
    g = _act(cfg.act_fn,
             jnp.einsum("ecd,edf->ecf", xb, params["we_gate"].astype(dtype)))
    u = jnp.einsum("ecd,edf->ecf", xb, params["we_up"].astype(dtype))
    yb = jnp.einsum("ecf,efd->ecd", g * u,
                    params["we_down"].astype(dtype))              # [E, C, d]

    # ---- combine ------------------------------------------------------------
    ybuf = jnp.concatenate(
        [yb.reshape(e * cap, d), jnp.zeros((1, d), dtype)], axis=0)
    contrib = ybuf[dst] * (flat_gate * keep.astype(dtype))[:, None]
    y = jnp.zeros((t, d), dtype).at[flat_tok].add(contrib)

    # ---- shared experts (dense path, DeepSeekMoE) ---------------------------
    if m.num_shared_experts > 0:
        sg = _act(cfg.act_fn, xf @ params["ws_gate"].astype(dtype))
        su = xf @ params["ws_up"].astype(dtype)
        y = y + (sg * su) @ params["ws_down"].astype(dtype)

    return y.reshape(b, s, d), aux
