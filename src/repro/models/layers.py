"""Core transformer layers: RMSNorm, RoPE, GQA attention, gated MLP.

Pure-functional: every layer is ``fn(params, x, ...) -> y`` over plain dict
pytrees.  Attention supports three execution paths:

  * ``naive``   -- full [S, T] logits; used for short sequences / smoke tests.
  * ``blocked`` -- lax.scan over query chunks (flash-style online softmax in
    fp32 accumulators); bounded memory for 32k+ prefill on any backend.
  * ``pallas``  -- the Pallas TPU kernel in ``repro.kernels`` (opt-in; the
    dry-run uses XLA paths because Pallas does not lower on CPU hosts).

Sliding-window attention is supported on every path; the blocked path can
additionally *slice* the KV range per query chunk (``window_slice=True``)
so windowed attention is sub-quadratic in compute, not just masked — this is
one of the beyond-paper roofline optimizations (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    """Truncated-normal fan-in init (maxtext-style 1/sqrt(fan_in))."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in fp32 accumulation, cast back to input dtype."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int) -> jax.Array:
    # Stored as (scale - 1) so zero-init == identity (gemma convention).
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    angles = angles[..., None, :]                      # [..., S, 1, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "norm": init_rms_norm(d),
        "wq": dense_init(ks[0], (d, h, hd), in_axis_size=d),
        "wk": dense_init(ks[1], (d, kv, hd), in_axis_size=d),
        "wv": dense_init(ks[2], (d, kv, hd), in_axis_size=d),
        "wo": dense_init(ks[3], (h, hd, d), in_axis_size=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def _qkv(params: Params, cfg: ModelConfig, x: jax.Array,
         positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = rms_norm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array,
               window: int) -> jax.Array:
    """Additive mask [.., Sq, Sk]: causal (+ sliding window if window>0)."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, bias: jax.Array,
          scale: float) -> jax.Array:
    """Grouped scaled-dot-product attention.

    q: [B, Sq, H, D]; k, v: [B, Sk, KV, D]; bias: [Sq, Sk] additive.
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits * scale + bias
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, h, d)


def _blocked_attention(q, k, v, q_positions, k_positions, window, scale,
                       block_q=1024, window_slice=False):
    """lax.scan over query chunks with online-softmax fp32 accumulators.

    When ``window_slice`` and a sliding window is active, each query chunk
    only reads a dynamic slice of KV of length (window + block_q), making
    compute O(S * window) instead of O(S^2).
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    nblocks = -(-s // block_q)
    pad = nblocks * block_q - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad), constant_values=-1)
    qb = q.reshape(b, nblocks, block_q, h, d).transpose(1, 0, 2, 3, 4)
    pb = q_positions.reshape(nblocks, block_q)

    use_slice = window_slice and window > 0
    kv_span = min(window + block_q, k.shape[1]) if use_slice else k.shape[1]

    def body(_, inputs):
        qi, qpos, iblk = inputs
        if use_slice:
            start = jnp.maximum(iblk * block_q + block_q - kv_span, 0)
            start = jnp.minimum(start, k.shape[1] - kv_span)
            ki = lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vi = lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            kpos = lax.dynamic_slice_in_dim(k_positions, start, kv_span)
        else:
            ki, vi, kpos = k, v, k_positions
        bias = _mask_bias(qpos, kpos, window)
        out = _sdpa(qi, ki, vi, bias, scale)
        return None, out

    iblk = jnp.arange(nblocks)
    _, outs = lax.scan(body, None, (qb, pb, iblk))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nblocks * block_q, h, d)
    return out[:, :s]


def attention(params: Params, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array, impl: str = "auto",
              window_slice: bool = False) -> jax.Array:
    """Full-sequence causal attention (train / prefill)."""
    q, k, v = _qkv(params, cfg, x, positions)
    scale = cfg.resolved_head_dim ** -0.5
    s = x.shape[1]
    if impl == "auto":
        impl = "naive" if s <= 2048 else "blocked"
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=True,
                                     window=cfg.sliding_window)
    elif impl == "blocked":
        out = _blocked_attention(q, k, v, positions, positions,
                                 cfg.sliding_window, scale,
                                 window_slice=window_slice)
    else:
        bias = _mask_bias(positions, positions, cfg.sliding_window)
        out = _sdpa(q, k, v, bias, scale)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def attention_fill(params: Params, cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array, cache_k: jax.Array,
                   cache_v: jax.Array, impl: str = "auto",
                   window_slice: bool = False
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence attention that also fills the KV cache (prefill).

    Writes K/V for positions [0, S) into the cache and returns the same
    output as ``attention``.
    """
    q, k, v = _qkv(params, cfg, x, positions)
    scale = cfg.resolved_head_dim ** -0.5
    s = x.shape[1]
    cache_k = lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), 0, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), 0, axis=1)
    if impl == "auto":
        impl = "naive" if s <= 2048 else "blocked"
    if impl == "blocked":
        out = _blocked_attention(q, k, v, positions, positions,
                                 cfg.sliding_window, scale,
                                 window_slice=window_slice)
    else:
        bias = _mask_bias(positions, positions, cfg.sliding_window)
        out = _sdpa(q, k, v, bias, scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, cache_k, cache_v


def attention_decode_ring(params: Params, cfg: ModelConfig, x: jax.Array,
                          cache_k: jax.Array, cache_v: jax.Array,
                          cache_index: jax.Array
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a RING (rolling) KV cache of length
    window+1 (§Perf: sliding-window archs keep O(window) state instead of
    O(seq_len); Mistral-style rolling buffer).

    Slot j holds absolute position  p(j) = index - ((index - j) mod L),
    L = cache length; keys are stored post-RoPE so only the mask needs
    absolute positions.
    """
    b = x.shape[0]
    ring = cache_k.shape[1]
    positions = jnp.full((b, 1), cache_index, dtype=jnp.int32)
    q, k_new, v_new = _qkv(params, cfg, x, positions)
    slot = jnp.mod(cache_index, ring)
    cache_k = lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
    j = jnp.arange(ring)
    k_pos = cache_index - jnp.mod(cache_index - j, ring)
    valid = k_pos >= 0
    if cfg.sliding_window > 0:
        valid &= k_pos > (cache_index - cfg.sliding_window)
    valid = valid | (j == slot)              # the fresh token is always live
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, :]
    scale = cfg.resolved_head_dim ** -0.5
    out = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                bias, scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, cache_k, cache_v


def attention_fill_ring(params: Params, cfg: ModelConfig, x: jax.Array,
                        positions: jax.Array, cache_k: jax.Array,
                        cache_v: jax.Array, impl: str = "auto",
                        window_slice: bool = False
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill that fills a ring cache: only the last ``ring`` positions
    land in the buffer, at slot = position mod ring."""
    q, k, v = _qkv(params, cfg, x, positions)
    s = x.shape[1]
    ring = cache_k.shape[1]
    n = min(s, ring)
    tail_pos = jnp.arange(s - n, s)
    slots = jnp.mod(tail_pos, ring)
    cache_k = cache_k.at[:, slots].set(k[:, -n:].astype(cache_k.dtype))
    cache_v = cache_v.at[:, slots].set(v[:, -n:].astype(cache_v.dtype))
    scale = cfg.resolved_head_dim ** -0.5
    if impl == "auto":
        impl = "naive" if s <= 2048 else "blocked"
    if impl == "blocked":
        out = _blocked_attention(q, k, v, positions, positions,
                                 cfg.sliding_window, scale,
                                 window_slice=window_slice)
    else:
        bias = _mask_bias(positions, positions, cfg.sliding_window)
        out = _sdpa(q, k, v, bias, scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, cache_k, cache_v


def attention_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                     cache_k: jax.Array, cache_v: jax.Array,
                     cache_index: jax.Array, window_slice: bool = False
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a KV cache.

    x: [B, 1, d]; cache_k/v: [B, S_max, KV, D]; cache_index: scalar int32
    (current length, == position of the new token).
    Returns (y [B,1,d], new_cache_k, new_cache_v).

    ``window_slice``: with sliding-window attention active, read only a
    window-sized dynamic slice of the cache instead of masking the full
    S_max — turns decode HBM traffic from O(S_max) into O(window)
    (EXPERIMENTS.md §Perf; numerically identical, tested).
    """
    b, _, _ = x.shape
    s_max = cache_k.shape[1]
    positions = jnp.full((b, 1), cache_index, dtype=jnp.int32)
    q, k_new, v_new = _qkv(params, cfg, x, positions)
    cache_k = lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), cache_index, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), cache_index, axis=1)
    scale = cfg.resolved_head_dim ** -0.5
    win = cfg.sliding_window
    if window_slice and 0 < win < s_max:
        span = win + 1                     # window ending at the new token
        start = jnp.clip(cache_index - win, 0, s_max - span)
        k_r = lax.dynamic_slice_in_dim(cache_k, start, span, axis=1)
        v_r = lax.dynamic_slice_in_dim(cache_v, start, span, axis=1)
        k_pos = start + jnp.arange(span)
    else:
        k_r, v_r = cache_k, cache_v
        k_pos = jnp.arange(s_max)
    valid = k_pos <= cache_index
    if win > 0:
        valid &= k_pos > (cache_index - win)
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, :]
    out = _sdpa(q, k_r.astype(q.dtype), v_r.astype(q.dtype), bias, scale)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "norm": init_rms_norm(d),
        "wi_gate": dense_init(ks[0], (d, f)),
        "wi_up": dense_init(ks[1], (d, f)),
        "wo": dense_init(ks[2], (f, d)),
    }


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def mlp(params: Params, x: jax.Array, act_fn: str = "silu") -> jax.Array:
    dtype = x.dtype
    gate = _act(act_fn, x @ params["wi_gate"].astype(dtype))
    up = x @ params["wi_up"].astype(dtype)
    return (gate * up) @ params["wo"].astype(dtype)
