"""Model zoo: unified LM over ModelConfig plus the paper's classic models."""

from __future__ import annotations

from repro.config import ModelConfig
from repro.models.transformer import LM
from repro.models.classic import KMeans, LinearSVM


def build_model(cfg: ModelConfig, **kwargs):
    """``--arch`` entry point: ModelConfig -> model object."""
    if cfg.family == "classic":
        if cfg.name.startswith("kmeans"):
            return KMeans(cfg, **kwargs)
        return LinearSVM(cfg, **kwargs)
    return LM(cfg, **kwargs)


__all__ = ["LM", "KMeans", "LinearSVM", "build_model"]
