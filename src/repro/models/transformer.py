"""Unified decoder-only language model over the ModelConfig space.

One implementation covers all ten assigned architectures:
  * dense GQA transformers (llama/qwen/minicpm/deepseek-coder family),
  * MoE transformers (olmoe, deepseek-moe: shared+routed, first-k-dense),
  * pure SSM (mamba2), hybrid attn/mamba interleave with MoE (jamba),
  * multimodal backbones (paligemma: prefix patch embeddings; musicgen:
    multi-codebook audio tokens with per-codebook heads).

Layer stacking uses **scan-over-groups**: the per-layer pattern is split
into (unscanned prefix, smallest repeating group); group params are stacked
on a leading axis and applied with ``lax.scan`` — this bounds HLO size and
compile time for the 80 dry-run lowerings regardless of depth, and is what
makes 72-layer Jamba lowering tractable on the CPU host.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ATTN, DENSE_FFN, MAMBA, MOE_FFN, NO_FFN, ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MoE

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Layer-group decomposition
# ---------------------------------------------------------------------------


def layer_groups(cfg: ModelConfig
                 ) -> Tuple[Tuple, Tuple, int]:
    """Split block pattern into (prefix, group, n_groups).

    ``prefix`` layers are applied unscanned; the remaining layers are
    ``n_groups`` repetitions of ``group``.  Minimizes the number of
    *unrolled* layers (prefix + group size) so HLO size stays bounded,
    breaking ties with the shortest prefix.
    """
    pattern = cfg.block_pattern()
    n = len(pattern)
    best = None
    for p in range(n + 1):
        rest = pattern[p:]
        if not rest:
            cand = (10 ** 9, p)   # all-prefix fallback: never preferred
            g = 0
        else:
            g = next(gg for gg in range(1, len(rest) + 1)
                     if len(rest) % gg == 0
                     and rest == rest[:gg] * (len(rest) // gg))
            cand = (p + g, p)
        if best is None or cand < best:
            best = cand
            best_split = (pattern[:p], rest[:g] if rest else (),
                          (len(rest) // g) if rest else 0)
    return best_split


# ---------------------------------------------------------------------------
# Single block (mixer + optional FFN)
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, kind: str, ffn: str) -> Params:
    k_mix, k_ffn = jax.random.split(key)
    p: Params = {}
    if kind == ATTN:
        p["mix"] = L.init_attention(k_mix, cfg)
    else:
        p["mix"] = M.init_mamba(k_mix, cfg)
    if ffn == DENSE_FFN:
        p["ffn"] = L.init_mlp(k_ffn, cfg.d_model, cfg.d_ff)
    elif ffn == MOE_FFN:
        p["ffn"] = MoE.init_moe(k_ffn, cfg)
    return p


def _zero_aux() -> Dict[str, jax.Array]:
    z = jnp.zeros((), jnp.float32)
    return {"load_balance_loss": z, "router_z_loss": z, "expert_frac_max": z,
            "n_moe": z}


def apply_block(p: Params, cfg: ModelConfig, kind: str, ffn: str,
                x: jax.Array, positions: jax.Array,
                attn_impl: str = "auto", window_slice: bool = False,
                use_ssd_kernel: bool = False
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    aux = _zero_aux()
    h = L.rms_norm(p["mix"]["norm"], x, cfg.norm_eps)
    if kind == ATTN:
        x = x + L.attention(p["mix"], cfg, h, positions, impl=attn_impl,
                            window_slice=window_slice)
    else:
        x = x + M.mamba_mixer(p["mix"], cfg, h, use_kernel=use_ssd_kernel)
    if ffn == DENSE_FFN:
        h = L.rms_norm(p["ffn"]["norm"], x, cfg.norm_eps)
        x = x + L.mlp(p["ffn"], h, cfg.act_fn)
    elif ffn == MOE_FFN:
        h = L.rms_norm(p["ffn"]["norm"], x, cfg.norm_eps)
        y, moe_aux = MoE.moe_ffn(p["ffn"], cfg, h)
        x = x + y
        for k in ("load_balance_loss", "router_z_loss"):
            aux[k] = aux[k] + moe_aux[k]
        aux["expert_frac_max"] = jnp.maximum(aux["expert_frac_max"],
                                             moe_aux["expert_frac_max"])
        aux["n_moe"] = aux["n_moe"] + 1.0
    return x, aux


def apply_block_decode(p: Params, cfg: ModelConfig, kind: str, ffn: str,
                       x: jax.Array, cache: Params, index: jax.Array,
                       window_slice: bool = False, ring: bool = False
                       ) -> Tuple[jax.Array, Params]:
    h = L.rms_norm(p["mix"]["norm"], x, cfg.norm_eps)
    if kind == ATTN:
        if ring:
            y, ck, cv = L.attention_decode_ring(p["mix"], cfg, h,
                                                cache["k"], cache["v"],
                                                index)
        else:
            y, ck, cv = L.attention_decode(p["mix"], cfg, h, cache["k"],
                                           cache["v"], index,
                                           window_slice=window_slice)
        x = x + y
        cache = {"k": ck, "v": cv}
    else:
        y, cache = M.mamba_decode(p["mix"], cfg, h, cache)
        x = x + y
    if ffn == DENSE_FFN:
        h = L.rms_norm(p["ffn"]["norm"], x, cfg.norm_eps)
        x = x + L.mlp(p["ffn"], h, cfg.act_fn)
    elif ffn == MOE_FFN:
        h = L.rms_norm(p["ffn"]["norm"], x, cfg.norm_eps)
        y, _ = MoE.moe_ffn(p["ffn"], cfg, h)
        x = x + y
    return x, cache


def apply_block_fill(p: Params, cfg: ModelConfig, kind: str, ffn: str,
                     x: jax.Array, positions: jax.Array, cache: Params,
                     attn_impl: str = "auto", window_slice: bool = False,
                     use_ssd_kernel: bool = False, ring: bool = False
                     ) -> Tuple[jax.Array, Params]:
    """Full-sequence block that also fills the decode cache (prefill)."""
    h = L.rms_norm(p["mix"]["norm"], x, cfg.norm_eps)
    if kind == ATTN:
        fill = L.attention_fill_ring if ring else L.attention_fill
        y, ck, cv = fill(p["mix"], cfg, h, positions,
                         cache["k"], cache["v"], impl=attn_impl,
                         window_slice=window_slice)
        x = x + y
        cache = {"k": ck, "v": cv}
    else:
        y, cache = M.mamba_mixer_with_state(p["mix"], cfg, h,
                                            use_kernel=use_ssd_kernel)
        x = x + y
    if ffn == DENSE_FFN:
        h = L.rms_norm(p["ffn"]["norm"], x, cfg.norm_eps)
        x = x + L.mlp(p["ffn"], h, cfg.act_fn)
    elif ffn == MOE_FFN:
        h = L.rms_norm(p["ffn"]["norm"], x, cfg.norm_eps)
        y, _ = MoE.moe_ffn(p["ffn"], cfg, h)
        x = x + y
    return x, cache


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype) -> Params:
    if kind == ATTN:
        hd = cfg.resolved_head_dim
        return {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        }
    return M.init_mamba_cache(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


class LM:
    """Functional language model: ``params`` pytree in, arrays out."""

    def __init__(self, cfg: ModelConfig, attn_impl: str = "auto",
                 window_slice: bool = False, use_ssd_kernel: bool = False,
                 fused_xent: bool = False, logits_spec=None,
                 ring_cache: bool = False):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.window_slice = window_slice
        self.use_ssd_kernel = use_ssd_kernel
        # fused_xent: compute CE as logsumexp - label logit (one-hot
        # contraction) instead of log_softmax + gather.  With the vocab dim
        # sharded over `model`, the gather forces XLA to ALL-GATHER the
        # full [tokens, vocab] logits; the contraction form partitions into
        # per-shard reductions + a scalar psum (EXPERIMENTS.md §Perf).
        self.fused_xent = fused_xent
        # logits_spec: optional PartitionSpec pinned onto the pre-loss
        # logits (vocab over `model`) so the partitioner keeps the CE
        # reduction sharded instead of all-gathering [tokens, vocab].
        self.logits_spec = logits_spec
        # ring_cache: sliding-window archs keep a rolling KV buffer of
        # length window+1 instead of the full sequence (§Perf).
        self.ring_cache = ring_cache and cfg.sliding_window > 0
        self.prefix, self.group, self.n_groups = layer_groups(cfg)

    # -- init ---------------------------------------------------------------

    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        r_emb, r_head, r_prefix, r_groups = jax.random.split(rng, 4)
        if cfg.n_codebooks > 1:
            embed = L.embed_init(r_emb,
                                 (cfg.n_codebooks, cfg.vocab_size, cfg.d_model))
        else:
            embed = L.embed_init(r_emb, (cfg.vocab_size, cfg.d_model))
        params: Params = {"embed": embed, "final_norm": L.init_rms_norm(cfg.d_model)}
        if not cfg.tie_embeddings:
            if cfg.n_codebooks > 1:
                params["lm_head"] = L.dense_init(
                    r_head, (cfg.n_codebooks, cfg.d_model, cfg.vocab_size),
                    in_axis_size=cfg.d_model)
            else:
                params["lm_head"] = L.dense_init(
                    r_head, (cfg.d_model, cfg.vocab_size))
        if self.prefix:
            keys = jax.random.split(r_prefix, len(self.prefix))
            params["prefix_layers"] = [
                init_block(k, cfg, kind, ffn)
                for k, (kind, ffn) in zip(keys, self.prefix)]
        if self.n_groups:
            gkeys = jax.random.split(r_groups, self.n_groups)

            def one_group(k):
                subkeys = jax.random.split(k, len(self.group))
                return {f"sub{i}": init_block(sk, cfg, kind, ffn)
                        for i, (sk, (kind, ffn))
                        in enumerate(zip(subkeys, self.group))}

            if cfg.scan_layers:
                params["groups"] = jax.vmap(one_group)(gkeys)
            else:
                params["groups"] = [one_group(k) for k in gkeys]
        return params

    # -- embedding ----------------------------------------------------------

    def embed(self, params: Params, tokens: jax.Array,
              prefix_emb: Optional[jax.Array]) -> jax.Array:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        emb = params["embed"]
        if cfg.n_codebooks > 1:
            # tokens: [B, n_cb, S] -> summed codebook embeddings
            cb = jnp.arange(cfg.n_codebooks)[None, :, None]      # [1,CB,1]
            x = jnp.sum(emb.astype(dtype)[cb, tokens], axis=1)   # [B, S, d]
        else:
            x = emb.astype(dtype)[tokens]                        # [B, S, d]
        if prefix_emb is not None:
            x = jnp.concatenate([prefix_emb.astype(dtype), x], axis=1)
        return x

    def unembed(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        dtype = x.dtype
        if cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(dtype))
        if cfg.n_codebooks > 1:
            return jnp.einsum("bsd,cdv->bcsv", x,
                              params["lm_head"].astype(dtype))
        return x @ params["lm_head"].astype(dtype)

    # -- forward (train / prefill) -------------------------------------------

    def _group_fn(self, p_group: Params, x: jax.Array, positions: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        aux = _zero_aux()
        for i, (kind, ffn) in enumerate(self.group):
            x, a = apply_block(p_group[f"sub{i}"], cfg, kind, ffn, x,
                               positions, self.attn_impl, self.window_slice,
                               self.use_ssd_kernel)
            aux = jax.tree.map(jnp.add, aux, a)
        return x, aux

    def forward(self, params: Params, tokens: jax.Array,
                prefix_emb: Optional[jax.Array] = None,
                last_only: bool = False
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x = self.embed(params, tokens, prefix_emb)
        positions = jnp.arange(x.shape[1])
        aux = _zero_aux()
        for p_layer, (kind, ffn) in zip(params.get("prefix_layers", []),
                                        self.prefix):
            x, a = apply_block(p_layer, cfg, kind, ffn, x, positions,
                               self.attn_impl, self.window_slice,
                               self.use_ssd_kernel)
            aux = jax.tree.map(jnp.add, aux, a)
        if self.n_groups:
            group_fn = self._group_fn
            if cfg.remat:
                group_fn = jax.checkpoint(group_fn)
            if cfg.scan_layers:
                def body(carry, p_group):
                    x, aux = carry
                    x, a = group_fn(p_group, x, positions)
                    return (x, jax.tree.map(jnp.add, aux, a)), None
                (x, aux), _ = lax.scan(body, (x, aux), params["groups"])
            else:
                for p_group in params["groups"]:
                    x, a = group_fn(p_group, x, positions)
                    aux = jax.tree.map(jnp.add, aux, a)
        x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
        if last_only:
            # serving prefill: only the next-token logits are needed —
            # slicing BEFORE the unembedding avoids computing (and
            # all-gathering) the full [B, S, vocab] logits (§Perf).
            x = x[:, -1:]
        logits = self.unembed(params, x)
        return logits, aux

    # -- loss ---------------------------------------------------------------

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Next-token cross-entropy (+ MoE aux). batch: tokens [B,S] or
        [B,CB,S]; optional prefix_emb [B,P,d]; optional loss_mask [B,S-1]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        prefix_emb = batch.get("prefix_emb")
        logits, aux = self.forward(params, tokens, prefix_emb)
        n_prefix = prefix_emb.shape[1] if prefix_emb is not None else 0
        if cfg.n_codebooks > 1:
            pred = logits[:, :, :-1]                        # [B,CB,S-1,V]
            tgt = tokens[:, :, 1:]                          # [B,CB,S-1]
        else:
            pred = logits[:, n_prefix:-1]                   # [B,S-1,V]
            tgt = tokens[:, 1:]
        if self.logits_spec is not None and len(self.logits_spec) == pred.ndim:
            pred = jax.lax.with_sharding_constraint(pred, self.logits_spec)
        if self.fused_xent:
            logits32 = pred.astype(jnp.float32)
            lse = jax.nn.logsumexp(logits32, axis=-1)
            onehot = jax.nn.one_hot(tgt, logits32.shape[-1],
                                    dtype=jnp.float32)
            label_logit = jnp.einsum("...v,...v->...", logits32, onehot)
            nll = lse - label_logit
        else:
            logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(nll.shape, jnp.float32)
        else:
            mask = jnp.broadcast_to(mask, nll.shape).astype(jnp.float32)
        ce = jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
        loss = ce
        m = cfg.moe
        if m.enabled:
            loss = loss + m.router_aux_loss * aux["load_balance_loss"]
            loss = loss + m.router_z_loss * aux["router_z_loss"]
        metrics = {"ce_loss": ce, "loss": loss,
                   "load_balance_loss": aux["load_balance_loss"],
                   "router_z_loss": aux["router_z_loss"]}
        return loss, metrics

    # -- serving -------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        if self.ring_cache:
            # ring length == window: slots cover positions
            # (index-window, index] exactly (and divides the mesh axes,
            # unlike window+1)
            max_len = min(max_len, cfg.sliding_window)
        dtype = jnp.dtype(cfg.dtype)
        cache: Params = {"index": jnp.zeros((), jnp.int32)}
        if self.prefix:
            cache["prefix_layers"] = [
                init_block_cache(cfg, kind, batch, max_len, dtype)
                for kind, _ in self.prefix]
        if self.n_groups:
            def one_group(_):
                return {f"sub{i}": init_block_cache(cfg, kind, batch,
                                                    max_len, dtype)
                        for i, (kind, _) in enumerate(self.group)}
            if cfg.scan_layers:
                cache["groups"] = jax.vmap(one_group)(
                    jnp.arange(self.n_groups))
            else:
                cache["groups"] = [one_group(i)
                                   for i in range(self.n_groups)]
        return cache

    def decode_step(self, params: Params, tokens: jax.Array, cache: Params
                    ) -> Tuple[jax.Array, Params]:
        """One-token decode. tokens: [B, 1] (or [B, CB, 1] multi-codebook)."""
        cfg = self.cfg
        index = cache["index"]
        x = self.embed(params, tokens, None)                 # [B, 1, d]
        new_cache: Params = {"index": index + 1}
        if self.prefix:
            new_prefix = []
            for p_layer, c_layer, (kind, ffn) in zip(
                    params.get("prefix_layers", []), cache["prefix_layers"],
                    self.prefix):
                x, c = apply_block_decode(p_layer, cfg, kind, ffn, x,
                                          c_layer, index,
                                          self.window_slice,
                                          self.ring_cache)
                new_prefix.append(c)
            new_cache["prefix_layers"] = new_prefix
        if self.n_groups:
            def body(x, scanned):
                p_group, c_group = scanned
                new_c = {}
                for i, (kind, ffn) in enumerate(self.group):
                    x, c = apply_block_decode(p_group[f"sub{i}"], cfg, kind,
                                              ffn, x, c_group[f"sub{i}"],
                                              index, self.window_slice,
                                              self.ring_cache)
                    new_c[f"sub{i}"] = c
                return x, new_c
            if cfg.scan_layers:
                x, new_groups = lax.scan(body, x,
                                         (params["groups"], cache["groups"]))
            else:
                new_groups = []
                for p_group, c_group in zip(params["groups"],
                                            cache["groups"]):
                    x, c = body(x, (p_group, c_group))
                    new_groups.append(c)
            new_cache["groups"] = new_groups
        x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self.unembed(params, x)
        return logits, new_cache

    def prefill(self, params: Params, tokens: jax.Array, cache: Params,
                prefix_emb: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Params]:
        """Run the full prompt through the model, filling the decode cache.

        Attention layers write K/V for positions [0, S); SSM layers store
        their final recurrent + conv state.  Returns full-sequence logits
        and the filled cache (index advanced to S).
        """
        cfg = self.cfg
        x = self.embed(params, tokens, prefix_emb)
        s = x.shape[1]
        positions = jnp.arange(s)
        new_cache: Params = {"index": cache["index"] + s}
        if self.prefix:
            new_prefix = []
            for p_layer, c_layer, (kind, ffn) in zip(
                    params.get("prefix_layers", []), cache["prefix_layers"],
                    self.prefix):
                x, c = apply_block_fill(p_layer, cfg, kind, ffn, x,
                                        positions, c_layer, self.attn_impl,
                                        self.window_slice,
                                        self.use_ssd_kernel,
                                        self.ring_cache)
                new_prefix.append(c)
            new_cache["prefix_layers"] = new_prefix
        if self.n_groups:
            def body(x, scanned):
                p_group, c_group = scanned
                new_c = {}
                for i, (kind, ffn) in enumerate(self.group):
                    x, c = apply_block_fill(
                        p_group[f"sub{i}"], cfg, kind, ffn, x, positions,
                        c_group[f"sub{i}"], self.attn_impl,
                        self.window_slice, self.use_ssd_kernel,
                        self.ring_cache)
                    new_c[f"sub{i}"] = c
                return x, new_c
            if cfg.scan_layers:
                x, new_groups = lax.scan(body, x,
                                         (params["groups"], cache["groups"]))
            else:
                new_groups = []
                for p_group, c_group in zip(params["groups"],
                                            cache["groups"]):
                    x, c = body(x, (p_group, c_group))
                    new_groups.append(c)
            new_cache["groups"] = new_groups
        x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = self.unembed(params, x)
        return logits, new_cache
