"""Mamba-2 (SSD — state-space duality) mixer layer. [arXiv:2405.21060]

Chunked SSD reference in pure jnp (the oracle for the Pallas ``ssd_scan``
kernel) plus the single-token recurrent decode step.  Single B/C group
(ngroups=1), scalar-per-head A — the Mamba-2 defaults.

Layer structure (Mamba-2 block):
    in_proj -> [z | x | B | C | dt]
    causal depthwise conv + silu over (x, B, C)
    y = SSD(x * dt, dt*A, B, C) + D * x
    out = out_proj( rmsnorm(y * silu(z)) )
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.layers import dense_init, init_rms_norm, rms_norm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# SSD core (chunked scan) — pure jnp oracle
# ---------------------------------------------------------------------------


def segsum(x: jax.Array) -> jax.Array:
    """Segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i >= j)."""
    l = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    out = csum[..., :, None] - csum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_reference(x: jax.Array, dA: jax.Array, b_mat: jax.Array,
                  c_mat: jax.Array, chunk: int,
                  initial_state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  [B, S, H, P]  (pre-scaled by dt)
    dA: [B, S, H]     (dt * A, negative)
    b_mat, c_mat: [B, S, N]  (single group, shared across heads)
    Returns (y [B, S, H, P], final_state [B, H, P, N]).
    S must be divisible by ``chunk``.
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    xc = x.reshape(bsz, c, chunk, h, p).astype(jnp.float32)
    bc = b_mat.reshape(bsz, c, chunk, n).astype(jnp.float32)
    cc = c_mat.reshape(bsz, c, chunk, n).astype(jnp.float32)
    a = dA.reshape(bsz, c, chunk, h).transpose(0, 3, 1, 2)  # [B,H,C,L]
    a = a.astype(jnp.float32)
    a_cs = jnp.cumsum(a, axis=-1)

    # 1) intra-chunk (diagonal blocks)
    decay = jnp.exp(segsum(a))                               # [B,H,C,L,L]
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        cc, bc, decay, xc)

    # 2) per-chunk states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)            # [B,H,C,L]
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        bc, decay_states, xc)                # [B,C,H,P,N]

    # 3) inter-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)
    states = jnp.concatenate(
        [initial_state[:, None].astype(jnp.float32), states], axis=1)
    chunk_decay = a_cs[..., -1]                              # [B,H,C]
    dc = jnp.exp(segsum(jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))))
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", dc, states)  # [B,C+1,H,P,N]
    prev_states, final_state = new_states[:, :-1], new_states[:, -1]

    # 4) state -> output (off-diagonal contribution)
    out_decay = jnp.exp(a_cs)                                # [B,H,C,L]
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cc, prev_states, out_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_recurrent_step(state: jax.Array, x_t: jax.Array, da_t: jax.Array,
                       b_t: jax.Array, c_t: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence.

    state: [B, H, P, N]; x_t: [B, H, P] (pre-scaled by dt);
    da_t: [B, H]; b_t, c_t: [B, N].
    Returns (y_t [B, H, P], new_state).
    """
    decay = jnp.exp(da_t.astype(jnp.float32))[..., None, None]   # [B,H,1,1]
    outer = (x_t.astype(jnp.float32)[..., None]
             * b_t.astype(jnp.float32)[:, None, None, :])        # [B,H,P,N]
    new_state = state * decay + outer
    y = jnp.einsum("bhpn,bn->bhp", new_state,
                   c_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba-2 mixer layer
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    mc = cfg.mamba
    di = mc.d_inner(d)
    nh = mc.n_heads(d)
    n = mc.d_state
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 4)
    # dt bias init: softplus^-1 of dt in [1e-3, 1e-1] (mamba default ~ 0.001..0.1)
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32)
                 * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))      # inverse softplus
    return {
        "norm": init_rms_norm(d),
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + nh), in_axis_size=d),
        "conv_w": dense_init(ks[1], (mc.d_conv, conv_ch), in_axis_size=mc.d_conv),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "gate_norm": init_rms_norm(di),
        "out_proj": dense_init(ks[3], (di, d), in_axis_size=di),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d = cfg.d_model
    mc = cfg.mamba
    di = mc.d_inner(d)
    n = mc.d_state
    nh = mc.n_heads(d)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xbc, dt, di, n, nh


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv over sequence. xbc: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    if prev is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = prev.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)          # [B, S+K-1, C]
    out = sum(xp[:, i: i + xbc.shape[1]] * w[i].astype(xbc.dtype)
              for i in range(k))
    return jax.nn.silu(out + b.astype(xbc.dtype))


def mamba_mixer(params: Params, cfg: ModelConfig, x: jax.Array,
                use_kernel: bool = False) -> jax.Array:
    """Full-sequence Mamba-2 mixer (train / prefill). x: [B, S, d]."""
    y, _ = mamba_mixer_with_state(params, cfg, x, use_kernel=use_kernel)
    return y


def mamba_mixer_with_state(params: Params, cfg: ModelConfig, x: jax.Array,
                           use_kernel: bool = False
                           ) -> Tuple[jax.Array, Params]:
    """Mixer that also returns the decode cache (final SSM + conv state)."""
    dtype = x.dtype
    mc = cfg.mamba
    zxbcdt = x @ params["in_proj"].astype(dtype)
    z, xbc_raw, dt, di, n, nh = _split_proj(cfg, zxbcdt)
    # conv cache: the last (d_conv - 1) *raw* channel inputs
    k1 = mc.d_conv - 1
    if xbc_raw.shape[1] >= k1:
        conv_tail = xbc_raw[:, -k1:] if k1 else xbc_raw[:, :0]
    else:
        conv_tail = jnp.pad(xbc_raw,
                            ((0, 0), (k1 - xbc_raw.shape[1], 0), (0, 0)))
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs = xbc[..., :di]
    b_mat = xbc[..., di: di + n]
    c_mat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])                    # [B,S,H]
    a = -jnp.exp(params["A_log"])                                # [H]
    xh = xs.reshape(*xs.shape[:2], nh, mc.head_dim)              # [B,S,H,P]
    x_scaled = xh * dt[..., None].astype(dtype)
    da = dt * a                                                  # [B,S,H]
    s = x.shape[1]
    chunk = min(mc.chunk_size, s)
    if s % chunk != 0:  # pad to a chunk multiple (masked timesteps decay=1,x=0)
        pad = chunk - s % chunk
        x_scaled = jnp.pad(x_scaled, ((0, 0), (0, pad), (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, final_state = ssd_ops.ssd(x_scaled, da, b_mat, c_mat, chunk)
    else:
        y, final_state = ssd_reference(x_scaled, da, b_mat, c_mat, chunk)
    y = y[:, :s]
    y = y + xh * params["D"].astype(dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:2], di)
    y = rms_norm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(dtype)
    return out, {"conv": conv_tail, "ssm": final_state}


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    d = cfg.d_model
    mc = cfg.mamba
    di = mc.d_inner(d)
    n = mc.d_state
    nh = mc.n_heads(d)
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, di + 2 * n), dtype),
        "ssm": jnp.zeros((batch, nh, mc.head_dim, n), jnp.float32),
    }


def mamba_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 cache: Params) -> Tuple[jax.Array, Params]:
    """One-token recurrent step. x: [B, 1, d]."""
    dtype = x.dtype
    mc = cfg.mamba
    zxbcdt = x @ params["in_proj"].astype(dtype)
    z, xbc, dt, di, n, nh = _split_proj(cfg, zxbcdt)
    # conv over (cached window + new token)
    conv_in = jnp.concatenate([cache["conv"].astype(dtype), xbc], axis=1)
    w = params["conv_w"].astype(dtype)
    out = sum(conv_in[:, i: i + 1] * w[i] for i in range(mc.d_conv))
    xbc_t = jax.nn.silu(out + params["conv_b"].astype(dtype))    # [B,1,C]
    new_conv = conv_in[:, 1:]
    xs = xbc_t[..., :di]
    b_t = xbc_t[:, 0, di: di + n]
    c_t = xbc_t[:, 0, di + n:]
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                           + params["dt_bias"])                  # [B,H]
    a = -jnp.exp(params["A_log"])
    xh = xs[:, 0].reshape(-1, nh, mc.head_dim)                   # [B,H,P]
    y_t, new_ssm = ssd_recurrent_step(
        cache["ssm"], xh * dt_t[..., None].astype(dtype), dt_t * a, b_t, c_t)
    y_t = y_t + xh * params["D"].astype(dtype)[None, :, None]
    y = y_t.reshape(-1, 1, di)
    y = rms_norm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = y @ params["out_proj"].astype(dtype)
    return y, {"conv": new_conv, "ssm": new_ssm}
