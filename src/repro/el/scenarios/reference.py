"""Host-side replay oracle for the in-graph scenario engine.

The compiled scenario programs (``make_sync_cell`` with
``cfg.scenario`` set) gather their churn/straggler schedules from the
replayed ``scn_active`` / ``scn_mult`` knob arrays and do all masking,
charging and pacing in-graph.  This module re-derives the same run in
plain numpy — mask per round, slowest-ACTIVE-edge slot, per-edge
charging, loop termination — from nothing but the config and the
compiled run's per-round ``interval`` decisions, and checks the
compiled history EVENT-FOR-EVENT against it.

That is the correctness bar the scenario engine is held to: the traced
mask arithmetic (``jnp.where`` chains inside a ``lax.while_loop``) must
agree with the obvious sequential bookkeeping a human would write down.
Arm choices themselves are not re-derived (they come from traced PRNG
streams); everything *downstream* of each choice is.

Restricted to ``cost_noise == 0`` runs: with the i.i.d. cost noise off
the multiplier is exactly 1.0, every per-round float32 op here mirrors
the compiled elementwise op, and the replay matches bit-for-bit, not
just to tolerance.
"""

from __future__ import annotations

from typing import Any, Dict

import numpy as np

from repro.config import OL4ELConfig
from repro.el.scenarios.schedule import activity_schedule, cost_schedule
from repro.el.scenarios.spec import ScenarioSpec

__all__ = ["replay_sync_scenario", "verify_sync_replay"]


def _schedules(cfg: OL4ELConfig):
    """The exact [period, E] knob arrays the compiled run gathered from
    (same host-side generators that built them — the replay shares the
    schedule SOURCE and re-derives everything downstream of it)."""
    scn = cfg.scenario
    if not isinstance(scn, ScenarioSpec):
        raise TypeError(
            f"cfg.scenario must be a ScenarioSpec for a scenario replay, "
            f"got {type(scn).__name__}")
    period = scn.period
    active = activity_schedule(scn.churn, cfg.n_edges, period)
    mult = cost_schedule(scn.cost, cfg.n_edges, period)
    return period, active, mult


def replay_sync_scenario(cfg: OL4ELConfig,
                         intervals: np.ndarray,
                         max_rounds: int) -> Dict[str, np.ndarray]:
    """Sequentially replay a sync scenario run from its arm decisions.

    ``intervals`` is the compiled run's per-round ``hist["interval"]``
    (only entries below the replayed round count are read).  Returns the
    replayed per-round histories plus the replay's own termination
    round — everything :func:`verify_sync_replay` compares.
    """
    if cfg.cost_noise != 0:
        raise ValueError(
            "the scenario replay oracle is exact only for cost_noise=0 "
            f"runs (got cost_noise={cfg.cost_noise}); noisy multipliers "
            "come from traced PRNG streams the host does not re-derive")
    from repro.el.ingraph import sync_knobs
    period, sched_act, sched_mult = _schedules(cfg)
    knobs = sync_knobs(cfg)
    comp = knobs["comp"].astype(np.float32)
    comm = knobs["comm"].astype(np.float32)
    costs_k = knobs["costs_k"].astype(np.float32)
    min_edge_cost = knobs["min_edge_cost"].astype(np.float32)
    budget = np.float32(knobs["budget"])

    consumed = np.zeros(cfg.n_edges, np.float32)
    wall = np.float32(0.0)
    hist = {"active_edges": np.zeros(max_rounds, np.int32),
            "consumed": np.zeros(max_rounds, np.float32),
            "wall": np.zeros(max_rounds, np.float32),
            "slot": np.zeros(max_rounds, np.float32)}
    t = 0
    while t < max_rounds:
        act = sched_act[t % period] > 0                          # [E]
        resid = budget - consumed
        # cond_scn verbatim: pace on the tightest ACTIVE edge
        affordable = (np.min(np.where(act, resid, np.inf))
                      >= np.min(costs_k) - 1e-12)
        exhausted = bool(np.any(act & (resid < min_edge_cost)))
        if not (affordable and not exhausted):
            break
        interval = np.int32(intervals[t])
        # body_scn bookkeeping verbatim (float32 elementwise, so the
        # replay is bit-exact against the compiled history)
        round_costs = (np.float32(interval) * comp + comm).astype(
            np.float32)
        round_costs = (round_costs * sched_mult[t % period]).astype(
            np.float32)
        slot = np.float32(np.max(np.where(act, round_costs,
                                          np.float32(0.0))))
        consumed = (consumed + np.where(act, slot,
                                        np.float32(0.0))).astype(
            np.float32)
        wall = np.float32(wall + slot)
        hist["active_edges"][t] = int(np.sum(act))
        hist["consumed"][t] = np.float32(np.sum(consumed))
        hist["wall"][t] = wall
        hist["slot"][t] = slot
        t += 1
    hist["n_rounds"] = np.int32(t)
    hist["budgets_left"] = budget - consumed
    return hist


def verify_sync_replay(cfg: OL4ELConfig, out: Dict[str, Any],
                       max_rounds: int) -> Dict[str, np.ndarray]:
    """Assert a compiled sync scenario run matches its host replay
    event-for-event; returns the replay on success.

    ``out`` is the compiled run's output dict (``report.raw`` /
    ``run_sweep_program`` cell slice): per-round ``interval`` /
    ``active_edges`` / ``consumed`` / ``wall``, plus ``n_rounds`` and
    ``budgets_left``.  Every round's active-edge count must agree
    exactly; budget/wall bookkeeping must agree to float32 round-off
    (identical elementwise ops — in practice bit-equal on CPU); the two
    loops must terminate on the SAME round.
    """
    ref = replay_sync_scenario(cfg, np.asarray(out["interval"]),
                               max_rounds)
    n = int(out["n_rounds"])
    if n != int(ref["n_rounds"]):
        raise AssertionError(
            f"termination mismatch: compiled ran {n} rounds, replay "
            f"predicts {int(ref['n_rounds'])}")
    got_act = np.asarray(out["active_edges"])[:n]
    want_act = ref["active_edges"][:n]
    if not np.array_equal(got_act, want_act):
        bad = int(np.flatnonzero(got_act != want_act)[0])
        raise AssertionError(
            f"active-edge mismatch at round {bad}: compiled "
            f"{got_act[bad]}, replay {want_act[bad]}")
    for name in ("consumed", "wall"):
        got = np.asarray(out[name])[:n]
        want = ref[name][:n]
        if not np.allclose(got, want, rtol=1e-5, atol=1e-5):
            bad = int(np.flatnonzero(
                ~np.isclose(got, want, rtol=1e-5, atol=1e-5))[0])
            raise AssertionError(
                f"{name} mismatch at round {bad}: compiled "
                f"{got[bad]!r}, replay {want[bad]!r}")
    if not np.allclose(np.asarray(out["budgets_left"]),
                       ref["budgets_left"], rtol=1e-5, atol=1e-5):
        raise AssertionError(
            f"budgets_left mismatch: compiled "
            f"{np.asarray(out['budgets_left'])!r}, replay "
            f"{ref['budgets_left']!r}")
    return ref
