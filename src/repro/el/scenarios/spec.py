"""``ScenarioSpec`` — frozen, hashable fleet-dynamics configuration.

Real edge fleets are not the fixed, always-on fleet the base simulator
assumes: devices drop out and reconnect (churn), per-block costs spike
heavy-tailed (stragglers), and local data distributions drift.  A
``ScenarioSpec`` describes those dynamics declaratively; the scenario
engine (``repro.el.scenarios.schedule``) materializes it host-side into
*traced* ``[period, n_edges]`` schedule knobs that ride into the
compiled EL programs exactly like every other control-plane input — so
one compiled program serves any churn rate / cost trace, and
``repro.el.sweep`` can stack scenario points along the cell axis.

The spec is frozen + hashable on purpose (the ``TelemetrySpec``
discipline): it lives on ``OL4ELConfig.scenario`` and therefore joins
the session's compile-cache keys and the fleet's cohort bucketing via
``ELSession._structural_cfg`` — but only its *structural* residue (the
schedule ``period``, which sizes the knob arrays, and whether the
scenario is on at all).  Rates, seeds and trace values are knob VALUES:
:meth:`ScenarioSpec.structural` normalizes them away so nearby scenario
points share one executable.

``scenario=None`` (the default everywhere) builds today's programs
bit-for-bit — the scenario branch is statically absent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

#: default schedule period (rounds sync / events async) before the
#: pattern repeats; structural (it sizes the [period, n_edges] knobs).
DEFAULT_PERIOD = 64

#: churn schedule generators
CHURN_KINDS = ("dropout", "trace")
#: per-edge cost-multiplier models (heavy-tailed draws are materialized
#: host-side into a replayed [period, n_edges] schedule)
COST_KINDS = ("pareto", "lognormal", "trace")


@dataclasses.dataclass(frozen=True)
class ChurnSpec:
    """Per-edge activity schedule: who is in the fleet each round/event.

    ``kind="dropout"`` draws an i.i.d. Bernoulli schedule — each edge is
    *inactive* with probability ``rate`` in each of the ``period`` slots
    (seeded, so the schedule is reproducible and sweepable); at least
    ``min_active`` edges stay active in every slot (the lowest-index
    dropped edges are revived).  ``kind="trace"`` replays an explicit
    0/1 schedule (``trace`` holds ``period`` rows of ``n_edges``
    flags — join/leave/reconnect patterns from real fleet logs).
    """

    kind: str = "dropout"
    rate: float = 0.1
    period: int = DEFAULT_PERIOD
    min_active: int = 1
    seed: int = 0
    trace: Tuple[Tuple[int, ...], ...] = ()

    def __post_init__(self):
        if self.kind not in CHURN_KINDS:
            raise ValueError(
                f"ChurnSpec.kind must be one of {CHURN_KINDS}, got "
                f"{self.kind!r}")
        if not 0.0 <= self.rate < 1.0:
            raise ValueError(
                f"ChurnSpec.rate is a per-slot dropout probability and "
                f"must be in [0, 1), got {self.rate}")
        if self.trace:
            object.__setattr__(self, "trace",
                               tuple(tuple(int(v) for v in row)
                                     for row in self.trace))
            object.__setattr__(self, "period", len(self.trace))
        if self.period < 1:
            raise ValueError(
                f"ChurnSpec.period must be >= 1, got {self.period}")
        if self.kind == "trace" and not self.trace:
            raise ValueError("ChurnSpec(kind='trace') needs trace= rows")


@dataclasses.dataclass(frozen=True)
class CostSpec:
    """Per-edge cost-multiplier schedule: stragglers and trace replay.

    Heavy-tailed kinds draw a seeded ``[period, n_edges]`` multiplier
    schedule host-side — ``"pareto"`` via inverse-CDF
    ``(1-u)^(-1/alpha)`` (multipliers >= 1: pure straggler spikes),
    ``"lognormal"`` via ``exp(sigma * N(0,1))`` — which the compiled
    program replays cyclically; ``"trace"`` replays explicit multiplier
    rows (e.g. measured per-device round times normalized to their
    mean).  Multipliers compose with the base ``cost_noise`` knob.
    """

    kind: str = "pareto"
    alpha: float = 2.0
    sigma: float = 0.5
    period: int = DEFAULT_PERIOD
    seed: int = 0
    trace: Tuple[Tuple[float, ...], ...] = ()

    def __post_init__(self):
        if self.kind not in COST_KINDS:
            raise ValueError(
                f"CostSpec.kind must be one of {COST_KINDS}, got "
                f"{self.kind!r}")
        if self.alpha <= 1.0:
            raise ValueError(
                f"CostSpec.alpha is a Pareto tail index and must be > 1 "
                f"(finite mean), got {self.alpha}")
        if self.sigma < 0.0:
            raise ValueError(
                f"CostSpec.sigma must be >= 0, got {self.sigma}")
        if self.trace:
            object.__setattr__(self, "trace",
                               tuple(tuple(float(v) for v in row)
                                     for row in self.trace))
            object.__setattr__(self, "period", len(self.trace))
        if self.period < 1:
            raise ValueError(
                f"CostSpec.period must be >= 1, got {self.period}")
        if self.kind == "trace":
            if not self.trace:
                raise ValueError("CostSpec(kind='trace') needs trace= rows")
            if any(v <= 0 for row in self.trace for v in row):
                raise ValueError(
                    "CostSpec trace multipliers must be positive")


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One fleet-dynamics scenario: churn x cost spikes x data drift.

    All three parts default off — ``ScenarioSpec()`` is the *identity*
    scenario (every edge active, all multipliers 1, no drift): it runs
    the scenario-path program (mask-aware aggregation, the policy
    switch), which is numerically equivalent to — but a different
    compiled program from — ``scenario=None``.  Only ``scenario=None``
    is bit-identical to the pre-scenario programs.

    ``drift`` is non-stationary data drift: each round/event ``t`` the
    minibatch sampler's index window rotates by
    ``floor(drift * t * n_samples_e)`` positions, so the effective
    local distribution moves over the edge's shard (``0.0`` = i.i.d.
    sampling, today's behavior).
    """

    churn: Optional[ChurnSpec] = None
    cost: Optional[CostSpec] = None
    drift: float = 0.0

    def __post_init__(self):
        if self.drift < 0.0:
            raise ValueError(
                f"ScenarioSpec.drift must be >= 0, got {self.drift}")

    @property
    def period(self) -> int:
        """The combined schedule length (the lcm of the parts' periods):
        the static leading dim of the materialized ``[period, n_edges]``
        scenario knobs — structural, like a telemetry ring size."""
        parts = [p.period for p in (self.churn, self.cost)
                 if p is not None]
        if not parts:
            return 1
        return math.lcm(*parts)

    def structural(self) -> "ScenarioSpec":
        """The compile-relevant residue: rates/seeds/trace values are
        knob VALUES (they only change the materialized schedule arrays),
        so they normalize away — only the schedule period (it sizes the
        traced arrays) and which parts are present survive into compile
        cache / cohort keys."""
        return ScenarioSpec(
            churn=(None if self.churn is None
                   else ChurnSpec(period=self.churn.period)),
            cost=(None if self.cost is None
                  else CostSpec(period=self.cost.period)),
            drift=0.0)


def as_scenario(scenario) -> Optional[ScenarioSpec]:
    """Normalize a user-facing scenario value: ``None``/``False`` → off
    (the programs compile bit-identical to the scenario-less ones), a
    ``ScenarioSpec`` passes through, ``True`` → the identity scenario.
    Anything else is a ``TypeError`` naming the accepted spellings."""
    if scenario is None or scenario is False:
        return None
    if scenario is True:
        return ScenarioSpec()
    if isinstance(scenario, ScenarioSpec):
        return scenario
    raise TypeError(
        f"scenario= expects None/bool/ScenarioSpec, got "
        f"{type(scenario).__name__}")
