"""Shared argparse glue for the fleet-dynamics scenario flags.

Both launchers (``repro.launch.train`` / ``repro.launch.sweep``) speak
the same scenario dialect:

    --churn 0.2                      # Bernoulli dropout schedule
    --churn-period 64                # schedule length before repeat
    --cost-model pareto              # straggler spikes (heavy-tailed)
    --cost-model trace:times.txt     # replay measured multipliers
    --drift 0.01                     # non-stationary data drift

``--cost-model`` keeps its classic values (``fixed`` / ``variable`` —
the base i.i.d. noise model) and gains the scenario cost KINDS: a
scenario kind leaves the base model ``fixed`` and rides in as a
``CostSpec`` multiplier schedule instead (the two compose — see
``repro.el.ingraph.support_matrix``).  A trace file is whitespace-
separated rows (``numpy.loadtxt``): one column broadcasts one
multiplier per slot to every edge, ``n_edges`` columns give per-edge
rows.
"""

from __future__ import annotations

import argparse
from typing import Optional, Tuple

import numpy as np

from repro.el.scenarios.spec import (ChurnSpec, CostSpec, DEFAULT_PERIOD,
                                     ScenarioSpec)

__all__ = ["add_scenario_args", "scenario_from_args",
           "BASE_COST_MODELS", "SCENARIO_COST_KINDS"]

#: the classic cfg.cost_model values (no scenario involved)
BASE_COST_MODELS = ("fixed", "variable")
#: --cost-model values routed into a CostSpec multiplier schedule
SCENARIO_COST_KINDS = ("pareto", "lognormal")


def _cost_model_value(value: str) -> str:
    v = str(value)
    if (v in BASE_COST_MODELS or v in SCENARIO_COST_KINDS
            or v.startswith("trace:")):
        return v
    raise argparse.ArgumentTypeError(
        f"--cost-model must be one of {BASE_COST_MODELS} (base noise "
        f"models), {SCENARIO_COST_KINDS} (scenario straggler schedules) "
        f"or trace:<path>, got {value!r}")


def add_scenario_args(ap: argparse.ArgumentParser, *,
                      cost_model_default: str = "fixed") -> None:
    """Install the scenario flag group (idempotent per parser)."""
    g = ap.add_argument_group(
        "fleet dynamics (repro.el.scenarios; any flag set compiles the "
        "scenario-path program — omit all for today's bit-identical one)")
    g.add_argument("--churn", type=float, default=None, metavar="RATE",
                   help="per-slot edge dropout probability in [0, 1): "
                        "draws a seeded Bernoulli activity schedule; "
                        "dropped edges run zero work, are not charged, "
                        "and rejoin per the schedule")
    g.add_argument("--churn-period", type=int, default=DEFAULT_PERIOD,
                   help="churn/cost schedule length in rounds (sync) or "
                        f"events (async) before it repeats (default "
                        f"{DEFAULT_PERIOD}; structural — it sizes the "
                        "traced schedule arrays)")
    g.add_argument("--cost-model", type=_cost_model_value,
                   default=cost_model_default,
                   help="fixed|variable (base noise model) or a scenario "
                        "straggler schedule: pareto|lognormal|"
                        "trace:<path> (whitespace rows of per-slot cost "
                        "multipliers; 1 or n_edges columns)")
    g.add_argument("--drift", type=float, default=None, metavar="RATE",
                   help="non-stationary data drift: each round t rotates "
                        "every edge's minibatch window by "
                        "drift*t*n_samples positions (0 = i.i.d.)")


def _cost_spec_from(value: str, period: int) -> Optional[CostSpec]:
    if value in BASE_COST_MODELS:
        return None
    if value.startswith("trace:"):
        path = value[len("trace:"):]
        rows = np.atleast_1d(np.loadtxt(path, dtype=np.float64))
        if rows.ndim == 1:
            rows = rows[:, None]
        return CostSpec(kind="trace",
                        trace=tuple(tuple(r) for r in rows))
    return CostSpec(kind=value, period=period)


def scenario_from_args(args) -> Tuple[Optional[ScenarioSpec], str]:
    """Resolve the flag group → ``(scenario_or_none, base_cost_model)``.

    ``base_cost_model`` is what ``cfg.cost_model`` should carry
    (``fixed``/``variable``); a scenario ``--cost-model`` kind maps to
    ``fixed`` there and to a ``CostSpec`` here.  Returns ``(None,
    base)`` when no scenario flag was touched, so default invocations
    build exactly today's programs.
    """
    period = int(args.churn_period)
    if period < 1:
        raise SystemExit(f"--churn-period must be >= 1, got {period}")
    churn = (None if args.churn is None
             else ChurnSpec(rate=float(args.churn), period=period))
    cost = _cost_spec_from(args.cost_model, period)
    base = args.cost_model if args.cost_model in BASE_COST_MODELS \
        else "fixed"
    drift = 0.0 if args.drift is None else float(args.drift)
    if churn is None and cost is None and drift == 0.0:
        return None, base
    return ScenarioSpec(churn=churn, cost=cost, drift=drift), base
