"""Host-side materialization of ``ScenarioSpec`` into traced knobs.

The scenario engine's whole contract with the compiled programs is three
extra knob arrays (plus, in sync mode, the policy selector):

- ``scn_active`` ``[period, n_edges]`` float32 0/1 — the activity
  schedule.  Round/event ``t`` reads row ``t % period``; a 0 means the
  edge is dropped out for that slot (zero masked work, zero aggregation
  weight, zero budget charge).
- ``scn_mult`` ``[period, n_edges]`` float32 > 0 — per-edge cost
  multipliers (heavy-tailed straggler spikes or replayed traces),
  composing with the base ``cost_noise`` model.
- ``scn_drift`` scalar float32 — non-stationary data drift rate for the
  minibatch sampler's rotating index window.
- ``policy_id`` scalar int32 (sync only) — selects the selection-policy
  branch of the in-graph ``lax.switch`` (OL4EL bandit vs the
  task-allocation baselines), so one compiled program benchmarks all
  registered in-graph policies.

Because these are ordinary knobs, everything downstream — sweep
stacking, fleet knob dispatch, mesh sharding (they are replicated /
cell-sharded like any other non-edge-dim knob) — works unchanged.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.config import OL4ELConfig
from repro.el.scenarios.spec import ChurnSpec, CostSpec, ScenarioSpec

#: Extra traced inputs the scenario path appends to ``KNOB_NAMES`` /
#: ``ASYNC_KNOB_NAMES``.  ``policy_id`` rides only in sync mode (the
#: async program keeps the paper's per-edge OL4EL bandit).
SCENARIO_KNOB_NAMES = ("scn_active", "scn_mult", "scn_drift")


def scenario_knob_names(mode: str) -> tuple:
    """The knob names the scenario path appends for ``mode``."""
    if mode == "sync":
        return SCENARIO_KNOB_NAMES + ("policy_id",)
    return SCENARIO_KNOB_NAMES


def activity_schedule(churn: Optional[ChurnSpec], n_edges: int,
                      period: int) -> np.ndarray:
    """The ``[period, n_edges]`` 0/1 activity schedule for ``churn``.

    ``None`` means always-on.  Dropout schedules are seeded and
    deterministic (the host reference replay re-derives the same rows);
    every row keeps at least ``min_active`` edges alive — the
    lowest-index dropped edges are revived, so sync rounds always have a
    straggler to pace on and the aggregation weights never normalize
    over an empty set.
    """
    if churn is None:
        return np.ones((period, n_edges), np.float32)
    if churn.kind == "trace":
        rows = np.asarray(churn.trace, np.float32)
        if rows.shape[1] != n_edges:
            raise ValueError(
                f"churn trace rows have {rows.shape[1]} edges, config "
                f"has {n_edges}")
        act = (rows > 0).astype(np.float32)
    else:  # "dropout"
        rng = np.random.default_rng(churn.seed)
        act = (rng.random((churn.period, n_edges))
               >= churn.rate).astype(np.float32)
    min_active = max(1, min(int(churn.min_active), n_edges))
    for row in act:
        short = min_active - int(row.sum())
        if short > 0:
            row[np.flatnonzero(row == 0)[:short]] = 1.0
    reps = period // act.shape[0]
    return np.tile(act, (reps, 1)) if reps > 1 else act


def cost_schedule(cost: Optional[CostSpec], n_edges: int,
                  period: int) -> np.ndarray:
    """The ``[period, n_edges]`` cost-multiplier schedule for ``cost``.

    ``None`` means all-ones.  Heavy-tailed kinds draw once, seeded, and
    the compiled program replays the schedule cyclically — "trace-
    replayed" in the generated case too, which keeps the in-graph side a
    single gather and the reference replay exact.
    """
    if cost is None:
        return np.ones((period, n_edges), np.float32)
    if cost.kind == "trace":
        mult = np.asarray(cost.trace, np.float32)
        if mult.shape[1] != n_edges:
            raise ValueError(
                f"cost trace rows have {mult.shape[1]} edges, config "
                f"has {n_edges}")
    else:
        rng = np.random.default_rng(cost.seed)
        if cost.kind == "pareto":
            # inverse-CDF Pareto(alpha): multipliers >= 1, mean
            # alpha/(alpha-1) — pure straggler spikes
            u = rng.random((cost.period, n_edges))
            mult = (1.0 - u) ** (-1.0 / cost.alpha)
        else:  # "lognormal"
            mult = np.exp(cost.sigma * rng.standard_normal(
                (cost.period, n_edges)))
        mult = mult.astype(np.float32)
    reps = period // mult.shape[0]
    return np.tile(mult, (reps, 1)) if reps > 1 else mult


def scenario_knobs(cfg: OL4ELConfig) -> Dict[str, np.ndarray]:
    """Materialize ``cfg.scenario`` into its traced knob arrays.

    Called by ``sync_knobs`` / ``async_knobs`` when a scenario is set;
    the sweep engine therefore stacks scenario knobs along the cell axis
    automatically, and the fleet's knob dispatch picks them up through
    the same functions.  Sync mode appends ``policy_id`` (resolved from
    ``cfg.policy`` against the in-graph policy switch).
    """
    scn = cfg.scenario
    if not isinstance(scn, ScenarioSpec):
        raise TypeError(
            f"cfg.scenario must be a ScenarioSpec (or None), got "
            f"{type(scn).__name__}")
    period = scn.period
    knobs: Dict[str, np.ndarray] = {
        "scn_active": activity_schedule(scn.churn, cfg.n_edges, period),
        "scn_mult": cost_schedule(scn.cost, cfg.n_edges, period),
        "scn_drift": np.float32(scn.drift),
    }
    if cfg.mode == "sync":
        from repro.el.scenarios.baselines import ingraph_policy_id
        knobs["policy_id"] = np.int32(ingraph_policy_id(cfg.policy))
    return knobs
