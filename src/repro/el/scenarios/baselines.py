"""In-graph selection-policy switch: OL4EL vs task-allocation baselines.

The scenario-path sync program routes arm selection through one
``lax.switch`` over these branches, selected by the traced ``policy_id``
knob — so "OL4EL vs baselines under churn" is ONE vmapped program with
``policy`` as an ordinary sweep axis, every cell sharing the executable.

Branch 0 is the OL4EL budget-limited UCB bandit, written with exactly
the ops the scenario-less program uses (``jax_selection_weights`` →
log-weights → ``categorical``).  Branches 1–2 are the PAPERS.md
task-allocation baselines:

- ``task_alloc`` — modeled on "Adaptive task allocation for mobile edge
  learning" (arXiv 1811.03748): allocate the largest locally-feasible
  workload each round (max updates per global sync the budget still
  covers), adapting to the residual instead of learning utilities.
- ``delay_energy`` — modeled on the delay/energy-constrained task
  allocation of arXiv 2012.00143: pace consumption so the budget lasts,
  picking the arm whose cost best matches a geometric pace
  ``sqrt(residual * min_cost)`` between spending-it-all-now and the
  cheapest sustainable rate.

All branches share the signature ``(bstate, resid, costs, ucb_c, key)
-> arm`` (int32); only OL4EL consumes the bandit state and the key, but
a uniform signature is what ``lax.switch`` requires.  Feasibility is
guaranteed by the loop condition (the program only enters the body while
the binding edge can afford the cheapest arm), matching the bandit
branch's assumption.

Host-loop counterparts are registered in ``repro.el.policies`` under the
same names; ``INGRAPH_POLICY_ORDER`` is the switch's branch order and
the single source of truth for which policies the compiled scenario
program implements.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bandit import jax_selection_weights

#: ``lax.switch`` branch order; index == the ``policy_id`` knob value.
INGRAPH_POLICY_ORDER = ("ol4el", "task_alloc", "delay_energy")


def ingraph_policy_id(name: str) -> int:
    """The ``policy_id`` knob value for a registry policy name."""
    if name not in INGRAPH_POLICY_ORDER:
        raise ValueError(
            f"policy {name!r} has no in-graph scenario branch; the "
            f"compiled policy switch implements {INGRAPH_POLICY_ORDER} "
            f"(other registry policies run host-side only)")
    return INGRAPH_POLICY_ORDER.index(name)


def _ol4el_arm(bstate, resid, costs, ucb_c, key):
    # the exact selection ops of the scenario-less sync program
    w = jax_selection_weights(bstate, resid, costs, ucb_c)
    logits = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-30)), -jnp.inf)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def _task_alloc_arm(bstate, resid, costs, ucb_c, key):
    # largest feasible workload: arm order == interval order, so the
    # max feasible index is the max updates-per-sync the budget covers
    feasible = costs <= resid + 1e-12
    arms = jnp.arange(costs.shape[0], dtype=jnp.int32)
    return jnp.max(jnp.where(feasible, arms, -1)).astype(jnp.int32)


def _delay_energy_arm(bstate, resid, costs, ucb_c, key):
    # budget pacing: target cost = geometric mean of "spend the whole
    # residual now" and "spend the cheapest sustainable amount"
    min_c = jnp.min(costs)
    pace = jnp.sqrt(jnp.maximum(resid, min_c) * min_c)
    feasible = costs <= resid + 1e-12
    score = jnp.where(feasible, jnp.abs(costs - pace), jnp.inf)
    return jnp.argmin(score).astype(jnp.int32)


_BRANCHES = (_ol4el_arm, _task_alloc_arm, _delay_energy_arm)


def select_arm_switch(policy_id, bstate, resid, costs, ucb_c, key):
    """Traced arm selection: dispatch on the ``policy_id`` knob."""
    return jax.lax.switch(policy_id, _BRANCHES, bstate, resid, costs,
                          ucb_c, key)
