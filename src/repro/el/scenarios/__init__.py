"""repro.el.scenarios — in-graph fleet dynamics for the compiled EL stack.

Churn (join/leave/dropout/reconnect activity masks), heavy-tailed and
trace-replayed per-edge cost models (straggler spikes), non-stationary
data drift, and real task-allocation baseline policies — all injected
*inside* the compiled sync/async programs as traced schedule knobs, so
every scenario axis is sweepable and "OL4EL vs baselines under churn" is
one vmapped program.  ``scenario=None`` keeps every program bit-identical
to the scenario-less build.

Layout:

- ``spec``      — frozen+hashable ``ScenarioSpec``/``ChurnSpec``/``CostSpec``
- ``schedule``  — host-side knob materialization (``scenario_knobs``)
- ``baselines`` — the in-graph policy switch + PAPERS.md baselines
- ``reference`` — host-side replay oracles for churn schedules
- ``cli``       — shared ``--churn/--cost-model/--drift`` argparse glue
"""

from repro.el.scenarios.spec import (ChurnSpec, CostSpec, ScenarioSpec,
                                     as_scenario)
from repro.el.scenarios.schedule import (SCENARIO_KNOB_NAMES,
                                         activity_schedule, cost_schedule,
                                         scenario_knob_names,
                                         scenario_knobs)
from repro.el.scenarios.baselines import (INGRAPH_POLICY_ORDER,
                                          ingraph_policy_id,
                                          select_arm_switch)
from repro.el.scenarios.reference import (replay_sync_scenario,
                                          verify_sync_replay)

__all__ = [
    "ChurnSpec", "CostSpec", "ScenarioSpec", "as_scenario",
    "SCENARIO_KNOB_NAMES", "activity_schedule", "cost_schedule",
    "scenario_knob_names", "scenario_knobs",
    "INGRAPH_POLICY_ORDER", "ingraph_policy_id", "select_arm_switch",
    "replay_sync_scenario", "verify_sync_replay",
]
