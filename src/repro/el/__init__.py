"""``repro.el`` — the unified edge-cloud collaborative-learning runtime.

The public surface of the OL4EL reproduction:

  * :class:`ELSession` — configure-then-run façade (host sync/async loops
    plus the compiled ``run_sync_ingraph`` / ``run_async_ingraph`` fast
    paths);
  * :mod:`repro.el.events` — the compiled async event-horizon engine
    (argmin finish-times + staleness-weighted masked merges, no host
    priority queue);
  * :class:`ELReport` / :class:`RoundRecord` — run artifacts;
  * :mod:`repro.el.policies` — first-class collaboration strategies behind
    a registry (``policies.get("ol4el")``);
  * :class:`EdgeExecutor` — the typed data-plane Protocol executors
    implement (``ClassicExecutor`` / ``LMExecutor`` satisfy it);
  * :mod:`repro.el.sweep` — declarative ablation grids
    (:class:`SweepSpec`) run as ONE vmapped, mesh-shardable compiled
    program via ``ELSession.sweep(spec)`` → :class:`SweepReport`;
  * :mod:`repro.el.fleet` — multi-tenant EL-as-a-service:
    :class:`FleetServer` buckets :class:`TenantRun` submissions into
    cohorts (one compiled slot-batch program per structural config)
    and streams per-tenant reports as slot waves complete;
  * :mod:`repro.el.scenarios` — in-graph fleet dynamics:
    :class:`ScenarioSpec` churn/straggler/drift schedules injected into
    the compiled programs as traced knobs, plus the baseline-policy
    switch the OL4EL-vs-competitors curves run through.
"""

from repro.el import policies
from repro.el.executor import (EdgeExecutor, InGraphExecutor,
                               validate_executor)
from repro.el.fleet import (FleetServer, ReportReady, RoundDelta,
                            TenantRun)
from repro.el.report import ELReport, RoundRecord
from repro.el.scenarios import ChurnSpec, CostSpec, ScenarioSpec
from repro.el.session import ELSession
from repro.el.sweep import SweepReport, SweepSpec

__all__ = [
    "ELSession", "ELReport", "RoundRecord", "EdgeExecutor",
    "InGraphExecutor", "validate_executor", "policies",
    "SweepSpec", "SweepReport",
    "FleetServer", "TenantRun", "RoundDelta", "ReportReady",
    "ScenarioSpec", "ChurnSpec", "CostSpec",
]
