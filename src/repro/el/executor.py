"""The typed edge data-plane interface the EL runtime drives.

``EdgeExecutor`` makes the previously implicit ``local_train/evaluate``
duck interface an explicit, runtime-checkable Protocol.  The two concrete
executors (``repro.federated.executors.ClassicExecutor`` for SVM/K-means
and ``LMExecutor`` for language models) satisfy it structurally — no
inheritance needed; third-party executors only have to match the shapes.

``InGraphExecutor`` is the narrower contract the compiled sync fast path
needs (raw per-edge arrays + a jittable model) — only ``ClassicExecutor``
satisfies it today.
"""

from __future__ import annotations

from typing import (Any, Dict, List, Protocol, Tuple, runtime_checkable)

import numpy as np

Params = Any


@runtime_checkable
class EdgeExecutor(Protocol):
    """One edge server's training/eval surface.

    ``local_train`` runs ``n_iters`` local iterations for ``edge`` starting
    from ``params`` and returns the updated params plus an info dict;
    ``evaluate`` computes cloud-side metrics (the utility estimator and the
    report both read them).
    """

    def local_train(self, params: Params, edge: int, n_iters: int,
                    seed: int) -> Tuple[Params, Dict]:
        ...

    def evaluate(self, params: Params) -> Dict[str, float]:
        ...


@runtime_checkable
class InitCapable(Protocol):
    """Executors that can produce their own initial parameters."""

    def init_params(self, seed: int) -> Params:
        ...


@runtime_checkable
class InGraphExecutor(Protocol):
    """What ``ELSession.run_sync_ingraph`` additionally needs: the jittable
    model plus raw per-edge datasets so the whole budgeted loop can be
    staged into one XLA program."""

    model: Any
    edge_data: List[Dict[str, np.ndarray]]
    eval_set: Dict[str, Any]
    batch: int
    lr: float

    def local_train(self, params: Params, edge: int, n_iters: int,
                    seed: int) -> Tuple[Params, Dict]:
        ...

    def evaluate(self, params: Params) -> Dict[str, float]:
        ...


def validate_executor(ex: Any) -> None:
    """Fail fast (with a useful message) on malformed executors."""
    missing = [m for m in ("local_train", "evaluate")
               if not callable(getattr(ex, m, None))]
    if missing:
        raise TypeError(
            f"{type(ex).__name__} does not satisfy EdgeExecutor: "
            f"missing callable(s) {missing}; see repro.el.executor")
