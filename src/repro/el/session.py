"""``ELSession`` — the single façade over the OL4EL runtime.

    from repro.el import ELSession

    report = (ELSession(cfg)
              .with_executor(executor)            # any EdgeExecutor
              .with_policy("ol4el")               # name or Policy object
              .on_round(lambda rec: ...)          # streaming callbacks
              .run())                             # -> ELReport

One session owns the whole paper pipeline: the cloud coordinator (budgets
+ bandit), the utility estimator, the host-driven sync/async loops (the
§V simulator semantics), and — for jax-pure executors — the compiled
``run_sync_ingraph`` fast path that stages the entire budgeted loop into
one XLA program (see ``repro.el.ingraph``).

The legacy ``repro.federated.ELSimulator`` is now a deprecation shim over
this class.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.config import ExperimentConfig, OL4ELConfig
from repro.core.coordinator import CloudCoordinator
from repro.core.utility import UtilityEstimator, param_l2_delta
from repro.el import policies as el_policies
from repro.el.cache import ProgramCache
from repro.el.executor import EdgeExecutor, validate_executor
from repro.el.report import (ELReport, RoundRecord, records_from_out,
                             report_from_out)

Params = Any
RoundCallback = Callable[[RoundRecord], None]


class ELSession:
    """Configure-then-run handle for one edge-cloud collaborative run."""

    def __init__(self, cfg: Union[OL4ELConfig, ExperimentConfig], *,
                 metric_name: str = "accuracy", lr: float = 0.1,
                 async_alpha: Optional[float] = None):
        if isinstance(cfg, ExperimentConfig):
            cfg = cfg.ol4el
        if async_alpha is not None:        # override the config's knob
            cfg = dataclasses.replace(cfg, async_alpha=float(async_alpha))
        self.cfg = cfg
        self.metric_name = metric_name
        self.lr = lr
        self._executor: Optional[EdgeExecutor] = None
        self._init_params: Optional[Params] = None
        self._n_samples: Optional[np.ndarray] = None
        self._policy: Optional[el_policies.Policy] = None
        self._callbacks: List[RoundCallback] = []
        self.coord: Optional[CloudCoordinator] = None   # built per run
        self._coord_consumed = False
        # compiled-program cache: key -> jitted program.  Keys carry the
        # structural config AND the mesh/sharding + donation identity
        # (two meshes compile different executables — sharing or
        # thrashing a slot between them would silently retrace per call).
        # Bounded FIFO (repro.el.cache.ProgramCache): each entry's
        # closure pins a device-resident copy of the padded per-edge
        # datasets, so an unbounded cache would leak under ever-changing
        # keys (e.g. fresh metric_fn lambdas).  A FleetServer can share
        # this cache (FleetServer(cache=session.compile_cache)) so its
        # cohorts and the session's verification runs count hits/misses
        # against one pool.
        self._max_cached_programs = 8
        self._programs = ProgramCache(self._max_cached_programs)
        self._closed = False
        self._fastpath = None                           # last sync program
        self._fastpath_key = None
        self._async_fastpath = None                     # last async program
        self._async_key = None
        self._sweep_program = None                      # last sweep program
        self._sweep_key = None

    @property
    def async_alpha(self) -> float:
        """The async staleness-mix base rate (a config knob since it is
        sweepable/traced; kept as an attribute for back-compat)."""
        return self.cfg.async_alpha

    # -- builder API ---------------------------------------------------------

    def with_executor(self, executor: EdgeExecutor, *,
                      init_params: Optional[Params] = None,
                      n_samples: Optional[Any] = None) -> "ELSession":
        validate_executor(executor)
        self._executor = executor
        self._init_params = init_params
        if n_samples is not None:
            self._n_samples = np.asarray(n_samples, np.float64)
        return self

    def with_policy(self, policy: Union[str, el_policies.Policy]
                    ) -> "ELSession":
        if isinstance(policy, str):
            self.cfg = dataclasses.replace(self.cfg, policy=policy)
            self._policy = None
        else:
            self._policy = policy
            self.cfg = dataclasses.replace(self.cfg, policy=policy.name)
        self.coord = None                    # any prepared coordinator is stale
        return self

    def with_metric(self, metric_name: str) -> "ELSession":
        self.metric_name = metric_name
        return self

    def on_round(self, callback: RoundCallback) -> "ELSession":
        """Register a streaming per-aggregation callback."""
        self._callbacks.append(callback)
        return self

    # -- internals -----------------------------------------------------------

    def _require_executor(self) -> EdgeExecutor:
        if self._closed:
            raise RuntimeError(
                "this ELSession is closed (close() released its compiled "
                "programs and device buffers); build a fresh session")
        if self._executor is None:
            raise RuntimeError("call .with_executor(...) before .run()")
        return self._executor

    def _initial_params(self) -> Params:
        if self._init_params is not None:
            if any(getattr(leaf, "is_deleted", lambda: False)()
                   for leaf in jax.tree.leaves(self._init_params)):
                raise RuntimeError(
                    "the session's init_params were donated to a previous "
                    "donate=True run (their buffers are invalidated); pass "
                    "fresh init_params via .with_executor() before running "
                    "again")
            return self._init_params
        ex = self._require_executor()
        if hasattr(ex, "init_params"):
            return ex.init_params(self.cfg.seed)
        raise RuntimeError(
            f"{type(ex).__name__} has no init_params(); pass "
            "init_params= to with_executor()")

    def coordinator(self) -> CloudCoordinator:
        """The current coordinator: before a run this is the instance the
        next run will use (budgets/costs inspectable — or adjustable);
        after a run it still holds that run's consumed state."""
        if self.coord is None:
            self.coord = CloudCoordinator(self.cfg, self.cfg.n_edges,
                                          lr=self.lr, policy=self._policy)
            self._coord_consumed = False
        return self.coord

    def _build(self) -> Tuple[CloudCoordinator, UtilityEstimator,
                              np.random.Generator]:
        if self._coord_consumed:             # each run starts from fresh
            self.coord = None                # budgets/bandit statistics
        coord = self.coordinator()
        self._coord_consumed = True
        utility = UtilityEstimator(self.cfg.utility)
        rng = np.random.default_rng(self.cfg.seed + 17)
        return coord, utility, rng

    def _emit(self, records: List[RoundRecord], rec: RoundRecord) -> None:
        records.append(rec)
        for cb in self._callbacks:
            cb(rec)

    def _snapshot(self, ex: EdgeExecutor, utility: UtilityEstimator,
                  params: Params, want_metric: bool) -> Dict[str, Any]:
        snap: Dict[str, Any] = {"params": params, "loss": 0.0}
        if want_metric or utility.kind in ("eval_gain", "loss_delta"):
            m = ex.evaluate(params)
            snap["metric"] = m[self.metric_name]
            snap["loss"] = m.get("loss", 0.0)
        else:
            snap["metric"] = float("nan")
        return snap

    def _report(self, ex: EdgeExecutor, coord: CloudCoordinator,
                params: Params, records: List[RoundRecord], reason: str,
                t0: float) -> ELReport:
        final = ex.evaluate(params)[self.metric_name]
        pulls = np.zeros(self.cfg.max_interval, np.int64)
        for b in coord.bandits:
            pulls += np.asarray(b.counts)
        return ELReport(
            records=records,
            final_metric=float(final),
            n_aggregations=len(records),
            total_consumed=coord.total_consumed(),
            wall_time=records[-1].wall_time if records else 0.0,
            terminated_reason=reason,
            policy=self.cfg.policy,
            mode=self.cfg.mode,
            arm_pulls=[int(c) for c in pulls],
            elapsed_s=time.perf_counter() - t0,
            final_params=params,
        )

    # -- host-driven synchronous loop ----------------------------------------

    def run_sync(self, max_rounds: int = 10_000,
                 eval_every: int = 1) -> ELReport:
        cfg = self.cfg
        ex = self._require_executor()
        coord, utility, rng = self._build()
        t0 = time.perf_counter()
        params = self._initial_params()
        records: List[RoundRecord] = []
        wall, n_agg = 0.0, 0
        prev = self._snapshot(ex, utility, params, want_metric=True)
        reason = "max_rounds"
        for _ in range(max_rounds):
            interval = coord.decide()
            if interval < 0 or coord.all_exhausted():
                reason = "budget_exhausted"
                break
            edge_params: List[Params] = []
            round_costs = np.zeros(cfg.n_edges)
            for e in range(cfg.n_edges):
                p_e, _ = ex.local_train(params, e, interval,
                                        rng.integers(1 << 31))
                edge_params.append(p_e)
                round_costs[e] = coord.realized_cost(e, interval)
            # Time-budget semantics (paper §V.A): synchronous edges BLOCK
            # on the slowest edge, so every edge's budget advances by the
            # straggler's round time.
            slot = float(round_costs.max())
            for e in range(cfg.n_edges):
                coord.charge(e, slot)
            wall += slot
            from repro.federated.aggregation import weighted_average
            w = (np.ones(cfg.n_edges) if self._n_samples is None
                 else self._n_samples)
            params = weighted_average(edge_params, w)
            n_agg += 1
            new = self._snapshot(ex, utility, params,
                                 want_metric=(n_agg % eval_every == 0))
            u = utility(prev, new)
            # sync: ONE bandit fed the worst-case (binding) cost
            coord.observe(0, interval, u, slot)
            if coord.ac is not None:
                self._update_ac(coord, edge_params, prev["params"], params,
                                interval)
            prev = new
            self._emit(records, RoundRecord(
                wall, coord.total_consumed(), new["metric"], u,
                interval, -1, n_agg))
        return self._report(ex, coord, params, records, reason, t0)

    # -- host-driven asynchronous (event-driven) loop ------------------------

    def run_async(self, max_events: Optional[int] = None,
                  eval_every: int = 1,
                  rng_streams: str = "numpy") -> ELReport:
        """The host-driven event-queue loop (paper §V.A async semantics).

        ``max_events=None`` derives the horizon from budget/cost
        (``repro.el.events.default_event_horizon``), so long runs are
        never silently truncated.

        ``rng_streams`` picks the randomness source: ``"numpy"`` (the
        legacy host streams) or ``"jax"`` — the same priority-queue loop
        driven by the compiled async program's ``jax.random`` chain and
        f32 kernels (``repro.el.events.reference``; needs the in-graph
        support matrix).  In fixed-cost mode the ``"jax"`` loop is
        bit-identical to ``run_async_ingraph()``; ``eval_every`` is
        ignored there (the bandits consume the utility every event).
        """
        cfg = self.cfg
        ex = self._require_executor()
        if rng_streams == "jax":
            from repro.el.events.reference import run_async_reference
            acfg = self._ingraph_cfg("run_async(rng_streams='jax')",
                                     mode="async")
            return run_async_reference(
                ex, acfg, self._initial_params(),
                metric_name=self.metric_name, max_events=max_events,
                callbacks=self._callbacks)
        if rng_streams != "numpy":
            raise ValueError(
                f"unknown rng_streams={rng_streams!r}; expected 'numpy' "
                "or 'jax'")
        if max_events is None:
            from repro.el.events.knobs import default_event_horizon
            max_events = default_event_horizon(cfg)
        coord, utility, rng = self._build()
        t0 = time.perf_counter()
        global_params = self._initial_params()
        records: List[RoundRecord] = []
        n_agg = 0
        prev = self._snapshot(ex, utility, global_params, want_metric=True)
        # per-edge in-flight blocks: (finish_time, edge, interval, cost) —
        # the SAME realized-cost draw sets the finish time AND is charged
        # at completion, so charged budget always equals simulated
        # wall-clock (one draw per block, not two independent ones).
        heap: List[Tuple[float, int, int, float]] = []
        fetch_version = np.zeros(cfg.n_edges)
        version = 0
        edge_params: List[Params] = [global_params] * cfg.n_edges
        for e in range(cfg.n_edges):
            i = coord.decide(e)
            if i < 0:
                continue
            cost = coord.realized_cost(e, i)
            heapq.heappush(heap, (cost, e, i, cost))
            fetch_version[e] = version
        wall = 0.0
        reason = "max_events"
        for _ in range(max_events):
            if not heap:
                reason = "budget_exhausted"
                break
            wall, e, interval, cost = heapq.heappop(heap)
            # edge e finishes `interval` local iterations and uploads
            p_e, _ = ex.local_train(edge_params[e], e, interval,
                                    rng.integers(1 << 31))
            coord.charge(e, cost)
            # staleness in *epochs*: normalize raw version staleness by the
            # fleet size so async mixing survives edge-count scaling
            staleness = (version - fetch_version[e]) / max(cfg.n_edges, 1)
            from repro.federated.aggregation import (staleness_alpha,
                                                     staleness_mix)
            alpha = staleness_alpha(self.async_alpha, staleness)
            global_params = staleness_mix(global_params, p_e, alpha)
            version += 1
            n_agg += 1
            new = self._snapshot(ex, utility, global_params,
                                 want_metric=(n_agg % eval_every == 0))
            u = utility(prev, new)
            coord.observe(e, interval, u, cost)
            prev = new
            self._emit(records, RoundRecord(
                wall, coord.total_consumed(), new["metric"], u,
                float(interval), e, n_agg))
            # edge fetches the fresh global model, schedules its next block
            edge_params[e] = global_params
            fetch_version[e] = version
            nxt = coord.decide(e)
            if nxt > 0 and not coord.exhausted(e):
                next_cost = coord.realized_cost(e, nxt)
                heapq.heappush(heap, (wall + next_cost, e, nxt, next_cost))
        return self._report(ex, coord, global_params, records, reason, t0)

    def run(self, **kw) -> ELReport:
        if self.cfg.mode == "sync":
            return self.run_sync(**kw)
        return self.run_async(**kw)

    # -- compiled fast path ---------------------------------------------------

    def _attach_cache_stats(self, report: ELReport,
                            key: Optional[tuple] = None) -> ELReport:
        """Fold the session's compile-cache counters into
        ``report.telemetry["cache"]`` (always present on fast-path
        reports — the cache exists whether or not rings were on).  When
        ``key`` names a cached program that has been profiled, its
        :class:`repro.obs.prof.ProgramProfile` snapshot joins as
        ``report.telemetry["profile"]``."""
        tele = dict(report.telemetry or {})
        tele["cache"] = self._programs.stats()
        if key is not None:
            prof = self._programs.profile(key)
            if prof is not None:
                tele["profile"] = prof.to_json()
        report.telemetry = tele
        return report

    def _profile_program(self, key: tuple, program: Any,
                         example_args: tuple, *, mode: str, mesh,
                         donate: bool, profile: bool, contract,
                         scenario: bool = False) -> Any:
        """The dispatch-time half of the performance observatory
        (``repro.obs.prof``): lazily extract a ``ProgramProfile`` for
        the cached program (once per cache entry — the AOT compile
        behind it does not share the jit dispatch cache, so this is
        strictly opt-in) and, when a contract is armed, enforce it.

        ``profile`` / ``contract`` are the per-call opt-ins;
        ``REPRO_EL_PROFILE=1`` / ``REPRO_EL_CONTRACTS=1`` arm them
        process-wide.  ``contract=True`` checks the mode's
        ``default_contract`` (collective census + donation aliasing);
        a ``CollectiveContract`` instance checks that.  Violations
        raise ``repro.obs.prof.ContractViolation`` before dispatch.
        """
        import os
        from repro.obs import prof as obs_prof, trace as obs_trace
        if contract is None and os.environ.get("REPRO_EL_CONTRACTS"):
            contract = True
        want_profile = (profile or bool(contract)
                        or bool(os.environ.get("REPRO_EL_PROFILE")))
        if not want_profile:
            return self._programs.profile(key)
        prof = self._programs.profile(key)
        if prof is None:
            with obs_trace.span("session.profile", mode=mode):
                prof = obs_prof.profile_jit(program, *example_args,
                                            donated=donate)
                self._programs.set_profile(key, prof)
        if contract:
            c = contract
            if c is True:
                c = obs_prof.default_contract(
                    mesh=mesh, donated=donate, mode=mode,
                    scenario=scenario,
                    param_bytes=obs_prof.param_tree_bytes(
                        example_args[0]))
            c.enforce(prof)
        return prof

    @staticmethod
    def _structural_cfg(cfg: OL4ELConfig) -> OL4ELConfig:
        """The config with the knob fields normalized away: ucb_c, budget,
        heterogeneity, cost noise, the async mixing rate and seed enter
        the compiled programs as traced inputs (``sync_knobs`` /
        ``async_knobs`` / the rng key), so cache keys built from this
        reuse one program across any knob point.  ``mode`` stays — it
        selects the sync round vs the async event-horizon program.  A
        scenario keeps only ``ScenarioSpec.structural()`` (presence +
        period — the schedule arrays' traced shape); churn rates, cost
        tails and the competing policy are knob values."""
        return dataclasses.replace(cfg, ucb_c=0.0, budget=0.0,
                                   heterogeneity=1.0, seed=0,
                                   cost_noise=0.0, cost_model="fixed",
                                   async_alpha=0.5,
                                   policy=(cfg.policy
                                           if cfg.scenario is None
                                           else "ol4el"),
                                   scenario=(None if cfg.scenario is None
                                             else cfg.scenario.structural()))

    def _ingraph_cfg(self, caller: str,
                     mode: Optional[str] = None) -> OL4ELConfig:
        """The effective (mode-coerced, support-checked) fast-path config."""
        from repro.el.ingraph import check_ingraph_support
        cfg = self.cfg
        if mode is not None and cfg.mode != mode:
            cfg = dataclasses.replace(cfg, mode=mode)
        # an injected ol4el Policy object carries its own exploration
        # constant; honor it like the host path does (other policy objects
        # are rejected by the support check below)
        if self._policy is not None and self._policy.name == "ol4el":
            cfg = dataclasses.replace(cfg, ucb_c=self._policy.ucb_c)
        check_ingraph_support(cfg, self._require_executor(), caller=caller)
        return cfg

    @property
    def compile_cache(self) -> ProgramCache:
        """The session's bounded compiled-program cache — pass it to a
        ``FleetServer(cache=...)`` to share one pool (and one hit/miss
        counter) between the server's cohorts and this session's
        independent verification runs."""
        return self._programs

    def clear_compile_cache(self) -> int:
        """Drop every cached compiled program AND the last-used aliases
        that keep evicted programs alive.  Each program's closure pins a
        device-resident copy of the padded per-edge datasets, so on a
        long-lived server this is what actually releases device memory
        (the buffers free once the GC collects the closures).  Returns
        the number of cached programs dropped; the session stays usable
        — the next run recompiles."""
        n = self._programs.clear()
        self._fastpath = self._fastpath_key = None
        self._async_fastpath = self._async_key = None
        self._sweep_program = self._sweep_key = None
        return n

    def close(self) -> None:
        """Release everything the session pins on device: the compiled
        programs (and the dataset copies their closures hold) plus the
        initial-params reference.  After ``close()`` the session refuses
        to run — build a fresh one instead (idempotent)."""
        self.clear_compile_cache()
        self._init_params = None
        self._executor = None
        self._closed = True

    def _cache_program(self, key: tuple, program: Any) -> Any:
        """Insert into the bounded FIFO program cache (oldest evicted;
        the last-used aliases keep an evicted program alive until the
        next run replaces them)."""
        self._programs.max_entries = self._max_cached_programs
        return self._programs.put(key, program)

    def _jit_ingraph(self, core, knob_names, mesh, donate, params):
        """jit one of the compiled EL programs with the run's placement
        and donation: with ``mesh`` the inputs land per
        ``repro.sharding.el_run_in_shardings`` (params by the per-arch
        resolver, control plane replicated); with ``donate`` the params
        argument's buffers are donated — XLA aliases them into the
        output params, so an aggregation updates the fleet's parameters
        in place instead of copying them every round.  ``params`` is the
        run's already-materialized initial tree (shapes only are read)."""
        kw: Dict[str, Any] = {}
        if donate:
            kw["donate_argnums"] = (0,)
        if mesh is not None:
            from repro.sharding import el_run_in_shardings
            ex = self._require_executor()
            kw["in_shardings"] = el_run_in_shardings(
                mesh, getattr(ex.model, "cfg", None),
                jax.eval_shape(lambda p: p, params), knob_names)
        return jax.jit(core, **kw)

    def run_sync_ingraph(self, max_rounds: int = 512,
                         metric_fn: Optional[Callable] = None, *,
                         mesh=None, donate: bool = False,
                         telemetry=None, profile: bool = False,
                         contract=None) -> ELReport:
        """Run the whole budgeted sync loop as ONE compiled XLA program.

        Numerically equivalent (up to RNG streams) to ``run_sync`` under
        the fast path's contract — the supported matrix (see
        ``repro.el.ingraph``; shared with ``run_async_ingraph``) is:

        ============  =====================================================
        mode           ``sync`` (this method) or ``async``
                       (``run_async_ingraph``, the ``repro.el.events``
                       event-horizon program)
        policy         ``ol4el`` only (the compiled 3-step KUBE bandit;
                       shared in sync, per-edge in async)
        cost_model     ``fixed`` or ``variable`` (in-graph cost noise)
        utility        ``eval_gain`` (jittable metric) or ``param_delta``
        executor       ``InGraphExecutor`` (e.g. ``ClassicExecutor``)
        ============  =====================================================

        Unsupported (policy, cost_model, executor) combinations raise an
        informative ``ValueError``/``TypeError`` naming the combination.
        Callbacks still fire, streamed after the device loop finishes.

        ``mesh=`` runs the program sharded: the ``[n_edges, ...]`` data
        plane over the mesh's (``pod``, ``data``) axes, model tensors
        over ``model``, control plane replicated — bit-identical to the
        mesh-less program (see ``make_sync_program``).  ``donate=True``
        donates the initial params' buffers to the program (in-place
        fleet update); the caller must not reuse the passed-in params
        afterwards — the session detects a reuse attempt and raises.

        ``telemetry=`` switches the in-graph observability rings on
        (``repro.obs``: None/False off — today's program bit-for-bit;
        True/int/``TelemetrySpec`` on).  The recorded rings land in
        ``report.telemetry["rings"]``; the gate is part of the compile
        cache key, so on/off runs never share a program slot.

        ``profile=True`` extracts a ``repro.obs.prof.ProgramProfile``
        for the compiled program (XLA cost/memory analysis + the HLO
        collective census) — computed once per cached program, attached
        to the cache entry and surfaced as
        ``report.telemetry["profile"]``.  ``contract=`` additionally
        enforces a ``CollectiveContract`` at dispatch time (``True``:
        the mode's ``default_contract`` — gather-before-reduce census
        plus donation alias bytes; or a contract instance).
        ``REPRO_EL_PROFILE=1`` / ``REPRO_EL_CONTRACTS=1`` arm these
        process-wide; both default off (profiling costs one extra AOT
        compile per program).
        """
        from repro.el.ingraph import (make_sync_program, sync_knob_names,
                                      sync_knobs)
        from repro.obs import rings as obs_rings, trace as obs_trace
        ex = self._require_executor()
        cfg = self._ingraph_cfg("run_sync_ingraph", mode="sync")
        spec = obs_rings.as_spec(telemetry)
        t0 = time.perf_counter()
        key = ("sync", ex, self._structural_cfg(cfg), max_rounds,
               metric_fn, self.metric_name,
               None if self._n_samples is None else tuple(self._n_samples),
               mesh, donate, spec)
        params = self._initial_params()
        program = self._programs.get(key)
        if program is None:
            with obs_trace.span("session.compile", mode="sync",
                                telemetry=spec is not None):
                program = self._jit_ingraph(make_sync_program(
                    ex.model, ex.edge_data, ex.eval_set, cfg,
                    lr=ex.lr, batch=ex.batch, n_samples=self._n_samples,
                    metric_fn=metric_fn, metric_name=self.metric_name,
                    max_rounds=max_rounds, mesh=mesh, telemetry=spec),
                    sync_knob_names(cfg), mesh, donate, params)
                self._cache_program(key, program)
        self._fastpath, self._fastpath_key = program, key
        self._profile_program(
            key, program,
            (jax.eval_shape(lambda p: p, params),
             jax.random.key(cfg.seed + 17), sync_knobs(cfg)),
            mode="sync", mesh=mesh, donate=donate, profile=profile,
            contract=contract, scenario=cfg.scenario is not None)
        with obs_trace.span("session.dispatch", mode="sync") as sp:
            params, out = jax.block_until_ready(
                program(params, jax.random.key(cfg.seed + 17),
                        sync_knobs(cfg)))
            sp["n_rounds"] = int(out["n_rounds"])
        records: List[RoundRecord] = []
        for rec in records_from_out(out, 0, int(out["n_rounds"])):
            self._emit(records, rec)
        final = ex.evaluate(params)[self.metric_name]
        report = report_from_out(
            out, mode="sync", policy=cfg.policy, horizon=max_rounds,
            final_metric=final, final_params=params,
            elapsed_s=time.perf_counter() - t0, records=records)
        return self._attach_cache_stats(report, key)

    def run_async_ingraph(self, max_events: Optional[int] = None,
                          metric_fn: Optional[Callable] = None, *,
                          mesh=None, donate: bool = False,
                          telemetry=None, profile: bool = False,
                          contract=None) -> ELReport:
        """Run the whole budgeted async event loop as ONE compiled XLA
        program (``repro.el.events``): no host priority queue — finish
        times live in an ``[n_edges]`` array and each ``lax.while_loop``
        step pops the argmin finish time, staleness-merges that edge's
        block and schedules its next one.

        Same supported matrix as ``run_sync_ingraph`` (policy ``ol4el``
        with per-edge bandits).  ``max_events=None`` derives the event
        horizon from budget/cost (``default_event_horizon``), so runs
        terminate on budget exhaustion, never silent truncation.  An
        explicit ``max_events`` is **bucketed**: the compiled history
        arrays are sized at the next power of two
        (``bucket_event_horizon``) while the exact cap rides in as the
        traced ``event_cap`` knob — nearby caps share ONE executable
        instead of recompiling per value, and the loop still stops at
        exactly ``max_events`` events.  In fixed-cost mode the result is
        bit-identical to the host event queue on the same streams,
        ``run_async(rng_streams="jax")``.

        ``mesh=`` shards the per-edge datasets and the ``[n_edges, ...]``
        fetched-params stack over the mesh (bit-identical to the
        mesh-less program — see ``make_async_program``); ``donate=True``
        donates the initial params' buffers (caller must not reuse them;
        the session detects reuse and raises).  ``cfg.async_batch_k``
        sets the engine's K-event wave width (0 auto-tunes from the
        mesh: sharded runs dispatch batched waves, replicated runs keep
        the single-event program — see ``resolve_async_batch_k``); it is
        structural, so it participates in the compile-cache key.

        ``telemetry=`` switches the in-graph observability rings on
        (see ``run_sync_ingraph``; async rings additionally record the
        merge ``alpha``/staleness and event inter-arrival times).
        ``profile=`` / ``contract=`` attach a ``ProgramProfile`` and
        enforce dispatch-time collective contracts exactly as in
        ``run_sync_ingraph`` (the async default contract uses the same
        gather-before-reduce census).
        """
        from repro.el.events import (async_knob_names, async_knobs,
                                     bucket_event_horizon,
                                     make_async_program,
                                     padded_event_horizon)
        from repro.obs import rings as obs_rings, trace as obs_trace
        ex = self._require_executor()
        cfg = self._ingraph_cfg("run_async_ingraph", mode="async")
        spec = obs_rings.as_spec(telemetry)
        t0 = time.perf_counter()
        if max_events is None:
            # the padded (power-of-two) horizon: it is part of the
            # compile cache key (it sizes the history arrays), so keying
            # the exact budget/cost-dependent value would recompile on
            # every knob change the traced inputs exist to absorb
            horizon = padded_event_horizon(cfg)
            event_cap = None
        else:
            # explicit caps bucket the same way: the STATIC history
            # length is the pow-2 envelope, the exact cap is the traced
            # event_cap knob — nearby caps share one executable
            event_cap = int(max_events)
            horizon = bucket_event_horizon(event_cap)
        key = ("async", ex, self._structural_cfg(cfg), horizon, metric_fn,
               self.metric_name, mesh, donate, spec)
        params = self._initial_params()
        program = self._programs.get(key)
        if program is None:
            with obs_trace.span("session.compile", mode="async",
                                telemetry=spec is not None):
                program = self._jit_ingraph(make_async_program(
                    ex.model, ex.edge_data, ex.eval_set, cfg,
                    lr=ex.lr, batch=ex.batch, metric_fn=metric_fn,
                    metric_name=self.metric_name, max_events=horizon,
                    mesh=mesh, telemetry=spec),
                    async_knob_names(cfg), mesh, donate, params)
                self._cache_program(key, program)
        self._async_fastpath, self._async_key = program, key
        knobs = async_knobs(cfg)
        if event_cap is not None:
            knobs["event_cap"] = np.int32(event_cap)
        self._profile_program(
            key, program,
            (jax.eval_shape(lambda p: p, params),
             jax.random.key(cfg.seed + 17), knobs),
            mode="async", mesh=mesh, donate=donate, profile=profile,
            contract=contract, scenario=cfg.scenario is not None)
        with obs_trace.span("session.dispatch", mode="async") as sp:
            params, out = jax.block_until_ready(
                program(params, jax.random.key(cfg.seed + 17), knobs))
            sp["n_events"] = int(out["n_rounds"])
        records: List[RoundRecord] = []
        for rec in records_from_out(out, 0, int(out["n_rounds"])):
            self._emit(records, rec)
        final = ex.evaluate(params)[self.metric_name]
        report = report_from_out(
            out, mode="async", policy=cfg.policy,
            horizon=horizon if event_cap is None else event_cap,
            final_metric=final, final_params=params,
            elapsed_s=time.perf_counter() - t0, records=records)
        return self._attach_cache_stats(report, key)

    # -- compiled ablation sweeps ---------------------------------------------

    def sweep(self, spec, *, mesh=None,
              metric_fn: Optional[Callable] = None, telemetry=None):
        """Run a whole ablation grid as ONE compiled, vmapped program.

        ``spec`` is a :class:`repro.el.sweep.SweepSpec` — grids over
        ``ucb_c`` / ``budget`` / ``heterogeneity`` / ``cost_noise`` /
        ``async_alpha`` / ``seeds``; empty axes inherit this session's
        config.  The session's ``cfg.mode`` picks the compiled program
        the grid vmaps over: the sync round (``repro.el.ingraph``) or
        the async event-horizon engine (``repro.el.events``).  Every
        cell is bit-identical to an independent ``run_sync_ingraph`` /
        ``run_async_ingraph`` with that cell's config (same RNG
        streams), and the same support matrix applies.  With ``mesh=``
        the sweep dim shards over the mesh's (``pod``, ``data``) axes.
        An async grid may sweep ``async_batch_k`` (the K-event wave
        width): each K is a different compiled body, so the session
        runs one vmapped sub-sweep per K (the axis is slowest-varying —
        sub-results concatenate back into the flattened grid order) and
        every K's cells remain bit-identical to each other.
        ``telemetry=`` switches the per-cell in-graph rings on (see
        ``run_sync_ingraph``); each cell's rings land stacked in the
        report's ``out["telemetry"]`` leaves.  Returns a
        :class:`repro.el.sweep.SweepReport`.
        """
        from repro.el.sweep.engine import (make_sweep_program,
                                           run_sweep_program)
        from repro.el.sweep.report import SweepReport
        from repro.obs import rings as obs_rings
        ex = self._require_executor()
        cfg = self._ingraph_cfg("ELSession.sweep")
        tele_spec = obs_rings.as_spec(telemetry)
        t0 = time.perf_counter()
        from repro.obs import trace as obs_trace
        # each async_batch_k value is a different compiled wave body —
        # run one vmapped sub-sweep per K (a single-K / sync grid is one
        # sub-sweep: exactly the old path)
        subs = (spec.per_batch_k() if cfg.mode == "async"
                else [(None, spec)])
        params_parts, out_parts = [], []
        for k_val, sub in subs:
            sub_cfg = (cfg if k_val is None else dataclasses.replace(
                cfg, async_batch_k=int(k_val)))
            # the jitted vmapped program only depends on the structural
            # config (incl. async_batch_k), the grid SHAPE (axis lengths
            # fix the [n_cells] dim and, with a mesh, the input
            # shardings) and max_rounds — not the knob values
            axes = sub.axes(sub_cfg)
            spec_shape = (tuple(len(v) for v in axes.values()),
                          sub.max_rounds)
            key = ("sweep", ex, self._structural_cfg(sub_cfg), spec_shape,
                   metric_fn, self.metric_name, mesh,
                   None if self._n_samples is None
                   else tuple(self._n_samples),
                   tele_spec)
            program = self._programs.get(key)
            if program is None:
                with obs_trace.span("session.compile", mode="sweep",
                                    n_cells=sub.n_cells):
                    program = make_sweep_program(
                        ex.model, ex.edge_data, ex.eval_set, sub_cfg, sub,
                        lr=ex.lr, batch=ex.batch,
                        n_samples=self._n_samples, metric_fn=metric_fn,
                        metric_name=self.metric_name,
                        mesh=mesh, telemetry=tele_spec)
                    self._cache_program(key, program)
            self._sweep_program, self._sweep_key = program, key
            with obs_trace.span("session.dispatch", mode="sweep",
                                n_cells=sub.n_cells):
                params, out = run_sweep_program(
                    program, self._initial_params(),
                    sub.cell_cfgs(sub_cfg))
            params_parts.append(params)
            out_parts.append(out)
        if len(out_parts) == 1:
            params, out = params_parts[0], out_parts[0]
        else:
            # async_batch_k is slowest-varying, so concatenating the
            # sub-sweeps along the cell axis reproduces spec.cells()
            params = jax.tree.map(
                lambda *xs: jax.numpy.concatenate(xs, axis=0),
                *params_parts)
            out = jax.tree.map(
                lambda *xs: np.concatenate(xs, axis=0), *out_parts)
        report = SweepReport(
            spec=spec, axes=spec.axes(cfg), cells=spec.cells(cfg),
            out=out, policy=cfg.policy,
            elapsed_s=time.perf_counter() - t0, final_params=params)
        # workloads without a jittable metric (e.g. K-means F1) run the
        # program with NaN metric history; score the final params host-side
        # so the report's frontier still has an accuracy axis
        report.score_final_params(
            lambda p: ex.evaluate(p)[self.metric_name])
        return report

    # -- AC-sync estimator plumbing -------------------------------------------

    @staticmethod
    def _update_ac(coord: CloudCoordinator, edge_params: List[Params],
                   prev_global: Params, new_global: Params,
                   tau: int) -> None:
        local_deltas = np.array([param_l2_delta(prev_global, p)
                                 for p in edge_params])
        global_delta = param_l2_delta(prev_global, new_global)
        coord.ac.update_estimates(local_deltas, global_delta, tau)
