"""First-class collaboration-strategy policies behind a registry.

Each policy is the paper's arm-selection rule as an object::

    policy = repro.el.policies.get("ol4el", ucb_c=2.0)
    arm = policy.select(state, residual_budget, costs, rng)   # -1 = broke

replacing the string-dispatch if-chains that used to live in
``repro.core.bandit.select_arm`` and ``CloudCoordinator.decide``.  The
numerical behaviour (including the order of RNG draws) is identical to the
old dispatch, so seeded experiments reproduce bit-for-bit.

Bandit policies (``ol4el``, ``ucb_bv``, ``greedy``, ``freq_only``,
``eps_greedy``) share the paper's initialization phase: every feasible arm
is tried once before the scoring rule kicks in (§IV.B).  ``fixed_i`` and
``uniform`` are the non-learning baselines; ``ac_sync`` wraps the adaptive
tau-control of Wang et al. [12] (stateful — it owns an ``ACSync``
estimator the runtime refreshes every aggregation).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple, Type

import numpy as np

from repro.core.bandit import BanditState, _ucb
from repro.core.strategies import ACSync


class Policy:
    """Arm-selection strategy over a budget-limited bandit.

    ``select`` returns a 0-based arm index (arm *i* = global-update
    interval *i+1*) or -1 when no arm is affordable.
    """

    name: str = ""
    init_phase: bool = True        # paper §IV.B: try every feasible arm once
    #: Modes the compiled in-graph programs implement for this policy
    #: (``repro.el.ingraph`` sync round / ``repro.el.events`` async
    #: event-horizon).  Empty = host paths only.
    ingraph_modes: Tuple[str, ...] = ()

    def __init__(self, ucb_c: float = 2.0, eps: float = 0.1,
                 fixed_arm: int = 3, **_: object):
        self.ucb_c = ucb_c
        self.eps = eps
        self.fixed_arm = fixed_arm

    # -- public API ---------------------------------------------------------

    def select(self, state: BanditState, residual_budget: float,
               costs: np.ndarray,
               rng: Optional[np.random.Generator] = None) -> int:
        rng = rng or np.random.default_rng(0)
        feasible = costs <= residual_budget + 1e-12
        if not feasible.any():
            return -1
        if self.init_phase:
            untried = feasible & (state.counts == 0)
            if untried.any():
                return int(np.argmax(untried))
        return self._select(state, residual_budget, costs, feasible, rng)

    # -- per-policy scoring rule -------------------------------------------

    def _select(self, state: BanditState, residual_budget: float,
                costs: np.ndarray, feasible: np.ndarray,
                rng: np.random.Generator) -> int:
        raise NotImplementedError

    # -- shared helpers -----------------------------------------------------

    def _density(self, state: BanditState, costs: np.ndarray,
                 feasible: np.ndarray) -> np.ndarray:
        ucb = _ucb(state, self.ucb_c)
        return np.where(feasible, ucb / np.maximum(costs, 1e-9), -np.inf)

    def __repr__(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"


_REGISTRY: Dict[str, Type[Policy]] = {}


def register(cls: Type[Policy]) -> Type[Policy]:
    assert cls.name, f"{cls} must set a registry name"
    _REGISTRY[cls.name] = cls
    return cls


def get(name: str, **kwargs) -> Policy:
    """Instantiate a registered policy; unknown kwargs are ignored so one
    call site can configure every policy family."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; available: {available()}") from None
    return cls(**kwargs)


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def ingraph_modes(name: str) -> Tuple[str, ...]:
    """Modes (``sync``/``async``) the compiled in-graph programs support
    for the named policy; ``()`` for host-only or unknown policies.  The
    sync program compiles ol4el's shared bandit, the async event-horizon
    program its per-edge bandit fleet."""
    cls = _REGISTRY.get(name)
    return getattr(cls, "ingraph_modes", ()) if cls is not None else ()


# ---------------------------------------------------------------------------
# The paper's procedure and its ablations
# ---------------------------------------------------------------------------


@register
class OL4ELPolicy(Policy):
    """§IV.B.1 3-step procedure: P(i) ∝ UCB-density_i × frequency_i."""

    name = "ol4el"
    ingraph_modes = ("sync", "async")   # shared / per-edge compiled bandits

    def _select(self, state, residual_budget, costs, feasible, rng):
        density = self._density(state, costs, feasible)
        freq = np.where(feasible, np.floor(residual_budget / costs), 0.0)
        d = np.where(np.isfinite(density), density, np.nanmax(
            np.where(np.isfinite(density), density, -np.inf)) + 1.0)
        d = d - d.min() + 1e-9                   # shift to positive
        w = np.where(feasible, np.maximum(d * freq, 0.0), 0.0)
        if w.sum() <= 0:
            return int(rng.choice(np.flatnonzero(feasible)))
        p = w / w.sum()
        return int(rng.choice(len(costs), p=p))


@register
class FreqOnlyPolicy(Policy):
    """Literal reading of §IV.B.1 step 3: P(i) ∝ frequency_i."""

    name = "freq_only"

    def _select(self, state, residual_budget, costs, feasible, rng):
        w = np.where(feasible, np.floor(residual_budget / costs), 0.0)
        w = np.where(feasible, np.maximum(w, 0.0), 0.0)
        if w.sum() <= 0:
            return int(rng.choice(np.flatnonzero(feasible)))
        p = w / w.sum()
        return int(rng.choice(len(costs), p=p))


@register
class GreedyPolicy(Policy):
    """argmax UCB density — the pure fractional-KUBE solution."""

    name = "greedy"

    def _select(self, state, residual_budget, costs, feasible, rng):
        return int(np.argmax(self._density(state, costs, feasible)))


@register
class EpsGreedyPolicy(Policy):
    """ε-greedy on UCB density (ablation)."""

    name = "eps_greedy"

    def _select(self, state, residual_budget, costs, feasible, rng):
        density = self._density(state, costs, feasible)
        if rng.random() < self.eps:
            return int(rng.choice(np.flatnonzero(feasible)))
        return int(np.argmax(density))


@register
class UCBBVPolicy(Policy):
    """Variable-cost UCB-BV1 [Ding et al., AAAI'13] (§IV.B.2)."""

    name = "ucb_bv"

    def _select(self, state, residual_budget, costs, feasible, rng):
        n = np.maximum(state.counts, 1)
        eps_i = np.sqrt(np.log(max(state.t - 1, 2)) / n)
        mean_c = state.mean_cost(fallback=costs)
        lam = max(float(np.min(mean_c)), 1e-6)
        denom = lam - eps_i
        density = state.mean_utility() / np.maximum(mean_c, 1e-9)
        d = np.where(denom > 1e-9,
                     density + (1.0 + 1.0 / lam) * eps_i / np.maximum(denom,
                                                                      1e-9),
                     np.inf)
        d = np.where(feasible, d, -np.inf)
        return int(np.argmax(d))


@register
class UniformPolicy(Policy):
    """Uniform over feasible arms (ablation floor)."""

    name = "uniform"
    init_phase = False

    def _select(self, state, residual_budget, costs, feasible, rng):
        return int(rng.choice(np.flatnonzero(feasible)))


@register
class FixedIPolicy(Policy):
    """The paper's Fixed-I baseline: a constant interval."""

    name = "fixed_i"
    init_phase = False

    def _select(self, state, residual_budget, costs, feasible, rng):
        arm = min(self.fixed_arm, state.n_arms - 1)
        return arm if feasible[arm] else int(np.argmax(feasible))


@register
class TaskAllocPolicy(Policy):
    """Adaptive task-allocation baseline modeled on arXiv 1811.03748
    ("Adaptive task allocation for mobile edge learning"): allocate the
    largest locally-feasible workload every round — the max number of
    local updates per global sync the residual budget still covers —
    adapting to the budget rather than learning arm utilities.

    Compiles through the sync scenario policy switch
    (``repro.el.scenarios.baselines``), so it needs a ``ScenarioSpec``
    on the in-graph path; the host loops run it anywhere.
    """

    name = "task_alloc"
    init_phase = False
    ingraph_modes = ("sync",)          # via the scenario policy switch

    def _select(self, state, residual_budget, costs, feasible, rng):
        arms = np.arange(len(costs))
        return int(np.max(np.where(feasible, arms, -1)))


@register
class DelayEnergyPolicy(Policy):
    """Budget-pacing baseline modeled on arXiv 2012.00143 (delay/energy-
    constrained task allocation for asynchronous edge learning): pick the
    arm whose cost best matches a geometric pace
    ``sqrt(residual * min_cost)`` — between spending the whole residual
    now and the cheapest sustainable rate — so consumption is smoothed
    over the run instead of front-loaded.

    Compiles through the sync scenario policy switch
    (``repro.el.scenarios.baselines``), so it needs a ``ScenarioSpec``
    on the in-graph path; the host loops run it anywhere.
    """

    name = "delay_energy"
    init_phase = False
    ingraph_modes = ("sync",)          # via the scenario policy switch

    def _select(self, state, residual_budget, costs, feasible, rng):
        min_c = max(float(np.min(costs)), 1e-9)
        pace = np.sqrt(max(residual_budget, min_c) * min_c)
        score = np.where(feasible, np.abs(costs - pace), np.inf)
        return int(np.argmin(score))


@register
class ACSyncPolicy(Policy):
    """AC-sync baseline [12]: adaptive tau from online (beta, delta, rho)
    estimates.  Stateful — the runtime must call
    ``policy.ac.update_estimates(...)`` after every aggregation."""

    name = "ac_sync"
    init_phase = False

    def __init__(self, eta: float = 0.1, max_interval: int = 10, **kw):
        super().__init__(**kw)
        self.ac = ACSync(eta=eta, max_interval=max_interval)

    def select(self, state, residual_budget, costs, rng=None):
        # Arm costs are linear in the interval (cost_i = i*comp + comm), so
        # the per-component costs ACSync scores with are recoverable.
        if len(costs) >= 2:
            comp = float(costs[1] - costs[0])
            comm = float(costs[0] - comp)
        else:
            comp, comm = float(costs[0]), 0.0
        tau = self.ac.select_tau(residual_budget, comp, comm)
        return -1 if tau < 0 else tau - 1
