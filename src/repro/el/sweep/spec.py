"""``SweepSpec`` — a declarative ablation grid over the EL control plane.

The paper's headline results are exactly these grids: policy
hyperparameters × budgets × heterogeneity, repeated over seeds (Figs.
3–5).  A ``SweepSpec`` names the axes; the engine flattens them
row-major into ``[n_cells]`` (seed fastest, so seed-replicates of one
hyperparameter point are contiguous) and runs every cell inside one
compiled, vmapped XLA program.

Only knobs that enter the compiled programs as *traced inputs* are
sweepable (``repro.el.ingraph.KNOB_NAMES`` /
``repro.el.events.ASYNC_KNOB_NAMES`` territory): the ``ol4el``
exploration constant ``ucb_c``, the per-edge ``budget``, the fleet
``heterogeneity`` (it only moves the cost arrays), the variable-cost
noise scale ``cost_noise``, the async staleness-mix base rate
``async_alpha`` (a no-op axis for sync grids), and the bandit/data
``seed``.  Structural knobs (n_edges, max_interval, utility, policy,
mode) change the program itself and stay fixed across a sweep — run
several sweeps to compare those (the session's ``cfg.mode`` picks the
sync round vs the async event-horizon program for the whole grid).

``async_batch_k`` is the one *semi-structural* axis: each K value is a
different compiled body (the K-event wave width), so the session splits
the grid into one sub-sweep per K — the axis is SLOWEST-varying
(first in ``AXIS_ORDER``) so each sub-sweep's cells are one contiguous
block of the flattened grid, and the concatenated results line up with
``cells()`` exactly.  All K values compute bit-identical results (the
wave program is order-equivalent to K=1); sweeping it compares
*throughput*, not learning curves.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple

from repro.config import OL4ELConfig

#: Sweep-axis order; the flattened cell index is row-major over these,
#: so ``seed`` varies fastest and ``async_batch_k`` slowest (each K is
#: its own compiled sub-sweep; first place keeps its cells contiguous).
#: ``policy`` and ``churn_rate`` are scenario-engine axes: the policy
#: competes through the traced ``policy_id`` switch and the churn rate
#: only re-draws the replayed ``scn_active`` schedule, so BOTH are
#: plain knob-value axes — every cell still shares one program.
AXIS_ORDER = ("async_batch_k", "policy", "ucb_c", "budget",
              "heterogeneity", "cost_noise", "async_alpha",
              "churn_rate", "seed")


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Grids over the sweepable control-plane knobs.

    An empty axis (the default) means "inherit the session config's
    value" — a one-point axis.  ``seeds`` must name at least one seed.

    Seed semantics: a sweep seed varies the *in-program* RNG streams
    (bandit selection, minibatch sampling, cost noise) — the dataset,
    edge partition and init params are program constants shared by every
    cell.  To resample those too, run one sweep per data seed.

    ``max_rounds`` bounds the per-cell history length: sync rounds for
    sync grids, merge *events* for async grids (where a generous bound
    is ``repro.el.events.default_event_horizon``).
    """

    ucb_c: Tuple[float, ...] = ()
    budget: Tuple[float, ...] = ()
    heterogeneity: Tuple[float, ...] = ()
    cost_noise: Tuple[float, ...] = ()
    async_alpha: Tuple[float, ...] = ()
    async_batch_k: Tuple[int, ...] = ()
    #: competitor-policy axis (``repro.el.scenarios.INGRAPH_POLICY_ORDER``
    #: names) — traced through the ``policy_id`` switch, so a multi-policy
    #: grid is still ONE program; needs ``cfg.scenario`` set (sync mode)
    policy: Tuple[str, ...] = ()
    #: churn-rate axis — re-draws each cell's replayed ``scn_active``
    #: schedule; needs a churn-bearing ``cfg.scenario``
    churn_rate: Tuple[float, ...] = ()
    seeds: Tuple[int, ...] = (0,)
    max_rounds: int = 256

    def __post_init__(self):
        for name in ("ucb_c", "budget", "heterogeneity", "cost_noise",
                     "async_alpha", "async_batch_k", "policy",
                     "churn_rate", "seeds"):
            vals = getattr(self, name)
            if not isinstance(vals, tuple):
                object.__setattr__(self, name, tuple(vals))
        if not self.seeds:
            raise ValueError("SweepSpec.seeds must name at least one seed")
        if self.max_rounds <= 0:
            raise ValueError(
                f"SweepSpec.max_rounds must be positive, got "
                f"{self.max_rounds}")
        if any(b <= 0 for b in self.budget):
            raise ValueError(f"SweepSpec.budget values must be positive, "
                             f"got {self.budget}")
        if any(h < 1.0 for h in self.heterogeneity):
            raise ValueError("SweepSpec.heterogeneity values are "
                             "fastest/slowest ratios and must be >= 1, "
                             f"got {self.heterogeneity}")
        if any(n < 0 for n in self.cost_noise):
            raise ValueError("SweepSpec.cost_noise values are relative "
                             "noise scales and must be >= 0, got "
                             f"{self.cost_noise}")
        if any(not 0.0 < a <= 1.0 for a in self.async_alpha):
            raise ValueError("SweepSpec.async_alpha values are mixing "
                             "rates and must be in (0, 1], got "
                             f"{self.async_alpha}")
        if any(int(k) < 0 for k in self.async_batch_k):
            raise ValueError("SweepSpec.async_batch_k values are wave "
                             "widths and must be >= 0 (0 = auto), got "
                             f"{self.async_batch_k}")
        if self.policy:
            from repro.el.scenarios.baselines import INGRAPH_POLICY_ORDER
            bad = tuple(p for p in self.policy
                        if p not in INGRAPH_POLICY_ORDER)
            if bad:
                raise ValueError(
                    f"SweepSpec.policy values must be in-graph switch "
                    f"policies {INGRAPH_POLICY_ORDER}, got {bad}")
        if any(not 0.0 <= r < 1.0 for r in self.churn_rate):
            raise ValueError("SweepSpec.churn_rate values are dropout "
                             "probabilities and must be in [0, 1), got "
                             f"{self.churn_rate}")

    # -- flattening ----------------------------------------------------------

    def axes(self, cfg: OL4ELConfig) -> Dict[str, Tuple]:
        """Axis name -> values, empty axes defaulted from ``cfg``."""
        scn = cfg.scenario
        base_rate = (scn.churn.rate
                     if scn is not None and scn.churn is not None
                     else 0.0)
        return {
            "async_batch_k": self.async_batch_k or (cfg.async_batch_k,),
            "policy": self.policy or (cfg.policy,),
            "ucb_c": self.ucb_c or (cfg.ucb_c,),
            "budget": self.budget or (cfg.budget,),
            "heterogeneity": self.heterogeneity or (cfg.heterogeneity,),
            "cost_noise": self.cost_noise or (cfg.cost_noise,),
            "async_alpha": self.async_alpha or (cfg.async_alpha,),
            "churn_rate": self.churn_rate or (base_rate,),
            "seed": self.seeds,
        }

    @property
    def n_cells(self) -> int:
        n = 1
        for vals in (self.async_batch_k or (None,),
                     self.policy or (None,),
                     self.ucb_c or (None,), self.budget or (None,),
                     self.heterogeneity or (None,),
                     self.cost_noise or (None,),
                     self.async_alpha or (None,),
                     self.churn_rate or (None,), self.seeds):
            n *= len(vals)
        return n

    def cells(self, cfg: OL4ELConfig) -> List[Dict[str, float]]:
        """The flattened ``[n_cells]`` grid, row-major (seed fastest)."""
        axes = self.axes(cfg)
        return [dict(zip(AXIS_ORDER, combo))
                for combo in itertools.product(*(axes[a]
                                                 for a in AXIS_ORDER))]

    def cell_cfgs(self, cfg: OL4ELConfig) -> List[OL4ELConfig]:
        """One per-cell config per flattened cell — exactly what an
        independent ``run_sync_ingraph`` / ``run_async_ingraph`` of that
        cell would use (the sweep-vs-independent equivalence tests lean
        on this).  The session config's ``mode`` carries through to every
        cell.  Only an EXPLICIT ``cost_noise`` axis flips nonzero-noise
        cells to ``cost_model="variable"`` (the knob derivations gate
        noise on it); an inherited one-point axis keeps the session's
        cost model, so a fixed-cost session with a dormant
        ``cfg.cost_noise`` sweeps exactly like its single runs.

        The scenario axes are likewise value-only: an explicit
        ``policy`` axis swaps each cell's ``cfg.policy`` (entering the
        program as the traced ``policy_id``), and an explicit
        ``churn_rate`` axis rewrites ``cfg.scenario.churn.rate`` — the
        scenario's PERIOD (the only structural residue) is untouched, so
        every cell still shares one compiled program.  Both explicit
        axes require a ``cfg.scenario``."""
        explicit_noise = bool(self.cost_noise)
        if (self.policy or self.churn_rate) and cfg.scenario is None:
            raise ValueError(
                "SweepSpec policy/churn_rate axes sweep the scenario "
                "engine's traced knobs and need cfg.scenario set (an "
                "identity ScenarioSpec() is enough for the policy axis)")
        if self.churn_rate and cfg.scenario.churn is None:
            raise ValueError(
                "SweepSpec.churn_rate re-draws the dropout schedule and "
                "needs cfg.scenario.churn set (e.g. ChurnSpec())")

        def _cell_scenario(c):
            if not self.churn_rate:
                return cfg.scenario
            return dataclasses.replace(
                cfg.scenario, churn=dataclasses.replace(
                    cfg.scenario.churn, rate=float(c["churn_rate"])))

        return [dataclasses.replace(
            cfg, ucb_c=float(c["ucb_c"]),
            budget=float(c["budget"]),
            heterogeneity=float(c["heterogeneity"]),
            cost_noise=float(c["cost_noise"]),
            cost_model=("variable"
                        if explicit_noise and c["cost_noise"] > 0
                        else cfg.cost_model),
            async_alpha=float(c["async_alpha"]),
            policy=str(c["policy"]),
            scenario=_cell_scenario(c),
            async_batch_k=int(c["async_batch_k"]), seed=int(c["seed"]))
            for c in self.cells(cfg)]

    def per_batch_k(self) -> List[Tuple[int, "SweepSpec"]]:
        """Split into one sub-spec per ``async_batch_k`` value (grid
        order).  Each K compiles a different wave body, so the engine
        runs one vmapped program per sub-spec; the axis is slowest-
        varying, so concatenating the sub-results along the cell axis
        reproduces the full flattened grid."""
        ks = self.async_batch_k or (None,)
        if len(ks) <= 1:
            return [(ks[0], self)]
        return [(k, dataclasses.replace(self, async_batch_k=(k,)))
                for k in ks]

    def describe(self, cfg: OL4ELConfig) -> str:
        axes = self.axes(cfg)
        dims = " × ".join(f"{len(v)} {k}" for k, v in axes.items())
        return f"{self.n_cells} cells ({dims}), max_rounds={self.max_rounds}"


def spec_from_sequences(ucb_c: Sequence[float] = (),
                        budget: Sequence[float] = (),
                        heterogeneity: Sequence[float] = (),
                        cost_noise: Sequence[float] = (),
                        async_alpha: Sequence[float] = (),
                        async_batch_k: Sequence[int] = (),
                        policy: Sequence[str] = (),
                        churn_rate: Sequence[float] = (),
                        seeds: Sequence[int] = (0,),
                        max_rounds: int = 256) -> SweepSpec:
    """CLI-friendly constructor (lists in, validated tuples out)."""
    return SweepSpec(ucb_c=tuple(float(x) for x in ucb_c),
                     budget=tuple(float(x) for x in budget),
                     heterogeneity=tuple(float(x) for x in heterogeneity),
                     cost_noise=tuple(float(x) for x in cost_noise),
                     async_alpha=tuple(float(x) for x in async_alpha),
                     async_batch_k=tuple(int(k) for k in async_batch_k),
                     policy=tuple(str(p) for p in policy),
                     churn_rate=tuple(float(r) for r in churn_rate),
                     seeds=tuple(int(s) for s in seeds),
                     max_rounds=int(max_rounds))
