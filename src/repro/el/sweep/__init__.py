"""``repro.el.sweep`` — vmapped, mesh-sharded ablation sweeps.

Turns a declarative :class:`SweepSpec` (grids over ``ucb_c``, budgets,
heterogeneity, cost noise, async mixing rate, seeds) into ONE compiled
XLA program: the in-graph sync round (``repro.el.ingraph``) or async
event-horizon run (``repro.el.events``) — picked by the session's
``cfg.mode`` — vmapped over a flattened ``[n_cells]`` axis, optionally
sharded over the production mesh.  Each cell is bit-identical to an
independent ``ELSession.run_sync_ingraph`` / ``run_async_ingraph`` with
that cell's config.  Front door: ``ELSession.sweep(spec)`` →
:class:`SweepReport`.
"""

from repro.el.sweep.engine import (cell_keys, knob_names,
                                   make_sweep_program, run_sweep_program,
                                   stack_knobs, sweep_input_shardings,
                                   sweep_partition_specs)
from repro.el.sweep.report import SweepReport
from repro.el.sweep.spec import AXIS_ORDER, SweepSpec, spec_from_sequences

__all__ = [
    "SweepSpec", "SweepReport", "AXIS_ORDER", "spec_from_sequences",
    "make_sweep_program", "run_sweep_program", "stack_knobs", "cell_keys",
    "knob_names", "sweep_partition_specs", "sweep_input_shardings",
]
