"""The sweep engine: one vmapped, optionally mesh-sharded XLA program.

``make_sync_program`` (repro.el.ingraph) and ``make_async_program``
(repro.el.events) both take the control-plane knobs as traced inputs;
this module stacks per-cell knob arrays along a leading ``[n_cells]``
axis, vmaps the mode's program over that axis, and jits — so a whole
ablation grid (every cell bit-identical to an independent
``run_sync_ingraph`` / ``run_async_ingraph`` with that cell's config)
is ONE compiled program.

On a multi-device mesh the sweep dim shards over the mesh's edge axes
(``pod``, ``data``) and the per-edge knob dim over ``model`` when
divisible — the same placement the fleet data plane uses
(``el_state_specs`` in ``repro.federated.local_sgd``), so large grids
scale across the production mesh.  Output shardings are left to GSPMD
propagation from the inputs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import OL4ELConfig
from repro.el.events.knobs import ASYNC_KNOB_NAMES, async_knobs
from repro.el.events.program import make_async_cell, make_async_program
from repro.el.ingraph import (KNOB_NAMES, make_sync_cell,
                              make_sync_program, sync_knobs)
from repro.el.sweep.spec import SweepSpec
# the knob-layout classification is shared with the single-run placement
# (repro.sharding.el_run_partition_specs) — one source of truth for which
# control-plane inputs carry a trailing per-edge dim
from repro.sharding import (EL_EDGE_KNOBS as _EDGE_KNOBS,
                            EL_SCALAR_KNOBS as _SCALAR_KNOBS,
                            EL_SCHEDULE_KNOBS as _SCHEDULE_KNOBS)

Params = Any


def knob_names(mode: str, scenario: bool = False) -> Tuple[str, ...]:
    """The traced knob set of the mode's compiled program; ``scenario``
    appends the scenario-engine schedule knobs (``scn_active`` /
    ``scn_mult`` / ``scn_drift``, plus ``policy_id`` on sync)."""
    names = ASYNC_KNOB_NAMES if mode == "async" else KNOB_NAMES
    if scenario:
        from repro.el.scenarios.schedule import scenario_knob_names
        names = names + scenario_knob_names(mode)
    return names


def stack_knobs(cell_cfgs: Sequence[OL4ELConfig]) -> Dict[str, np.ndarray]:
    """Per-cell ``sync_knobs`` / ``async_knobs`` (by the cells' mode)
    stacked along a leading [n_cells] axis."""
    knobs_fn = async_knobs if cell_cfgs[0].mode == "async" else sync_knobs
    per_cell = [knobs_fn(c) for c in cell_cfgs]
    return {k: np.stack([knobs[k] for knobs in per_cell])
            for k in knob_names(cell_cfgs[0].mode,
                                cell_cfgs[0].scenario is not None)}


def cell_keys(cell_cfgs: Sequence[OL4ELConfig]) -> jax.Array:
    """Stacked per-cell PRNG keys — the exact stream ``run_sync_ingraph``
    / ``run_async_ingraph`` seeds for that cell's config
    (``jax.random.key(seed + 17)``)."""
    # int32 matches the scalar path's x64-disabled seed canonicalization
    # (negative seeds wrap identically; >= 2**31 overflows on both paths)
    seeds = jnp.asarray([c.seed + 17 for c in cell_cfgs], jnp.int32)
    return jax.vmap(jax.random.key)(seeds)


# ---------------------------------------------------------------------------
# Mesh placement (el_state_specs pattern: lead dim over pod/data, inner
# parallel dim over model when divisible)
# ---------------------------------------------------------------------------


def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, np.shape(mesh.devices)))


def sweep_partition_specs(axis_names: Sequence[str],
                          axis_sizes: Dict[str, int],
                          n_cells: int, n_edges: int,
                          mode: str = "sync",
                          scenario: bool = False
                          ) -> Tuple[P, Dict[str, P]]:
    """PartitionSpecs for (keys, knobs): sweep dim over the edge axes,
    per-edge knob dim over ``model`` when divisible.  Pure (no devices) so
    placement policy is unit-testable; raises ``ValueError`` when the grid
    does not tile the mesh."""
    sweep_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    if not sweep_axes:
        raise ValueError(
            f"mesh axes {tuple(axis_names)} have no edge axes "
            "('pod'/'data') to shard the sweep dim over")
    n_shards = math.prod(axis_sizes[a] for a in sweep_axes)
    if n_cells % n_shards != 0:
        raise ValueError(
            f"sweep of {n_cells} cells does not tile the mesh's "
            f"{sweep_axes} axes ({n_shards} shards); pad the grid (e.g. "
            f"add seeds) to a multiple of {n_shards} or run without a "
            "mesh")
    model_size = axis_sizes.get("model", 1)
    edge_ax = "model" if (model_size > 1
                          and n_edges % model_size == 0) else None
    key_spec = P(sweep_axes)

    def spec_for(name: str) -> P:
        if name in _EDGE_KNOBS:                       # [C, E]
            return P(sweep_axes, edge_ax)
        if name in _SCALAR_KNOBS:                     # [C]
            return P(sweep_axes)
        if name == "costs_ek":                        # [C, E, K] (async)
            return P(sweep_axes, edge_ax, None)
        if name in _SCHEDULE_KNOBS:                   # [C, S, E]
            # the period dim is gathered one row per round — keep it
            # whole; the trailing edge dim is small and rides along
            return P(sweep_axes, None, None)
        return P(sweep_axes, None)                    # costs_k [C, K]

    knob_specs = {name: spec_for(name)
                  for name in knob_names(mode, scenario)}
    return key_spec, knob_specs


def sweep_input_shardings(mesh, n_cells: int, n_edges: int,
                          mode: str = "sync", scenario: bool = False):
    """NamedShardings for the vmapped program's (init_params, keys,
    knobs) arguments: params replicated, sweep dim over the edge axes."""
    key_spec, knob_specs = sweep_partition_specs(
        mesh.axis_names, _axis_sizes(mesh), n_cells, n_edges, mode,
        scenario)
    return (NamedSharding(mesh, P()),
            NamedSharding(mesh, key_spec),
            {k: NamedSharding(mesh, s) for k, s in knob_specs.items()})


# ---------------------------------------------------------------------------
# Program construction
# ---------------------------------------------------------------------------


def make_sweep_program(model, edge_data, eval_set, cfg: OL4ELConfig,
                       spec: SweepSpec, *, lr: float, batch: int,
                       n_samples: Optional[np.ndarray] = None,
                       metric_fn: Optional[Callable] = None,
                       metric_name: str = "accuracy",
                       mesh=None, telemetry=None):
    """Compile the sweep: ``program(init_params, keys, knobs)`` →
    ``(params_stacked, out_stacked)`` with every output carrying a
    leading ``[n_cells]`` axis.

    The per-cell computation is ``jax.vmap`` of the very same program
    ``run_sync_ingraph`` / ``run_async_ingraph`` drives (picked by
    ``cfg.mode``), so each cell is bit-identical to an independent run
    with that cell's config.  ``telemetry=`` gates the per-cell rings
    (see ``make_sync_program``) — each cell's recorded rings come back
    stacked under ``out["telemetry"]``.
    """
    cfgs = spec.cell_cfgs(cfg)
    # structural fields (n_edges, utility, mode, ...) are identical
    # across cells by SweepSpec construction — any cell builds the program
    if len({c.policy for c in cfgs}) > 1:
        # a policy axis is value-only (the lax.switch traces every
        # branch), but each named policy must itself be a supported
        # in-graph combo — surface a per-cell error, not a trace failure
        from repro.el.ingraph import check_ingraph_support
        for c in cfgs:
            check_ingraph_support(c)
    if cfg.mode == "async" and len({c.async_batch_k for c in cfgs}) > 1:
        raise ValueError(
            "a multi-valued async_batch_k grid needs one compiled "
            "program per K (each K is a different wave body); split "
            "with spec.per_batch_k() — ELSession.sweep does this "
            "automatically")
    make_program = (make_async_program if cfg.mode == "async"
                    else make_sync_program)
    core = make_program(
        model, edge_data, eval_set, cfgs[0], lr=lr, batch=batch,
        n_samples=n_samples, metric_fn=metric_fn, metric_name=metric_name,
        telemetry=telemetry,
        **({"max_events": spec.max_rounds} if cfg.mode == "async"
           else {"max_rounds": spec.max_rounds}))
    vmapped = jax.vmap(core, in_axes=(None, 0, 0))
    if mesh is None:
        return jax.jit(vmapped)
    return jax.jit(vmapped, in_shardings=sweep_input_shardings(
        mesh, spec.n_cells, cfg.n_edges, cfg.mode,
        cfg.scenario is not None))


def run_sweep_program(program, init_params: Params,
                      cell_cfgs: List[OL4ELConfig]
                      ) -> Tuple[Params, Dict[str, np.ndarray]]:
    """Execute a compiled sweep program and pull the outputs to host."""
    knobs = stack_knobs(cell_cfgs)
    keys = cell_keys(cell_cfgs)
    params, out = jax.block_until_ready(program(init_params, keys, knobs))
    # tree.map (not a dict comprehension): ``out`` may carry a nested
    # telemetry subtree when the program was built with telemetry on
    return params, jax.tree.map(np.asarray, out)


# ---------------------------------------------------------------------------
# Steppable cell batches (the fleet data plane)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CellBatch:
    """A resumable slot-batched EL engine: the sweep's vmapped cell run
    ``rounds_per_wave`` iterations at a time over a fixed ``[n_slots]``
    batch with an activity mask, instead of to completion in one call.

    Between waves the host may harvest finished slots (``take_slot`` /
    ``finalize_slot``) and admit new tenants into the freed rows
    (``init_slot`` + ``place``) — continuous batching over the EL
    control plane.  Per-slot math is the unsharded :class:`ELCell`'s
    ``cond``/``body`` verbatim; a slot applies ``body`` exactly as many
    times as the single-run ``lax.while_loop`` would (``cond`` is a pure
    function of carry + knobs), so every tenant's trajectory is
    bit-identical to an independent ``run_sync_ingraph`` /
    ``run_async_ingraph`` of that tenant alone.  Inactive slots run ZERO
    body iterations per wave: their bandit state, budget, RNG, and
    history are byte-for-byte frozen (the mask is inside the per-slot
    loop condition, not a post-hoc select).

    ``place`` and ``step`` donate the stacked carry, so a cohort
    stepping for thousands of waves recycles one set of device buffers;
    callers must treat the previous stacked value as consumed.
    """

    mode: str
    n_slots: int
    rounds_per_wave: int
    horizon: int
    #: (init_params, key, knobs_row) -> single-slot carry
    init_slot: Callable
    #: (carry_one) -> stacked carry with every row a copy (fills a fresh
    #: batch; rows are only read after ``place`` overwrites them)
    broadcast: Callable
    #: (stacked, carry_one, slot) -> stacked with row ``slot`` replaced
    #: (donates ``stacked``)
    place: Callable
    #: (stacked, carries_tuple, slots[n_slots] i32) -> stacked with every
    #: named row replaced in ONE scatter per leaf (donates ``stacked``).
    #: ``carries_tuple`` is always length ``n_slots`` — pad by repeating
    #: the last real (carry, slot) pair, so the pytree arity is fixed
    #: (one compile) and duplicate writes are idempotent.
    place_many: Callable
    #: (stacked, slot) -> carry_one (a gather — safe before donation)
    take_slot: Callable
    #: (stacked, slots[n] i32) -> the named rows stacked along a leading
    #: [n] axis, ONE gather per leaf (pad ``slots`` by repetition for a
    #: fixed shape; safe before donation)
    take_many: Callable
    #: (stacked, knobs_stacked, active[n_slots] bool) ->
    #: (stacked', running[n_slots] bool); donates ``stacked``
    step: Callable
    #: (carry_one, knobs_row) -> (params, out) — the cell's finalize
    finalize_slot: Callable


def make_cell_batch(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                    n_slots: int, rounds_per_wave: int = 32,
                    lr: float, batch: int,
                    n_samples: Optional[np.ndarray] = None,
                    metric_fn: Optional[Callable] = None,
                    metric_name: str = "accuracy",
                    horizon: int = 512,
                    mesh=None, telemetry=None) -> CellBatch:
    """Build the steppable slot-batch engine for one structural config.

    ``cfg`` contributes only structure (mode, n_edges, arch, utility,
    horizon sizing); per-slot knob values and PRNG keys arrive at call
    time, exactly as in :func:`make_sweep_program` — so one
    ``CellBatch`` serves every tenant that shares the structure.
    ``horizon`` is the compiled history length (``max_rounds`` sync,
    ``max_events`` async); use :func:`padded_event_horizon` for async
    cohorts so nearby budget points share one program.

    With a ``mesh`` the slot dim of the stacked carry is constrained to
    the cohort placement (:func:`repro.sharding.el_cohort_state_specs`)
    inside ``step``; PRNG-key-typed leaves are left to GSPMD (key
    arrays reject explicit layout constraints on some backends).

    ``telemetry=`` gates the cell's in-graph rings (see
    ``make_sync_cell``): the stacked carry gains a per-slot ``"telem"``
    subtree and ``finalize_slot`` emits ``out["telemetry"]`` per
    tenant; off (the default) the batch is today's, bit-for-bit.
    """
    if cfg.mode == "async":
        cell = make_async_cell(
            model, edge_data, eval_set, cfg, lr=lr, batch=batch,
            n_samples=n_samples, metric_fn=metric_fn,
            metric_name=metric_name, max_events=horizon,
            telemetry=telemetry)
    else:
        cell = make_sync_cell(
            model, edge_data, eval_set, cfg, lr=lr, batch=batch,
            n_samples=n_samples, metric_fn=metric_fn,
            metric_name=metric_name, max_rounds=horizon,
            telemetry=telemetry)

    def _constrain(stacked):
        if mesh is None:
            return stacked
        from repro.sharding import el_cohort_state_specs
        specs = el_cohort_state_specs(mesh, n_slots, stacked)

        def put(leaf, spec):
            if jax.dtypes.issubdtype(leaf.dtype, jax.dtypes.prng_key):
                return leaf
            return lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, spec))

        return jax.tree.map(put, stacked, specs)

    def _init_slot(init_params, key, knobs_row):
        return cell.init(init_params, key, knobs_row)

    def _broadcast(carry_one):
        return _constrain(jax.tree.map(
            lambda leaf: jnp.broadcast_to(
                leaf[None], (n_slots,) + leaf.shape), carry_one))

    def _place(stacked, carry_one, slot):
        return _constrain(jax.tree.map(
            lambda s, one: s.at[slot].set(one), stacked, carry_one))

    def _place_many(stacked, carries, slots):
        # a wave's admissions land in ONE scatter per carry leaf: stack
        # the single-slot carries into [n_slots, ...] rows and write
        # them at their slot indices (duplicate padded indices rewrite
        # the same values — idempotent)
        rows = jax.tree.map(lambda *xs: jnp.stack(xs), *carries)
        return _constrain(jax.tree.map(
            lambda s, r: s.at[slots].set(r), stacked, rows))

    def _take_slot(stacked, slot):
        return jax.tree.map(lambda s: s[slot], stacked)

    def _take_many(stacked, slots):
        # a wave's finalizes read their rows in ONE gather per leaf
        return jax.tree.map(lambda s: s[slots], stacked)

    def _step_one(carry, knobs, active):
        # the mask lives INSIDE the loop condition: an inactive slot
        # takes zero body iterations, so its carry (bandit counts,
        # consumed budget, PRNG key, history) is returned untouched
        def wave_cond(ci):
            c, i = ci
            return (i < rounds_per_wave) & active & cell.cond(c, knobs)

        def wave_body(ci):
            c, i = ci
            return cell.body(c, knobs), i + jnp.int32(1)

        carry, _ = lax.while_loop(wave_cond, wave_body,
                                  (carry, jnp.int32(0)))
        return carry, active & cell.cond(carry, knobs)

    def _step(stacked, knobs_stacked, active):
        stacked, running = jax.vmap(_step_one)(
            stacked, knobs_stacked, active)
        return _constrain(stacked), running

    def _finalize_slot(carry_one, knobs_row):
        return cell.finalize(carry_one, knobs_row)

    return CellBatch(
        mode=cfg.mode, n_slots=n_slots, rounds_per_wave=rounds_per_wave,
        horizon=horizon,
        init_slot=jax.jit(_init_slot),
        broadcast=jax.jit(_broadcast),
        place=jax.jit(_place, donate_argnums=(0,)),
        place_many=jax.jit(_place_many, donate_argnums=(0,)),
        take_slot=jax.jit(_take_slot),
        take_many=jax.jit(_take_many),
        step=jax.jit(_step, donate_argnums=(0,)),
        finalize_slot=jax.jit(_finalize_slot))
