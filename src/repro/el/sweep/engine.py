"""The sweep engine: one vmapped, optionally mesh-sharded XLA program.

``make_sync_program`` (repro.el.ingraph) and ``make_async_program``
(repro.el.events) both take the control-plane knobs as traced inputs;
this module stacks per-cell knob arrays along a leading ``[n_cells]``
axis, vmaps the mode's program over that axis, and jits — so a whole
ablation grid (every cell bit-identical to an independent
``run_sync_ingraph`` / ``run_async_ingraph`` with that cell's config)
is ONE compiled program.

On a multi-device mesh the sweep dim shards over the mesh's edge axes
(``pod``, ``data``) and the per-edge knob dim over ``model`` when
divisible — the same placement the fleet data plane uses
(``el_state_specs`` in ``repro.federated.local_sgd``), so large grids
scale across the production mesh.  Output shardings are left to GSPMD
propagation from the inputs.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import OL4ELConfig
from repro.el.events.knobs import ASYNC_KNOB_NAMES, async_knobs
from repro.el.events.program import make_async_program
from repro.el.ingraph import KNOB_NAMES, make_sync_program, sync_knobs
from repro.el.sweep.spec import SweepSpec
# the knob-layout classification is shared with the single-run placement
# (repro.sharding.el_run_partition_specs) — one source of truth for which
# control-plane inputs carry a trailing per-edge dim
from repro.sharding import (EL_EDGE_KNOBS as _EDGE_KNOBS,
                            EL_SCALAR_KNOBS as _SCALAR_KNOBS)

Params = Any


def knob_names(mode: str) -> Tuple[str, ...]:
    """The traced knob set of the mode's compiled program."""
    return ASYNC_KNOB_NAMES if mode == "async" else KNOB_NAMES


def stack_knobs(cell_cfgs: Sequence[OL4ELConfig]) -> Dict[str, np.ndarray]:
    """Per-cell ``sync_knobs`` / ``async_knobs`` (by the cells' mode)
    stacked along a leading [n_cells] axis."""
    knobs_fn = async_knobs if cell_cfgs[0].mode == "async" else sync_knobs
    per_cell = [knobs_fn(c) for c in cell_cfgs]
    return {k: np.stack([knobs[k] for knobs in per_cell])
            for k in knob_names(cell_cfgs[0].mode)}


def cell_keys(cell_cfgs: Sequence[OL4ELConfig]) -> jax.Array:
    """Stacked per-cell PRNG keys — the exact stream ``run_sync_ingraph``
    / ``run_async_ingraph`` seeds for that cell's config
    (``jax.random.key(seed + 17)``)."""
    # int32 matches the scalar path's x64-disabled seed canonicalization
    # (negative seeds wrap identically; >= 2**31 overflows on both paths)
    seeds = jnp.asarray([c.seed + 17 for c in cell_cfgs], jnp.int32)
    return jax.vmap(jax.random.key)(seeds)


# ---------------------------------------------------------------------------
# Mesh placement (el_state_specs pattern: lead dim over pod/data, inner
# parallel dim over model when divisible)
# ---------------------------------------------------------------------------


def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, np.shape(mesh.devices)))


def sweep_partition_specs(axis_names: Sequence[str],
                          axis_sizes: Dict[str, int],
                          n_cells: int, n_edges: int,
                          mode: str = "sync"
                          ) -> Tuple[P, Dict[str, P]]:
    """PartitionSpecs for (keys, knobs): sweep dim over the edge axes,
    per-edge knob dim over ``model`` when divisible.  Pure (no devices) so
    placement policy is unit-testable; raises ``ValueError`` when the grid
    does not tile the mesh."""
    sweep_axes = tuple(a for a in ("pod", "data") if a in axis_names)
    if not sweep_axes:
        raise ValueError(
            f"mesh axes {tuple(axis_names)} have no edge axes "
            "('pod'/'data') to shard the sweep dim over")
    n_shards = math.prod(axis_sizes[a] for a in sweep_axes)
    if n_cells % n_shards != 0:
        raise ValueError(
            f"sweep of {n_cells} cells does not tile the mesh's "
            f"{sweep_axes} axes ({n_shards} shards); pad the grid (e.g. "
            f"add seeds) to a multiple of {n_shards} or run without a "
            "mesh")
    model_size = axis_sizes.get("model", 1)
    edge_ax = "model" if (model_size > 1
                          and n_edges % model_size == 0) else None
    key_spec = P(sweep_axes)

    def spec_for(name: str) -> P:
        if name in _EDGE_KNOBS:                       # [C, E]
            return P(sweep_axes, edge_ax)
        if name in _SCALAR_KNOBS:                     # [C]
            return P(sweep_axes)
        if name == "costs_ek":                        # [C, E, K] (async)
            return P(sweep_axes, edge_ax, None)
        return P(sweep_axes, None)                    # costs_k [C, K]

    knob_specs = {name: spec_for(name) for name in knob_names(mode)}
    return key_spec, knob_specs


def sweep_input_shardings(mesh, n_cells: int, n_edges: int,
                          mode: str = "sync"):
    """NamedShardings for the vmapped program's (init_params, keys,
    knobs) arguments: params replicated, sweep dim over the edge axes."""
    key_spec, knob_specs = sweep_partition_specs(
        mesh.axis_names, _axis_sizes(mesh), n_cells, n_edges, mode)
    return (NamedSharding(mesh, P()),
            NamedSharding(mesh, key_spec),
            {k: NamedSharding(mesh, s) for k, s in knob_specs.items()})


# ---------------------------------------------------------------------------
# Program construction
# ---------------------------------------------------------------------------


def make_sweep_program(model, edge_data, eval_set, cfg: OL4ELConfig,
                       spec: SweepSpec, *, lr: float, batch: int,
                       n_samples: Optional[np.ndarray] = None,
                       metric_fn: Optional[Callable] = None,
                       metric_name: str = "accuracy",
                       mesh=None):
    """Compile the sweep: ``program(init_params, keys, knobs)`` →
    ``(params_stacked, out_stacked)`` with every output carrying a
    leading ``[n_cells]`` axis.

    The per-cell computation is ``jax.vmap`` of the very same program
    ``run_sync_ingraph`` / ``run_async_ingraph`` drives (picked by
    ``cfg.mode``), so each cell is bit-identical to an independent run
    with that cell's config.
    """
    cfgs = spec.cell_cfgs(cfg)
    # structural fields (n_edges, utility, mode, ...) are identical
    # across cells by SweepSpec construction — any cell builds the program
    make_program = (make_async_program if cfg.mode == "async"
                    else make_sync_program)
    core = make_program(
        model, edge_data, eval_set, cfgs[0], lr=lr, batch=batch,
        n_samples=n_samples, metric_fn=metric_fn, metric_name=metric_name,
        **({"max_events": spec.max_rounds} if cfg.mode == "async"
           else {"max_rounds": spec.max_rounds}))
    vmapped = jax.vmap(core, in_axes=(None, 0, 0))
    if mesh is None:
        return jax.jit(vmapped)
    return jax.jit(vmapped, in_shardings=sweep_input_shardings(
        mesh, spec.n_cells, cfg.n_edges, cfg.mode))


def run_sweep_program(program, init_params: Params,
                      cell_cfgs: List[OL4ELConfig]
                      ) -> Tuple[Params, Dict[str, np.ndarray]]:
    """Execute a compiled sweep program and pull the outputs to host."""
    knobs = stack_knobs(cell_cfgs)
    keys = cell_keys(cell_cfgs)
    params, out = jax.block_until_ready(program(init_params, keys, knobs))
    return params, {k: np.asarray(v) for k, v in out.items()}
