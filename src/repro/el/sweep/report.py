"""``SweepReport`` — reductions over a sweep's per-cell round records.

The raw engine output is a dict of ``[n_cells, ...]`` arrays (metric /
consumed / interval per round, plus per-cell terminal scalars).  The
report reduces those into the artifacts the paper's figures are made of:

  * **learning curves** — mean ± 95% CI over the seed axis for every
    hyperparameter point (Fig. 3/4-style accuracy-vs-consumption);
  * **Pareto frontier** — the non-dominated (resource consumed, final
    accuracy) cells (the Fig. 5 trade-off view);
  * flat rows for the benchmark CSV contract.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.el.sweep.spec import AXIS_ORDER, SweepSpec

_GROUP_AXES = tuple(a for a in AXIS_ORDER if a != "seed")


def _nan_reduce(fn, rows: np.ndarray) -> np.ndarray:
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)   # all-NaN columns
        return fn(rows, axis=0)


@dataclasses.dataclass
class SweepReport:
    """Results of one compiled ablation sweep.

    ``cells`` is the flattened row-major grid (seed fastest); ``out``
    holds the stacked per-cell device outputs pulled to numpy:
    ``metric`` / ``utility`` / ``interval`` / ``consumed`` / ``wall``
    ``[n_cells, max_rounds]`` and ``n_rounds`` ``[n_cells]``,
    ``budgets_left`` ``[n_cells, E]``, ``arm_pulls`` ``[n_cells, K]``,
    ``wall_time`` ``[n_cells]``.  Async grids add per-event ``edge`` /
    ``cost`` histories and per-edge ``arm_pulls`` ``[n_cells, E, K]``
    ("rounds" are merge events there).  Rounds past a cell's termination
    hold NaN metrics (never observed), which the reductions respect.
    """

    spec: SweepSpec
    axes: Dict[str, Tuple]
    cells: List[Dict[str, float]]
    out: Dict[str, np.ndarray]
    policy: str = "ol4el"
    elapsed_s: float = 0.0
    final_params: Any = None

    @property
    def n_cells(self) -> int:
        return len(self.cells)

    # -- per-cell terminals --------------------------------------------------

    def n_rounds(self) -> np.ndarray:
        return np.asarray(self.out["n_rounds"], np.int64)

    def _at_last_round(self, name: str) -> np.ndarray:
        vals = np.asarray(self.out[name], np.float64)
        n = self.n_rounds()
        idx = np.maximum(n - 1, 0)
        picked = vals[np.arange(self.n_cells), idx]
        return np.where(n > 0, picked, np.nan)

    def final_metrics(self) -> np.ndarray:
        """Metric after each cell's last aggregation, [n_cells].  Falls
        back to the host-side final-params scores
        (``score_final_params``) when the workload had no jittable
        in-graph metric."""
        vals = self._at_last_round("metric")
        if np.isnan(vals).all() and "final_metric_host" in self.out:
            return np.asarray(self.out["final_metric_host"], np.float64)
        return vals

    def score_final_params(self, eval_fn) -> bool:
        """Host-side scoring fallback: when the compiled program had no
        in-graph metric (all-NaN history), score each cell's final params
        with ``eval_fn(params) -> float`` and record the results.  No-op
        (returns False) when the in-graph metric exists."""
        if self.final_params is None:
            return False
        if not np.isnan(self._at_last_round("metric")).all():
            return False
        import jax
        self.out["final_metric_host"] = np.asarray(
            [eval_fn(jax.tree.map(lambda x: x[i], self.final_params))
             for i in range(self.n_cells)], np.float64)
        return True

    def total_consumed(self) -> np.ndarray:
        """Total resource consumed (summed over edges), [n_cells]."""
        cons = self._at_last_round("consumed")
        return np.where(np.isnan(cons), 0.0, cons)

    def truncated(self) -> np.ndarray:
        """Per-cell flag: the history cap (``spec.max_rounds``) cut the
        run short of budget exhaustion, so that cell's final metric /
        consumption are mid-run values.  Async cells report this exactly
        (the program's ``n_active`` counts blocks still in flight at
        exit); sync cells fall back to the round-cap heuristic.  Raise
        ``max_rounds`` (async: toward
        ``repro.el.events.default_event_horizon``) for full runs."""
        if "n_active" in self.out:
            return np.asarray(self.out["n_active"]) > 0
        return self.n_rounds() >= self.spec.max_rounds

    # -- seed-axis reductions ------------------------------------------------

    def _seed_groups(self) -> List[Tuple[Dict[str, float], List[int]]]:
        groups: Dict[Tuple, List[int]] = {}
        keys: Dict[Tuple, Dict[str, float]] = {}
        for i, cell in enumerate(self.cells):
            k = tuple(cell[a] for a in _GROUP_AXES)
            groups.setdefault(k, []).append(i)
            keys[k] = {a: cell[a] for a in _GROUP_AXES}
        return [(keys[k], idx) for k, idx in groups.items()]

    def learning_curves(self) -> List[Dict[str, Any]]:
        """Mean ± 95% CI learning curves over the seed axis, one entry per
        (ucb_c, budget, heterogeneity) point.  Round *t* aggregates only
        the seeds still alive at *t* — alive means ``t < n_rounds[cell]``,
        so the consumed curve stays meaningful even for workloads whose
        in-graph metric history is all-NaN (no jittable metric)."""
        metric = np.asarray(self.out["metric"], np.float64)
        consumed = np.asarray(self.out["consumed"], np.float64)
        n_rounds = self.n_rounds()
        n_cols = metric.shape[1]
        curves = []
        for key, idx in self._seed_groups():
            alive = (np.arange(n_cols)[None, :]
                     < n_rounds[idx][:, None])       # [S, R]
            rows = np.where(alive, metric[idx], np.nan)
            n_alive = alive.sum(0)
            mean = _nan_reduce(np.nanmean, rows)
            std = _nan_reduce(np.nanstd, rows)
            ci95 = np.where(n_alive > 1,
                            1.96 * std / np.sqrt(np.maximum(n_alive, 1)),
                            0.0)
            r_max = int(n_rounds[idx].max())
            curves.append({
                **key,
                "n_seeds": len(idx),
                "rounds": r_max,
                "mean": mean[:r_max],
                "ci95": ci95[:r_max],
                "consumed": _nan_reduce(np.nanmean,
                                        np.where(alive, consumed[idx],
                                                 np.nan))[:r_max],
            })
        return curves

    def grouped_rows(self) -> List[Dict[str, float]]:
        """Seed-mean summary per (ucb_c, budget, heterogeneity) point."""
        finals = self.final_metrics()
        consumed = self.total_consumed()
        rows = []
        for key, idx in self._seed_groups():
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                m = float(np.nanmean(finals[idx]))
                s = float(np.nanstd(finals[idx]))
            rows.append({**key, "n_seeds": len(idx), "final_metric": m,
                         "final_metric_std": s,
                         "total_consumed": float(np.mean(consumed[idx]))})
        return rows

    # -- the accuracy-vs-resource trade-off ----------------------------------

    def pareto_frontier(self, group_seeds: bool = True
                        ) -> List[Dict[str, float]]:
        """Non-dominated (total consumed ↓, final metric ↑) points.

        With ``group_seeds`` (default) each hyperparameter point enters as
        its seed-mean before domination is applied, so the frontier is
        over configurations, not lucky seeds."""
        if group_seeds:
            points = self.grouped_rows()
        else:
            finals = self.final_metrics()
            consumed = self.total_consumed()
            points = [{**cell, "final_metric": float(finals[i]),
                       "total_consumed": float(consumed[i])}
                      for i, cell in enumerate(self.cells)]
        points = [p for p in points if np.isfinite(p["final_metric"])]
        points.sort(key=lambda p: (p["total_consumed"],
                                   -p["final_metric"]))
        frontier, best = [], -np.inf
        for p in points:
            if p["final_metric"] > best:
                frontier.append(p)
                best = p["final_metric"]
        return frontier

    # -- export --------------------------------------------------------------

    def to_rows(self) -> List[Dict[str, float]]:
        """One flat dict per cell (the benchmark CSV contract)."""
        finals = self.final_metrics()
        consumed = self.total_consumed()
        n_rounds = self.n_rounds()
        return [{**cell,
                 "final_metric": float(finals[i]),
                 "total_consumed": float(consumed[i]),
                 "n_rounds": int(n_rounds[i]),
                 "wall_time": float(self.out["wall_time"][i])}
                for i, cell in enumerate(self.cells)]

    def best_cell(self) -> Optional[Dict[str, float]]:
        finals = self.final_metrics()
        if not np.isfinite(finals).any():
            return None
        return self.to_rows()[int(np.nanargmax(finals))]

    def summary(self) -> str:
        finals = self.final_metrics()
        ok = np.isfinite(finals)
        lo = float(np.nanmin(finals)) if ok.any() else float("nan")
        hi = float(np.nanmax(finals)) if ok.any() else float("nan")
        trunc = int(self.truncated().sum())
        return (f"sweep[{self.policy}] {self.n_cells} cells "
                f"({', '.join(f'{k}×{len(v)}' for k, v in self.axes.items())}"
                f"): metric {lo:.4f}..{hi:.4f}, "
                f"{len(self.pareto_frontier())} Pareto points, "
                f"{self.elapsed_s:.1f}s"
                + (f" [{trunc} cells truncated at max_rounds="
                   f"{self.spec.max_rounds}]" if trunc else ""))
