"""State containers for the async event engine: the per-edge bandit
fleet and slice/place helpers shared by the compiled program and the
host reference loop.

The fleet is the stacked form of ``jax_bandit_init`` — a dict of arrays
with a leading ``[E]`` edge dim — so one ``lax.while_loop`` carry holds
every edge's sufficient statistics and a single dynamic index selects
the event edge's bandit.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.bandit import jax_bandit_init

BanditFleet = Dict[str, jax.Array]


def bandit_fleet_init(n_edges: int, n_arms: int) -> BanditFleet:
    """One fresh bandit per edge, stacked along a leading [E] dim."""
    return jax.vmap(lambda _: jax_bandit_init(n_arms))(jnp.arange(n_edges))


def bandit_slice(fleet: BanditFleet, edge: jax.Array) -> BanditFleet:
    """Edge ``edge``'s bandit state (the unstacked jax_bandit_* shape)."""
    return {k: v[edge] for k, v in fleet.items()}


def bandit_place(fleet: BanditFleet, edge: jax.Array,
                 state: BanditFleet) -> BanditFleet:
    """Write one edge's (updated) bandit state back into the fleet."""
    return {k: fleet[k].at[edge].set(state[k]) for k in fleet}
