"""The host reference event queue — ``run_async`` on jax RNG streams.

``ELSession.run_async(rng_streams="jax")`` lands here: the SAME
priority-queue event loop as the legacy numpy-RNG host path (heap of
``(finish_time, edge, interval, cost)`` blocks, staleness merges,
per-edge bandits, charge-at-completion budgets), but every random draw —
arm selection, minibatch sampling, cost noise — comes from the
``jax.random`` chain the compiled event-horizon program uses
(``scheduler.split_init_keys`` / ``split_event_keys``), and every piece
of arithmetic runs through the very kernels the program inlines
(``make_async_kernels``), in float32.

That makes this loop the *transparent* twin of the compiled scheduler:
in fixed-cost mode, ``run_async(rng_streams="jax")`` and
``run_async_ingraph()`` agree bit-for-bit on event order, merge values
and charged costs (the acceptance test in ``tests/test_el_events.py``) —
any divergence is a scheduler-compilation bug, never RNG noise.
"""

from __future__ import annotations

import heapq
import time
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import OL4ELConfig
from repro.core.bandit import jax_bandit_init
from repro.el.events.knobs import async_knobs, default_event_horizon
from repro.el.events.program import make_async_kernels
from repro.el.events.scheduler import split_event_keys, split_init_keys
from repro.el.report import ELReport, RoundRecord

Params = Any


def run_async_reference(executor, cfg: OL4ELConfig, init_params: Params, *,
                        metric_name: str = "accuracy",
                        metric_fn: Optional[Callable] = None,
                        max_events: Optional[int] = None,
                        callbacks: Sequence[Callable] = ()) -> ELReport:
    """Run the async event queue on the host with the compiled program's
    jax RNG streams and f32 arithmetic; returns an ``ELReport``.

    The metric is evaluated at every event (the utility stream feeds the
    bandits, so it cannot be thinned the way the numpy path's
    ``eval_every`` does).
    """
    t0 = time.perf_counter()
    horizon = (default_event_horizon(cfg) if max_events is None
               else int(max_events))
    kernels = make_async_kernels(
        executor.model, executor.edge_data, executor.eval_set, cfg,
        lr=executor.lr, batch=executor.batch, metric_fn=metric_fn,
        metric_name=metric_name)
    knobs = {k: jnp.asarray(v) for k, v in async_knobs(cfg).items()}
    n_edges, k_arms = cfg.n_edges, cfg.max_interval

    def schedule(edge: int, bstate, resid, wall, k_sel, k_cost):
        return kernels["schedule"](
            bstate, resid, knobs["costs_ek"][edge], knobs["ucb_c"],
            knobs["min_edge_cost"][edge], knobs["cost_noise"],
            knobs["comp"][edge], knobs["comm"][edge], wall,
            jax.random.fold_in(k_sel, edge),
            jax.random.fold_in(k_cost, edge))

    rng = jax.random.key(cfg.seed + 17)
    rng, k_sel0, k_cost0 = split_init_keys(rng)
    bandits = [jax_bandit_init(k_arms) for _ in range(n_edges)]
    # in-flight blocks: (finish_time, edge, interval, cost) — the same
    # realized-cost draw sets the finish time AND is charged at
    # completion (charged == scheduled)
    heap: List[Tuple[float, int, int, float]] = []
    for e in range(n_edges):
        active, interval, cost, finish = schedule(
            e, bandits[e], knobs["budget"], jnp.float32(0.0),
            k_sel0, k_cost0)
        if bool(active):
            heapq.heappush(heap, (float(finish), e, int(interval),
                                  float(cost)))

    global_params = init_params
    edge_params: List[Params] = [init_params] * n_edges
    consumed = jnp.zeros((n_edges,), jnp.float32)
    fetch_version = np.zeros(n_edges, np.int64)
    version = 0
    if kernels["metric"] is not None:
        prev_metric = kernels["metric"](init_params)
    else:
        prev_metric = jnp.float32(jnp.nan)
    records: List[RoundRecord] = []
    wall, t = 0.0, 0
    while heap and t < horizon:
        wall, e, interval, cost = heapq.heappop(heap)
        rng, k_sel, k_data, k_cost = split_event_keys(rng)
        # edge e finishes `interval` local iterations and uploads
        p_new = kernels["local_train"](edge_params[e], e, interval,
                                       jax.random.fold_in(k_data, e))
        consumed = consumed.at[e].add(jnp.float32(cost))
        new_global = kernels["merge"](global_params, p_new,
                                      knobs["async_alpha"], version,
                                      int(fetch_version[e]))
        version += 1
        # ONE kernel yields (metric, utility) — the same fused expression
        # the compiled program rounds through (see make_async_kernels)
        metric, utility = kernels["eval_step"](new_global, global_params,
                                               prev_metric)
        bandits[e] = kernels["bandit_update"](bandits[e], interval - 1,
                                              utility, jnp.float32(cost))
        t += 1
        rec = RoundRecord(wall, float(jnp.sum(consumed)), float(metric),
                          float(utility), float(interval), e, t)
        records.append(rec)
        for cb in callbacks:
            cb(rec)
        # edge fetches the fresh global model, schedules its next block
        edge_params[e] = new_global
        fetch_version[e] = version
        resid = knobs["budget"] - consumed[e]
        active, nxt_i, nxt_c, finish = schedule(
            e, bandits[e], resid, jnp.float32(wall), k_sel, k_cost)
        if bool(active):
            heapq.heappush(heap, (float(finish), e, int(nxt_i),
                                  float(nxt_c)))
        prev_metric = metric
        global_params = new_global

    pulls = np.zeros(k_arms, np.int64)
    for b in bandits:
        pulls += np.asarray(b["counts"], np.int64)
    final = executor.evaluate(global_params)[metric_name]
    return ELReport(
        records=records,
        final_metric=float(final),
        n_aggregations=t,
        total_consumed=float(jnp.sum(consumed)),
        wall_time=wall,
        terminated_reason="max_events" if heap else "budget_exhausted",
        policy=cfg.policy,
        mode="async",
        arm_pulls=[int(c) for c in pulls],
        elapsed_s=time.perf_counter() - t0,
        final_params=global_params,
    )
