"""The compiled asynchronous EL engine: one XLA program per async run.

The host ``ELSession.run_async`` drives a Python priority queue: pop the
next finishing edge, train its block, staleness-merge it into the global
model, update that edge's bandit, schedule its next block.  This module
reformulates that event loop with **no host priority queue** (à la
Mohammad & Sorour's asynchronous mobile edge learning): edge finish
times live in an ``[n_edges]`` array, and each ``lax.while_loop`` step

    argmin finish-time  (the next event)
      → masked local block on the event edge (shared ``make_local_block``)
      → staleness-weighted masked merge (``jnp.where``-free tree mix,
        scatter into the per-edge fetched-params stack)
      → in-graph utility → per-edge ``jax_bandit_update`` + budget charge
      → schedule the edge's next block (``schedule_block``), advancing
        its finish time — or ``+inf`` when its budget affords no arm

until budget exhaustion silences every edge or the fixed event horizon
is reached.  An entire async run — hundreds of events — is ONE compiled
program with zero host synchronization, the async half of the paper's
headline claim joining the fast path.

Like the sync program, the control-plane knobs (``ASYNC_KNOB_NAMES``)
are traced inputs — ``make_async_program`` returns
``program(init_params, rng, knobs)`` — so ``repro.el.sweep`` vmaps one
program over a flattened ablation grid (now including ``async_alpha``
and ``cost_noise`` axes) and shards it over the mesh like sync cells.

``make_async_kernels`` jits the *same* sub-computations individually for
the host reference event queue (``repro.el.events.reference``); in
fixed-cost mode the two paths are bit-identical (tested).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config import OL4ELConfig
from repro.core.bandit import jax_bandit_update
from repro.el.events.knobs import ASYNC_KNOB_NAMES  # noqa: F401 (re-export)
from repro.el.events.scheduler import (schedule_block, split_event_keys,
                                       split_init_keys, staleness_alpha,
                                       staleness_merge)
from repro.el.events.state import (bandit_fleet_init, bandit_place,
                                   bandit_slice)
from repro.el.ingraph import (ELCell, _edge_stack_constraints,
                              _pad_edge_data, _shard_edge_data, _tree_l2,
                              check_ingraph_support, default_metric_fn,
                              make_local_block)

Params = Any


def _build_parts(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                 lr: float, batch: int, metric_fn: Optional[Callable],
                 metric_name: str, mesh=None):
    """The data-plane pieces both async paths share: the masked local
    block (identical minibatch streams to the sync program's) and the
    jittable eval metric.  With ``mesh=`` the per-edge datasets live
    sharded over the mesh's edge axes (the host reference kernels never
    pass one)."""
    xs, ys, n_per_edge = _pad_edge_data(edge_data)
    if mesh is not None:
        xs, ys = _shard_edge_data(mesh, cfg.n_edges, xs, ys)
    local_block = make_local_block(model, xs, ys, n_per_edge, batch, lr,
                                   cfg.max_interval)
    if metric_fn is None:
        metric_fn = default_metric_fn(model, eval_set, metric_name)
    if cfg.utility == "eval_gain" and metric_fn is None:
        raise ValueError(
            "utility='eval_gain' needs a jittable metric; pass metric_fn= "
            "or use utility='param_delta'")

    # ONE closure computes (metric, utility) for both async paths: XLA
    # may fuse the metric's final multiply into the gain subtraction as
    # an FMA (skipping the intermediate rounding), so the compiled
    # program and the reference kernels must present it the identical
    # expression to round identically.
    def eval_step(params, prev_params, prev_metric):
        if metric_fn is not None:
            metric = metric_fn(params)
        else:
            metric = jnp.float32(jnp.nan)
        if cfg.utility == "eval_gain":
            utility = metric - prev_metric
        else:                              # param_delta (§III.A)
            utility = 1.0 / (1.0 + _tree_l2(prev_params, params))
        return metric, utility

    return local_block, metric_fn, eval_step


def make_async_cell(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                    lr: float, batch: int,
                    n_samples: Optional[np.ndarray] = None,
                    metric_fn: Optional[Callable] = None,
                    metric_name: str = "accuracy",
                    max_events: int = 256, mesh=None,
                    telemetry=None) -> ELCell:
    """The budgeted async event loop as an :class:`repro.el.ingraph.ELCell`
    — the unfused form of ``make_async_program`` (which recomposes
    exactly these closures into one ``lax.while_loop`` over events); see
    that function for the semantics, knob contract and mesh placement.

    ``telemetry=`` is the static in-graph observability gate (see
    ``make_sync_cell``): off builds exactly today's carry; on adds a
    ``carry["telem"]`` ring subtree recording, per event, the edge, arm,
    realized charge, the edge's residual budget, the staleness-weighted
    merge ``alpha`` (and the raw staleness), event inter-arrival time
    and the event edge's per-arm bandit statistics.
    """
    from repro.obs.rings import (as_spec, async_ring_init,
                                 async_ring_record, finalize_telemetry)
    spec = as_spec(telemetry)
    del n_samples
    check_ingraph_support(cfg, caller="make_async_program")

    n_edges, k = cfg.n_edges, cfg.max_interval
    local_block, metric_fn, eval_step = _build_parts(
        model, edge_data, eval_set, cfg, lr=lr, batch=batch,
        metric_fn=metric_fn, metric_name=metric_name, mesh=mesh)
    constrain_edge_stack, gather_edge_stack = _edge_stack_constraints(
        mesh, n_edges)

    def init(init_params: Params, rng: jax.Array,
             knobs: Dict[str, jax.Array]) -> Dict[str, Any]:
        ucb_c, budget = knobs["ucb_c"], knobs["budget"]
        costs_ek = knobs["costs_ek"]                            # [E, K]

        fleet = bandit_fleet_init(n_edges, k)
        # initial scheduling: every edge selects its first block, in edge
        # order (host loop's pre-event decide/realized_cost round)
        rng, k_sel0, k_cost0 = split_init_keys(rng)

        def init_edge(e):
            return schedule_block(
                bandit_slice(fleet, e), budget, costs_ek[e], ucb_c,
                knobs["min_edge_cost"][e], knobs["cost_noise"],
                knobs["comp"][e], knobs["comm"][e],
                jnp.float32(0.0), jax.random.fold_in(k_sel0, e),
                jax.random.fold_in(k_cost0, e))

        _, interval0, cost0, finish0 = jax.vmap(init_edge)(
            jnp.arange(n_edges))

        edge_params = constrain_edge_stack(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_edges,) + x.shape),
            init_params))
        if metric_fn is not None:
            prev_metric = metric_fn(init_params)
        else:
            prev_metric = jnp.float32(jnp.nan)
        hist = {
            "metric": jnp.full((max_events,), jnp.nan, jnp.float32),
            "utility": jnp.zeros((max_events,), jnp.float32),
            "interval": jnp.zeros((max_events,), jnp.int32),
            "edge": jnp.full((max_events,), -1, jnp.int32),
            "cost": jnp.zeros((max_events,), jnp.float32),
            "consumed": jnp.zeros((max_events,), jnp.float32),
            "wall": jnp.zeros((max_events,), jnp.float32),
        }
        carry = {"gparams": init_params, "edge_params": edge_params,
                 "fleet": fleet,
                 "consumed": jnp.zeros((n_edges,), jnp.float32),
                 "finish": finish0, "infl_i": interval0, "infl_c": cost0,
                 "fetch_ver": jnp.zeros((n_edges,), jnp.int32),
                 "version": jnp.int32(0), "t": jnp.int32(0), "rng": rng,
                 "prev_metric": prev_metric, "wall": jnp.float32(0.0),
                 "hist": hist}
        if spec is not None:
            carry["telem"] = async_ring_init(spec, k)
        return carry

    def cond(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        return ((carry["t"] < max_events)
                & jnp.any(jnp.isfinite(carry["finish"])))

    def body(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        ucb_c, budget = knobs["ucb_c"], knobs["budget"]
        costs_ek = knobs["costs_ek"]                            # [E, K]
        alpha0 = knobs["async_alpha"]
        gparams, edge_params = carry["gparams"], carry["edge_params"]
        fleet, consumed = carry["fleet"], carry["consumed"]
        finish = carry["finish"]
        infl_i, infl_c = carry["infl_i"], carry["infl_c"]
        fetch_ver, version = carry["fetch_ver"], carry["version"]
        t, prev_metric = carry["t"], carry["prev_metric"]
        hist = carry["hist"]

        rng, k_sel, k_data, k_cost = split_event_keys(carry["rng"])
        # the event horizon: the earliest-finishing in-flight block
        e = jnp.argmin(finish)
        wall = finish[e]
        interval, cost = infl_i[e], infl_c[e]
        # edge e finishes `interval` local iterations and uploads;
        # its slice of the sharded stack is gathered replicated so
        # the block/merge arithmetic runs identically on every
        # device (the event path is control plane)
        p_e = gather_edge_stack(jax.tree.map(lambda a: a[e],
                                             edge_params))
        p_new = local_block(p_e, e, interval,
                            jax.random.fold_in(k_data, e))
        # the SAME realized-cost draw set the finish time and is
        # charged at completion (charged == scheduled)
        consumed = consumed.at[e].add(cost)
        alpha = staleness_alpha(alpha0, version, fetch_ver[e], n_edges)
        if spec is not None:
            # the raw staleness (staleness_alpha's exact f32
            # expression), recorded in the telemetry ring below
            stale = ((version - fetch_ver[e]).astype(jnp.float32)
                     / jnp.float32(max(n_edges, 1)))
        new_global = staleness_merge(gparams, p_new, alpha)
        version = version + 1
        metric, utility = eval_step(new_global, gparams, prev_metric)
        bstate_e = jax_bandit_update(bandit_slice(fleet, e),
                                     interval - 1, utility, cost)
        fleet = bandit_place(fleet, e, bstate_e)
        # edge fetches the fresh global model, schedules next block
        # (the scatter re-pins the stack's sharding so the
        # while-loop carry layout is stable across iterations)
        edge_params = constrain_edge_stack(jax.tree.map(
            lambda a, g: a.at[e].set(g), edge_params, new_global))
        fetch_ver = fetch_ver.at[e].set(version)
        resid = budget - consumed[e]
        _, nxt_i, nxt_c, fin = schedule_block(
            bstate_e, resid, costs_ek[e], ucb_c,
            knobs["min_edge_cost"][e], knobs["cost_noise"],
            knobs["comp"][e], knobs["comm"][e], wall,
            jax.random.fold_in(k_sel, e),
            jax.random.fold_in(k_cost, e))
        finish = finish.at[e].set(fin)
        infl_i = infl_i.at[e].set(nxt_i)
        infl_c = infl_c.at[e].set(nxt_c)
        hist = {
            "metric": hist["metric"].at[t].set(metric),
            "utility": hist["utility"].at[t].set(utility),
            "interval": hist["interval"].at[t].set(interval),
            "edge": hist["edge"].at[t].set(e.astype(jnp.int32)),
            "cost": hist["cost"].at[t].set(cost),
            "consumed": hist["consumed"].at[t].set(jnp.sum(consumed)),
            "wall": hist["wall"].at[t].set(wall),
        }
        new_carry = {"gparams": new_global, "edge_params": edge_params,
                     "fleet": fleet, "consumed": consumed,
                     "finish": finish, "infl_i": infl_i,
                     "infl_c": infl_c, "fetch_ver": fetch_ver,
                     "version": version, "t": t + 1, "rng": rng,
                     "prev_metric": metric, "wall": wall, "hist": hist}
        if spec is not None:
            with jax.named_scope("obs.telemetry"):
                new_carry["telem"] = async_ring_record(
                    carry["telem"], spec, t=t, edge=e,
                    arm=interval - 1, cost=cost, budget_resid=resid,
                    alpha=alpha, staleness=stale,
                    interarrival=wall - carry["wall"],
                    bstate_e=bstate_e)
        return new_carry

    def finalize(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        out = dict(carry["hist"])
        out["n_rounds"] = carry["t"]
        out["budgets_left"] = knobs["budget"] - carry["consumed"]
        out["arm_pulls"] = carry["fleet"]["counts"]             # [E, K]
        out["wall_time"] = carry["wall"]
        # blocks still in flight at exit: 0 means the budgets silenced
        # every edge (terminated_reason="budget_exhausted"), >0 means
        # the event horizon cut the run short ("max_events")
        out["n_active"] = jnp.sum(
            jnp.isfinite(carry["finish"]).astype(jnp.int32))
        if spec is not None:
            out["telemetry"] = finalize_telemetry(carry["telem"],
                                                  carry["t"], spec)
        return carry["gparams"], out

    return ELCell(init=init, cond=cond, body=body, finalize=finalize,
                  horizon=max_events)


def make_async_program(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                       lr: float, batch: int,
                       n_samples: Optional[np.ndarray] = None,
                       metric_fn: Optional[Callable] = None,
                       metric_name: str = "accuracy",
                       max_events: int = 256, mesh=None,
                       telemetry=None):
    """Build ``program(init_params, rng, knobs) -> (params, out)`` — the
    whole budgeted async run as one ``lax.while_loop`` over events, with
    the control-plane knobs (``ASYNC_KNOB_NAMES`` / ``async_knobs``) as
    traced inputs.

    ``n_samples`` is accepted for signature parity with the sync program
    and ignored: the async global update is the staleness mix, not a
    weighted average.

    With ``mesh=`` the big per-edge state — the datasets and the
    ``[n_edges, ...]`` fetched-params stack each edge trains from —
    shards over the mesh's (``pod``, ``data``) axes and its tensor dims
    over ``model`` (``el_stacked_param_specs`` layout), so a large fleet's
    model copies spread across devices instead of replicating E-fold.
    The event edge's slice is gathered replicated before its local
    block, merge and bandit update (the replicated control plane:
    finish times, budgets, bandit fleet), which keeps every computed
    value — and hence the whole run — bit-identical to the unsharded
    program (tested on a debug mesh).

    ``out`` is a dict of device arrays: per-event ``metric``,
    ``utility``, ``interval``, ``edge``, ``cost`` (the charge),
    ``consumed`` (cumulative total across edges) and ``wall`` (the event
    time), plus scalars ``n_rounds`` (events completed), ``wall_time``,
    the final per-edge ``budgets_left`` and the per-edge bandit
    ``arm_pulls`` ``[E, K]``.
    """
    cell = make_async_cell(
        model, edge_data, eval_set, cfg, lr=lr, batch=batch,
        n_samples=n_samples, metric_fn=metric_fn, metric_name=metric_name,
        max_events=max_events, mesh=mesh, telemetry=telemetry)

    def program(init_params: Params, rng: jax.Array,
                knobs: Dict[str, jax.Array]):
        carry = lax.while_loop(lambda c: cell.cond(c, knobs),
                               lambda c: cell.body(c, knobs),
                               cell.init(init_params, rng, knobs))
        return cell.finalize(carry, knobs)

    return program


def make_async_kernels(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                       lr: float, batch: int,
                       metric_fn: Optional[Callable] = None,
                       metric_name: str = "accuracy") -> Dict[str, Any]:
    """The per-event sub-computations of ``make_async_program``, jitted
    individually for the host reference event queue — same closures,
    same ops, same key contracts, so the reference reproduces the
    compiled program's arithmetic exactly."""
    check_ingraph_support(cfg, caller="make_async_kernels")
    local_block, metric_fn, eval_step = _build_parts(
        model, edge_data, eval_set, cfg, lr=lr, batch=batch,
        metric_fn=metric_fn, metric_name=metric_name)
    n_edges = cfg.n_edges

    def merge(gparams, p_new, alpha0, version, fetch_ver):
        alpha = staleness_alpha(alpha0, version, fetch_ver, n_edges)
        return staleness_merge(gparams, p_new, alpha)

    return {
        "local_train": jax.jit(local_block),
        "schedule": jax.jit(schedule_block),
        "merge": jax.jit(merge),
        "metric": None if metric_fn is None else jax.jit(metric_fn),
        "eval_step": jax.jit(eval_step),
        "bandit_update": jax.jit(jax_bandit_update),
    }
