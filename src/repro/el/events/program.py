"""The compiled asynchronous EL engine: one XLA program per async run.

The host ``ELSession.run_async`` drives a Python priority queue: pop the
next finishing edge, train its block, staleness-merge it into the global
model, update that edge's bandit, schedule its next block.  This module
reformulates that event loop with **no host priority queue** (à la
Mohammad & Sorour's asynchronous mobile edge learning): edge finish
times live in an ``[n_edges]`` array, and each ``lax.while_loop`` step

    argmin finish-time  (the next event)
      → masked local block on the event edge (shared ``make_local_block``)
      → staleness-weighted masked merge (``jnp.where``-free tree mix,
        scatter into the per-edge fetched-params stack)
      → in-graph utility → per-edge ``jax_bandit_update`` + budget charge
      → schedule the edge's next block (``schedule_block``), advancing
        its finish time — or ``+inf`` when its budget affords no arm

until budget exhaustion silences every edge or the fixed event horizon
is reached.  An entire async run — hundreds of events — is ONE compiled
program with zero host synchronization, the async half of the paper's
headline claim joining the fast path.

**K-event waves** (``batch_k > 1``, the sharded fast path): instead of
one argmin pop per loop step, a wave pops the K earliest completions
with ``lax.top_k``, accepts the prefix of lanes that provably precede
any block an earlier lane could reschedule (``wave_safe_gap`` — a
rescheduled block costs at least ``fl(min_edge_cost · mult_floor)``, so
every lane with ``f_(j) < fl(f_(0) + gap)`` is order-safe), runs the
accepted lanes' local blocks as ONE vmapped dispatch over a slice-local
``[K, ...]`` gather of the fetched-params stack, and replays the merge /
bandit / schedule control plane sequentially per lane (masked
``lax.cond``) so every computed value equals the one-event program's.
Wave lanes are always DISTINCT edges (one in-flight block per edge), the
per-event RNG chain advances exactly ``n_batch`` splits, and history /
telemetry writes coalesce into one drop-mode vector scatter per field —
the processed event order, merge values, charged costs and arm pulls are
identical to ``batch_k=1`` (tested), while the while-loop iterates ~K
times fewer, amortizing the sharded control plane's per-step collectives.

Like the sync program, the control-plane knobs (``ASYNC_KNOB_NAMES``)
are traced inputs — ``make_async_program`` returns
``program(init_params, rng, knobs)`` — so ``repro.el.sweep`` vmaps one
program over a flattened ablation grid (now including ``async_alpha``
and ``cost_noise`` axes) and shards it over the mesh like sync cells.

``make_async_kernels`` jits the *same* sub-computations individually for
the host reference event queue (``repro.el.events.reference``); in
fixed-cost mode the two paths are bit-identical (tested).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config import OL4ELConfig
from repro.core.bandit import jax_bandit_update
from repro.el.events.knobs import ASYNC_KNOB_NAMES  # noqa: F401 (re-export)
from repro.el.events.knobs import resolve_async_batch_k
from repro.el.events.scheduler import (schedule_block, split_event_keys,
                                       split_init_keys, staleness_alpha,
                                       staleness_merge, wave_safe_gap)
from repro.el.events.state import (bandit_fleet_init, bandit_place,
                                   bandit_slice)
from repro.el.ingraph import (ELCell, _edge_stack_constraints,
                              _pad_edge_data, _shard_edge_data, _tree_l2,
                              check_ingraph_support, default_metric_fn,
                              make_local_block)

Params = Any


def _build_parts(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                 lr: float, batch: int, metric_fn: Optional[Callable],
                 metric_name: str, mesh=None, drift: bool = False):
    """The data-plane pieces both async paths share: the masked local
    block (identical minibatch streams to the sync program's) and the
    jittable eval metric.  With ``mesh=`` the per-edge datasets live
    sharded over the mesh's edge axes (the host reference kernels never
    pass one).  ``drift=`` builds the scenario path's drift-aware block
    (see ``make_local_block``)."""
    xs, ys, n_per_edge = _pad_edge_data(edge_data)
    if mesh is not None:
        xs, ys = _shard_edge_data(mesh, cfg.n_edges, xs, ys)
    local_block = make_local_block(model, xs, ys, n_per_edge, batch, lr,
                                   cfg.max_interval, drift=drift)
    if metric_fn is None:
        metric_fn = default_metric_fn(model, eval_set, metric_name)
    if cfg.utility == "eval_gain" and metric_fn is None:
        raise ValueError(
            "utility='eval_gain' needs a jittable metric; pass metric_fn= "
            "or use utility='param_delta'")

    # ONE closure computes (metric, utility) for both async paths: XLA
    # may fuse the metric's final multiply into the gain subtraction as
    # an FMA (skipping the intermediate rounding), so the compiled
    # program and the reference kernels must present it the identical
    # expression to round identically.
    def eval_step(params, prev_params, prev_metric):
        if metric_fn is not None:
            metric = metric_fn(params)
        else:
            metric = jnp.float32(jnp.nan)
        if cfg.utility == "eval_gain":
            utility = metric - prev_metric
        else:                              # param_delta (§III.A)
            utility = 1.0 / (1.0 + _tree_l2(prev_params, params))
        return metric, utility

    return local_block, metric_fn, eval_step


def make_async_cell(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                    lr: float, batch: int,
                    n_samples: Optional[np.ndarray] = None,
                    metric_fn: Optional[Callable] = None,
                    metric_name: str = "accuracy",
                    max_events: int = 256, mesh=None,
                    telemetry=None,
                    batch_k: Optional[int] = None) -> ELCell:
    """The budgeted async event loop as an :class:`repro.el.ingraph.ELCell`
    — the unfused form of ``make_async_program`` (which recomposes
    exactly these closures into one ``lax.while_loop`` over events); see
    that function for the semantics, knob contract and mesh placement.

    ``telemetry=`` is the static in-graph observability gate (see
    ``make_sync_cell``): off builds exactly today's carry; on adds a
    ``carry["telem"]`` ring subtree recording, per event, the edge, arm,
    realized charge, the edge's residual budget, the staleness-weighted
    merge ``alpha`` (and the raw staleness), event inter-arrival time
    and the event edge's per-arm bandit statistics.

    ``batch_k=`` is the static K-event wave width (see the module
    docstring); ``None`` resolves it from the config and mesh
    (``resolve_async_batch_k``).  ``batch_k=1`` builds exactly the
    single-event argmin-pop body; ``> 1`` builds the order-equivalent
    wave body.
    """
    from repro.obs.rings import (as_spec, async_ring_init,
                                 async_ring_record,
                                 async_ring_record_wave,
                                 finalize_telemetry)
    spec = as_spec(telemetry)
    del n_samples
    check_ingraph_support(cfg, caller="make_async_program")
    # fleet-dynamics scenario: None keeps every closure below EXACTLY
    # today's traced code; a ScenarioSpec swaps in the churn-aware
    # single-event body (dropout probes, uncharged dead edges).
    scn = cfg.scenario
    period = scn.period if scn is not None else 0

    n_edges, k = cfg.n_edges, cfg.max_interval
    if batch_k is None:
        batch_k = resolve_async_batch_k(cfg, mesh)
    batch_k = max(1, min(int(batch_k), n_edges))
    if scn is not None and batch_k > 1:
        raise ValueError(
            f"async_batch_k={batch_k} with a ScenarioSpec: the scenario "
            "path (per-event activity masks, dropout probes) is defined "
            "on the single-event program only — pin async_batch_k=1 or "
            "leave it 0 (auto resolves to 1 under a scenario)")
    if spec is not None and batch_k > spec.ring_size:
        raise ValueError(
            f"async_batch_k={batch_k} exceeds the telemetry ring size "
            f"{spec.ring_size}: a wave's per-event ring writes would "
            "collide within one scatter — raise telemetry= or lower "
            "the batch width")
    local_block, metric_fn, eval_step = _build_parts(
        model, edge_data, eval_set, cfg, lr=lr, batch=batch,
        metric_fn=metric_fn, metric_name=metric_name, mesh=mesh,
        drift=scn is not None)
    constrain_edge_stack, gather_edge_stack = _edge_stack_constraints(
        mesh, n_edges)

    def init(init_params: Params, rng: jax.Array,
             knobs: Dict[str, jax.Array]) -> Dict[str, Any]:
        ucb_c, budget = knobs["ucb_c"], knobs["budget"]
        costs_ek = knobs["costs_ek"]                            # [E, K]

        fleet = bandit_fleet_init(n_edges, k)
        # initial scheduling: every edge selects its first block, in edge
        # order (host loop's pre-event decide/realized_cost round)
        rng, k_sel0, k_cost0 = split_init_keys(rng)

        def init_edge(e):
            return schedule_block(
                bandit_slice(fleet, e), budget, costs_ek[e], ucb_c,
                knobs["min_edge_cost"][e], knobs["cost_noise"],
                knobs["comp"][e], knobs["comm"][e],
                jnp.float32(0.0), jax.random.fold_in(k_sel0, e),
                jax.random.fold_in(k_cost0, e))

        _, interval0, cost0, finish0 = jax.vmap(init_edge)(
            jnp.arange(n_edges))

        edge_params = constrain_edge_stack(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_edges,) + x.shape),
            init_params))
        if metric_fn is not None:
            prev_metric = metric_fn(init_params)
        else:
            prev_metric = jnp.float32(jnp.nan)
        hist = {
            "metric": jnp.full((max_events,), jnp.nan, jnp.float32),
            "utility": jnp.zeros((max_events,), jnp.float32),
            "interval": jnp.zeros((max_events,), jnp.int32),
            "edge": jnp.full((max_events,), -1, jnp.int32),
            "cost": jnp.zeros((max_events,), jnp.float32),
            "consumed": jnp.zeros((max_events,), jnp.float32),
            "wall": jnp.zeros((max_events,), jnp.float32),
        }
        if scn is not None:
            hist["active_edges"] = jnp.zeros((max_events,), jnp.int32)
        carry = {"gparams": init_params, "edge_params": edge_params,
                 "fleet": fleet,
                 "consumed": jnp.zeros((n_edges,), jnp.float32),
                 "finish": finish0, "infl_i": interval0, "infl_c": cost0,
                 "fetch_ver": jnp.zeros((n_edges,), jnp.int32),
                 "version": jnp.int32(0), "t": jnp.int32(0), "rng": rng,
                 "prev_metric": prev_metric, "wall": jnp.float32(0.0),
                 "hist": hist}
        if spec is not None:
            carry["telem"] = async_ring_init(spec, k,
                                             scenario=scn is not None)
        return carry

    def cond(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        # the static horizon sizes the history arrays (bucketed to a
        # power of two by the callers); the traced event_cap knob is the
        # run's exact cap, so nearby caps share one executable
        cap = jnp.minimum(jnp.int32(max_events),
                          knobs["event_cap"].astype(jnp.int32))
        return ((carry["t"] < cap)
                & jnp.any(jnp.isfinite(carry["finish"])))

    def body_one(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        ucb_c, budget = knobs["ucb_c"], knobs["budget"]
        costs_ek = knobs["costs_ek"]                            # [E, K]
        alpha0 = knobs["async_alpha"]
        gparams, edge_params = carry["gparams"], carry["edge_params"]
        fleet, consumed = carry["fleet"], carry["consumed"]
        finish = carry["finish"]
        infl_i, infl_c = carry["infl_i"], carry["infl_c"]
        fetch_ver, version = carry["fetch_ver"], carry["version"]
        t, prev_metric = carry["t"], carry["prev_metric"]
        hist = carry["hist"]

        rng, k_sel, k_data, k_cost = split_event_keys(carry["rng"])
        # the event horizon: the earliest-finishing in-flight block
        e = jnp.argmin(finish)
        wall = finish[e]
        interval, cost = infl_i[e], infl_c[e]
        # edge e finishes `interval` local iterations and uploads;
        # its slice of the sharded stack is gathered replicated so
        # the block/merge arithmetic runs identically on every
        # device (the event path is control plane)
        p_e = gather_edge_stack(jax.tree.map(lambda a: a[e],
                                             edge_params))
        p_new = local_block(p_e, e, interval,
                            jax.random.fold_in(k_data, e))
        # the SAME realized-cost draw set the finish time and is
        # charged at completion (charged == scheduled)
        consumed = consumed.at[e].add(cost)
        alpha = staleness_alpha(alpha0, version, fetch_ver[e], n_edges)
        if spec is not None:
            # the raw staleness (staleness_alpha's exact f32
            # expression), recorded in the telemetry ring below
            stale = ((version - fetch_ver[e]).astype(jnp.float32)
                     / jnp.float32(max(n_edges, 1)))
        new_global = staleness_merge(gparams, p_new, alpha)
        version = version + 1
        metric, utility = eval_step(new_global, gparams, prev_metric)
        bstate_e = jax_bandit_update(bandit_slice(fleet, e),
                                     interval - 1, utility, cost)
        fleet = bandit_place(fleet, e, bstate_e)
        # edge fetches the fresh global model, schedules next block
        # (the scatter re-pins the stack's sharding so the
        # while-loop carry layout is stable across iterations)
        edge_params = constrain_edge_stack(jax.tree.map(
            lambda a, g: a.at[e].set(g), edge_params, new_global))
        fetch_ver = fetch_ver.at[e].set(version)
        resid = budget - consumed[e]
        _, nxt_i, nxt_c, fin = schedule_block(
            bstate_e, resid, costs_ek[e], ucb_c,
            knobs["min_edge_cost"][e], knobs["cost_noise"],
            knobs["comp"][e], knobs["comm"][e], wall,
            jax.random.fold_in(k_sel, e),
            jax.random.fold_in(k_cost, e))
        finish = finish.at[e].set(fin)
        infl_i = infl_i.at[e].set(nxt_i)
        infl_c = infl_c.at[e].set(nxt_c)
        hist = {
            "metric": hist["metric"].at[t].set(metric),
            "utility": hist["utility"].at[t].set(utility),
            "interval": hist["interval"].at[t].set(interval),
            "edge": hist["edge"].at[t].set(e.astype(jnp.int32)),
            "cost": hist["cost"].at[t].set(cost),
            "consumed": hist["consumed"].at[t].set(jnp.sum(consumed)),
            "wall": hist["wall"].at[t].set(wall),
        }
        new_carry = {"gparams": new_global, "edge_params": edge_params,
                     "fleet": fleet, "consumed": consumed,
                     "finish": finish, "infl_i": infl_i,
                     "infl_c": infl_c, "fetch_ver": fetch_ver,
                     "version": version, "t": t + 1, "rng": rng,
                     "prev_metric": metric, "wall": wall, "hist": hist}
        if spec is not None:
            with jax.named_scope("obs.telemetry"):
                new_carry["telem"] = async_ring_record(
                    carry["telem"], spec, t=t, edge=e,
                    arm=interval - 1, cost=cost, budget_resid=resid,
                    alpha=alpha, staleness=stale,
                    interarrival=wall - carry["wall"],
                    bstate_e=bstate_e)
        return new_carry

    def body_wave(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        ucb_c, budget = knobs["ucb_c"], knobs["budget"]
        costs_ek = knobs["costs_ek"]                            # [E, K]
        alpha0 = knobs["async_alpha"]
        edge_params = carry["edge_params"]
        finish = carry["finish"]
        infl_i, infl_c = carry["infl_i"], carry["infl_c"]
        t0, hist = carry["t"], carry["hist"]

        # -- wave selection: the K earliest completions, sorted (ties
        # lower-edge-first, matching successive argmin pops).  A lane is
        # accepted while it finishes strictly before ANY block an
        # earlier lane's reschedule could produce (wave_safe_gap's f32
        # lower bound); every guard is monotone in the lane index, so
        # `valid` is a prefix mask and lane j's event index is t0 + j.
        neg_f, e_sorted = lax.top_k(-finish, batch_k)
        f_sorted = -neg_f
        gap = wave_safe_gap(knobs["min_edge_cost"], knobs["cost_noise"])
        cap = jnp.minimum(jnp.int32(max_events),
                          knobs["event_cap"].astype(jnp.int32))
        lane = jnp.arange(batch_k, dtype=jnp.int32)
        valid = (lane == 0) | (jnp.isfinite(f_sorted)
                               & (f_sorted < f_sorted[0] + gap)
                               & (t0 + lane < cap))
        n_batch = jnp.sum(valid.astype(jnp.int32))

        # -- the per-event RNG chain advances exactly n_batch splits:
        # lane j's keys are the (t0+j)-th split of the run's one chain,
        # identical to batch_k=1 processing the same events
        r = carry["rng"]
        rng_steps, k_sels, k_datas, k_costs = [r], [], [], []
        for _ in range(batch_k):
            r, ks, kd, kc = split_event_keys(r)
            rng_steps.append(r)
            k_sels.append(ks)
            k_datas.append(kd)
            k_costs.append(kc)
        rng = jnp.stack(rng_steps)[n_batch]

        # -- data plane: ONE vmapped dispatch over the wave's lanes.
        # Lanes are distinct edges and each trains from the params its
        # edge fetched BEFORE this wave, so the lanes are data-
        # independent; only the K event slices of the sharded stack are
        # gathered replicated (slice-local), never the full [E, ...]
        # edge stack.
        interval_l = infl_i[e_sorted]                           # [Kw]
        cost_l = infl_c[e_sorted]
        # K scalar gathers, stacked — NOT one vector-index gather: the
        # SPMD partitioner lowers `a[e_sorted]` on the sharded edge
        # stack through a one-hot contraction (all-reduce), while the
        # scalar form keeps the single-event path's slice-local
        # all-gather lowering (the dispatch contract pins all-reduce==0)
        p_stack = gather_edge_stack(jax.tree.map(
            lambda a: jnp.stack([a[e_sorted[j]]
                                 for j in range(batch_k)]),
            edge_params))
        data_keys = jnp.stack([
            jax.random.fold_in(k_datas[j], e_sorted[j])
            for j in range(batch_k)])
        p_new_stack = jax.vmap(local_block)(p_stack, e_sorted,
                                            interval_l, data_keys)

        # -- control plane: the merge chain is inherently sequential
        # (lane j+1 merges into lane j's global), so replay it per lane
        # under a validity mask — the exact op sequence of batch_k=1.
        def lane_step(j, state):
            (gparams, fleet, consumed, fetch_ver, version,
             prev_metric) = state
            e = e_sorted[j]
            wall_j = f_sorted[j]
            interval, cost = interval_l[j], cost_l[j]
            p_new = jax.tree.map(lambda a: a[j], p_new_stack)
            consumed = consumed.at[e].add(cost)
            alpha = staleness_alpha(alpha0, version, fetch_ver[e],
                                    n_edges)
            stale = ((version - fetch_ver[e]).astype(jnp.float32)
                     / jnp.float32(max(n_edges, 1)))
            new_global = staleness_merge(gparams, p_new, alpha)
            version = version + 1
            metric, utility = eval_step(new_global, gparams, prev_metric)
            bstate_e = jax_bandit_update(bandit_slice(fleet, e),
                                         interval - 1, utility, cost)
            fleet = bandit_place(fleet, e, bstate_e)
            fetch_ver = fetch_ver.at[e].set(version)
            resid = budget - consumed[e]
            _, nxt_i, nxt_c, fin = schedule_block(
                bstate_e, resid, costs_ek[e], ucb_c,
                knobs["min_edge_cost"][e], knobs["cost_noise"],
                knobs["comp"][e], knobs["comm"][e], wall_j,
                jax.random.fold_in(k_sels[j], e),
                jax.random.fold_in(k_costs[j], e))
            outs = {"metric": metric, "utility": utility,
                    "interval": interval, "cost": cost,
                    "consumed_sum": jnp.sum(consumed),
                    "resid": resid, "alpha": alpha, "stale": stale,
                    "bcounts": bstate_e["counts"],
                    "butil": bstate_e["utility_sum"],
                    "nxt_i": nxt_i, "nxt_c": nxt_c, "fin": fin,
                    "new_global": new_global}
            return ((new_global, fleet, consumed, fetch_ver, version,
                     metric), outs)

        def lane_skip(state):
            outs = {"metric": jnp.float32(0), "utility": jnp.float32(0),
                    "interval": jnp.int32(0), "cost": jnp.float32(0),
                    "consumed_sum": jnp.float32(0),
                    "resid": jnp.float32(0), "alpha": jnp.float32(0),
                    "stale": jnp.float32(0),
                    "bcounts": jnp.zeros((k,), jnp.int32),
                    "butil": jnp.zeros((k,), jnp.float32),
                    "nxt_i": jnp.int32(0), "nxt_c": jnp.float32(0),
                    "fin": jnp.float32(0), "new_global": state[0]}
            return state, outs

        state = (carry["gparams"], carry["fleet"], carry["consumed"],
                 carry["fetch_ver"], carry["version"],
                 carry["prev_metric"])
        lanes = []
        for j in range(batch_k):
            if j == 0:          # lane 0 is the argmin event: always valid
                state, outs = lane_step(0, state)
            else:
                state, outs = lax.cond(
                    j < n_batch,
                    lambda s, j=j: lane_step(j, s),
                    lane_skip, state)
            lanes.append(outs)
        (gparams, fleet, consumed, fetch_ver, version,
         prev_metric) = state

        stk = {name: jnp.stack([o[name] for o in lanes])
               for name in lanes[0] if name != "new_global"}
        g_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[o["new_global"] for o in lanes])

        # -- coalesced state scatters: invalid lanes route to index
        # n_edges / the horizon and drop
        e_scatter = jnp.where(valid, e_sorted, jnp.int32(n_edges))
        edge_params = constrain_edge_stack(jax.tree.map(
            lambda a, g: a.at[e_scatter].set(g, mode="drop"),
            edge_params, g_stack))
        finish = finish.at[e_scatter].set(stk["fin"], mode="drop")
        infl_i = infl_i.at[e_scatter].set(stk["nxt_i"], mode="drop")
        infl_c = infl_c.at[e_scatter].set(stk["nxt_c"], mode="drop")
        idx = jnp.where(valid, t0 + lane, jnp.int32(max_events))
        hist = {
            "metric": hist["metric"].at[idx].set(stk["metric"],
                                                 mode="drop"),
            "utility": hist["utility"].at[idx].set(stk["utility"],
                                                   mode="drop"),
            "interval": hist["interval"].at[idx].set(stk["interval"],
                                                     mode="drop"),
            "edge": hist["edge"].at[idx].set(e_sorted.astype(jnp.int32),
                                             mode="drop"),
            "cost": hist["cost"].at[idx].set(stk["cost"], mode="drop"),
            "consumed": hist["consumed"].at[idx].set(stk["consumed_sum"],
                                                     mode="drop"),
            "wall": hist["wall"].at[idx].set(f_sorted, mode="drop"),
        }
        wall_out = f_sorted[n_batch - 1]
        new_carry = {"gparams": gparams, "edge_params": edge_params,
                     "fleet": fleet, "consumed": consumed,
                     "finish": finish, "infl_i": infl_i,
                     "infl_c": infl_c, "fetch_ver": fetch_ver,
                     "version": version, "t": t0 + n_batch, "rng": rng,
                     "prev_metric": prev_metric, "wall": wall_out,
                     "hist": hist}
        if spec is not None:
            with jax.named_scope("obs.telemetry"):
                prev_walls = jnp.concatenate(
                    [carry["wall"][None], f_sorted[:-1]])
                new_carry["telem"] = async_ring_record_wave(
                    carry["telem"], spec, t0=t0, valid=valid,
                    edge=e_sorted, arm=interval_l - 1, cost=cost_l,
                    budget_resid=stk["resid"], alpha=stk["alpha"],
                    staleness=stk["stale"],
                    interarrival=f_sorted - prev_walls,
                    arm_counts=stk["bcounts"],
                    arm_utility=stk["butil"])
        return new_carry

    def body_one_scn(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        # the scenario variant of body_one: the popped edge's activity
        # bit decides between a real completion and a dropout PROBE —
        # a probe discards the block (no merge, no charge, no bandit
        # pull, no version bump) and retries the same in-flight block
        # after a reconnect delay, so churned edges burn wall clock but
        # never budget, and the merge chain skips them entirely.
        ucb_c, budget = knobs["ucb_c"], knobs["budget"]
        costs_ek = knobs["costs_ek"]                            # [E, K]
        alpha0 = knobs["async_alpha"]
        scn_active, scn_mult = knobs["scn_active"], knobs["scn_mult"]
        gparams, edge_params = carry["gparams"], carry["edge_params"]
        fleet, consumed = carry["fleet"], carry["consumed"]
        finish = carry["finish"]
        infl_i, infl_c = carry["infl_i"], carry["infl_c"]
        fetch_ver, version = carry["fetch_ver"], carry["version"]
        t, prev_metric = carry["t"], carry["prev_metric"]
        hist = carry["hist"]

        rng, k_sel, k_data, k_cost = split_event_keys(carry["rng"])
        e = jnp.argmin(finish)
        wall = finish[e]
        slot_i = jnp.mod(t, period)
        act_row = scn_active[slot_i] > 0                        # [E]
        is_act = act_row[e]
        interval, cost = infl_i[e], infl_c[e]
        p_e = gather_edge_stack(jax.tree.map(lambda a: a[e],
                                             edge_params))
        # a dropped edge runs zero masked work (interval 0) and the
        # drift shift rotates the sampling window
        shift = knobs["scn_drift"] * t.astype(jnp.float32)
        p_new = local_block(p_e, e, jnp.where(is_act, interval, 0),
                            jax.random.fold_in(k_data, e), shift)
        # charge-at-completion, live edges only: probes are free
        consumed = consumed.at[e].add(jnp.where(is_act, cost, 0.0))
        alpha = staleness_alpha(alpha0, version, fetch_ver[e], n_edges)
        if spec is not None:
            stale = ((version - fetch_ver[e]).astype(jnp.float32)
                     / jnp.float32(max(n_edges, 1)))
        merged = staleness_merge(gparams, p_new, alpha)
        new_global = jax.tree.map(
            lambda m, g: jnp.where(is_act, m, g), merged, gparams)
        version = version + jnp.where(is_act, 1, 0)
        metric, utility = eval_step(new_global, gparams, prev_metric)
        # arm -1 makes the bandit update a no-op (its valid guard), so
        # a probe pulls nothing
        bstate_e = jax_bandit_update(
            bandit_slice(fleet, e),
            jnp.where(is_act, interval - 1, -1), utility, cost)
        fleet = bandit_place(fleet, e, bstate_e)
        # only a live edge refetches the global model
        edge_params = constrain_edge_stack(jax.tree.map(
            lambda a, g: a.at[e].set(jnp.where(is_act, g, a[e])),
            edge_params, new_global))
        fetch_ver = fetch_ver.at[e].set(
            jnp.where(is_act, version, fetch_ver[e]))
        resid = budget - consumed[e]
        # straggler spikes scale the NEXT block's cost surface at
        # scheduling time (cost = m * (i*comp + comm) by linearity)
        m = scn_mult[slot_i, e]
        _, nxt_i, nxt_c, fin = schedule_block(
            bstate_e, resid, costs_ek[e] * m, ucb_c,
            knobs["min_edge_cost"][e] * m, knobs["cost_noise"],
            knobs["comp"][e] * m, knobs["comm"][e] * m, wall,
            jax.random.fold_in(k_sel, e),
            jax.random.fold_in(k_cost, e))
        # a probe keeps its in-flight block and retries after a
        # reconnect delay of the edge's minimum block cost
        fin = jnp.where(is_act, fin,
                        wall + knobs["min_edge_cost"][e])
        nxt_i = jnp.where(is_act, nxt_i, interval)
        nxt_c = jnp.where(is_act, nxt_c, cost)
        finish = finish.at[e].set(fin)
        infl_i = infl_i.at[e].set(nxt_i)
        infl_c = infl_c.at[e].set(nxt_c)
        n_act_fleet = jnp.sum(act_row.astype(jnp.int32))
        hist = {
            "metric": hist["metric"].at[t].set(metric),
            "utility": hist["utility"].at[t].set(
                jnp.where(is_act, utility, 0.0)),
            "interval": hist["interval"].at[t].set(
                jnp.where(is_act, interval, 0)),
            "edge": hist["edge"].at[t].set(e.astype(jnp.int32)),
            "cost": hist["cost"].at[t].set(
                jnp.where(is_act, cost, 0.0)),
            "consumed": hist["consumed"].at[t].set(jnp.sum(consumed)),
            "wall": hist["wall"].at[t].set(wall),
            "active_edges": hist["active_edges"].at[t].set(n_act_fleet),
        }
        new_carry = {"gparams": new_global, "edge_params": edge_params,
                     "fleet": fleet, "consumed": consumed,
                     "finish": finish, "infl_i": infl_i,
                     "infl_c": infl_c, "fetch_ver": fetch_ver,
                     "version": version, "t": t + 1, "rng": rng,
                     "prev_metric": metric, "wall": wall, "hist": hist}
        if spec is not None:
            with jax.named_scope("obs.telemetry"):
                new_carry["telem"] = async_ring_record(
                    carry["telem"], spec, t=t, edge=e,
                    arm=interval - 1,
                    cost=jnp.where(is_act, cost, 0.0),
                    budget_resid=resid, alpha=alpha, staleness=stale,
                    interarrival=wall - carry["wall"],
                    bstate_e=bstate_e,
                    scn=(n_act_fleet,
                         1 - is_act.astype(jnp.int32),
                         jnp.int32(0)))
        return new_carry

    if scn is not None:
        body = body_one_scn
    else:
        body = body_one if batch_k == 1 else body_wave

    def finalize(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        out = dict(carry["hist"])
        out["n_rounds"] = carry["t"]
        out["budgets_left"] = knobs["budget"] - carry["consumed"]
        out["arm_pulls"] = carry["fleet"]["counts"]             # [E, K]
        out["wall_time"] = carry["wall"]
        # blocks still in flight at exit: 0 means the budgets silenced
        # every edge (terminated_reason="budget_exhausted"), >0 means
        # the event horizon cut the run short ("max_events")
        out["n_active"] = jnp.sum(
            jnp.isfinite(carry["finish"]).astype(jnp.int32))
        if spec is not None:
            out["telemetry"] = finalize_telemetry(carry["telem"],
                                                  carry["t"], spec)
        return carry["gparams"], out

    return ELCell(init=init, cond=cond, body=body, finalize=finalize,
                  horizon=max_events)


def make_async_program(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                       lr: float, batch: int,
                       n_samples: Optional[np.ndarray] = None,
                       metric_fn: Optional[Callable] = None,
                       metric_name: str = "accuracy",
                       max_events: int = 256, mesh=None,
                       telemetry=None,
                       batch_k: Optional[int] = None):
    """Build ``program(init_params, rng, knobs) -> (params, out)`` — the
    whole budgeted async run as one ``lax.while_loop`` over events, with
    the control-plane knobs (``ASYNC_KNOB_NAMES`` / ``async_knobs``) as
    traced inputs.

    ``batch_k=`` is the static K-event wave width (module docstring);
    ``None`` auto-resolves from the config and mesh
    (``resolve_async_batch_k``), ``1`` is the single-event argmin-pop
    program, ``> 1`` dispatches K-event waves whose processed order,
    merge values, charged costs and arm pulls are identical (tested).

    ``n_samples`` is accepted for signature parity with the sync program
    and ignored: the async global update is the staleness mix, not a
    weighted average.

    With ``mesh=`` the big per-edge state — the datasets and the
    ``[n_edges, ...]`` fetched-params stack each edge trains from —
    shards over the mesh's (``pod``, ``data``) axes and its tensor dims
    over ``model`` (``el_stacked_param_specs`` layout), so a large fleet's
    model copies spread across devices instead of replicating E-fold.
    The event edge's slice is gathered replicated before its local
    block, merge and bandit update (the replicated control plane:
    finish times, budgets, bandit fleet), which keeps every computed
    value — and hence the whole run — bit-identical to the unsharded
    program (tested on a debug mesh).

    ``out`` is a dict of device arrays: per-event ``metric``,
    ``utility``, ``interval``, ``edge``, ``cost`` (the charge),
    ``consumed`` (cumulative total across edges) and ``wall`` (the event
    time), plus scalars ``n_rounds`` (events completed), ``wall_time``,
    the final per-edge ``budgets_left`` and the per-edge bandit
    ``arm_pulls`` ``[E, K]``.
    """
    cell = make_async_cell(
        model, edge_data, eval_set, cfg, lr=lr, batch=batch,
        n_samples=n_samples, metric_fn=metric_fn, metric_name=metric_name,
        max_events=max_events, mesh=mesh, telemetry=telemetry,
        batch_k=batch_k)

    def program(init_params: Params, rng: jax.Array,
                knobs: Dict[str, jax.Array]):
        carry = lax.while_loop(lambda c: cell.cond(c, knobs),
                               lambda c: cell.body(c, knobs),
                               cell.init(init_params, rng, knobs))
        return cell.finalize(carry, knobs)

    return program


def make_async_kernels(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                       lr: float, batch: int,
                       metric_fn: Optional[Callable] = None,
                       metric_name: str = "accuracy") -> Dict[str, Any]:
    """The per-event sub-computations of ``make_async_program``, jitted
    individually for the host reference event queue — same closures,
    same ops, same key contracts, so the reference reproduces the
    compiled program's arithmetic exactly."""
    check_ingraph_support(cfg, caller="make_async_kernels")
    local_block, metric_fn, eval_step = _build_parts(
        model, edge_data, eval_set, cfg, lr=lr, batch=batch,
        metric_fn=metric_fn, metric_name=metric_name)
    n_edges = cfg.n_edges

    def merge(gparams, p_new, alpha0, version, fetch_ver):
        alpha = staleness_alpha(alpha0, version, fetch_ver, n_edges)
        return staleness_merge(gparams, p_new, alpha)

    return {
        "local_train": jax.jit(local_block),
        "schedule": jax.jit(schedule_block),
        "merge": jax.jit(merge),
        "metric": None if metric_fn is None else jax.jit(metric_fn),
        "eval_step": jax.jit(eval_step),
        "bandit_update": jax.jit(jax_bandit_update),
    }
