"""The event-horizon scheduling math — pure jnp, shared verbatim by the
compiled program (``repro.el.events.program``) and the host reference
event queue (``repro.el.events.reference``).

Sharing these functions is what makes the two paths bit-comparable: the
reference loop calls them as tiny jitted kernels in the exact order the
``lax.while_loop`` body inlines them, with identical key derivations, so
in fixed-cost mode every selection, realized cost, merge coefficient and
budget charge agrees bit-for-bit.

Everything here is control plane: in a mesh-sharded run
(``make_async_program(mesh=...)``) these functions execute replicated on
every device — selections, realized costs and merge coefficients are
scalars derived from replicated bandit/budget state, so the shared
``jax.random`` chain advances identically on every shard and the sharded
program stays bit-identical to the unsharded one (only the per-edge
datasets and the fetched-params stack shard).

Key schedule (one ``jax.random`` chain per run, seeded like the sync
program with ``jax.random.key(cfg.seed + 17)``):

  * init:       ``rng -> (rng, k_sel, k_cost)``; per-edge keys are
                ``fold_in(k_sel, e)`` / ``fold_in(k_cost, e)``.
  * per event:  ``rng -> (rng, k_sel, k_data, k_cost)``; the event
                edge's keys are ``fold_in(k_*, e)`` (``k_data`` feeds
                the shared minibatch sampler ``make_local_block``).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.bandit import jax_select_arm


def split_init_keys(rng: jax.Array) -> Tuple[jax.Array, ...]:
    """Keys for the initial round of per-edge scheduling."""
    rng, k_sel, k_cost = jax.random.split(rng, 3)
    return rng, k_sel, k_cost


def split_event_keys(rng: jax.Array) -> Tuple[jax.Array, ...]:
    """Keys for one event: selection, minibatch data, cost noise."""
    rng, k_sel, k_data, k_cost = jax.random.split(rng, 4)
    return rng, k_sel, k_data, k_cost


def schedule_block(bstate_e, resid, costs_e, ucb_c, min_cost_e, cost_noise,
                   comp_e, comm_e, wall, k_sel_e, k_cost_e):
    """Select edge ``e``'s next interval and realize its block cost.

    Mirrors the host loop's ``coord.decide(e)`` →
    ``coord.realized_cost(e, i)`` → schedule-if-affordable sequence:
    the arm is the in-graph ol4el draw (``jax_select_arm``), the cost is
    ``interval·comp_e + comm_e`` times the variable-cost multiplier
    ``max(0.1, 1 + noise·N(0,1))`` (a 0.0 noise knob multiplies by
    exactly 1.0), and the block is scheduled only when an arm was
    affordable and the residual still covers the cheapest block
    (``not coord.exhausted(e)``).

    Returns ``(active, interval, cost, finish)`` with ``finish`` =
    ``wall + cost`` for scheduled blocks and ``+inf`` for stopped edges.
    """
    arm = jax_select_arm(k_sel_e, bstate_e, resid, costs_e, ucb_c)
    interval = arm + 1
    eps = jax.random.normal(k_cost_e, ())
    mult = jnp.maximum(0.1, 1.0 + cost_noise * eps)
    # the maximum() pins the charged cost to its f32 rounding (costs are
    # strictly positive, so it never changes the value): without it XLA
    # may contract `wall + expr·mult` into an FMA in one compilation
    # context but not another, and the compiled program and the host
    # reference would disagree by an ulp in variable-cost mode
    cost = jnp.maximum((interval.astype(jnp.float32) * comp_e + comm_e)
                       * mult, 0.0)
    active = (arm >= 0) & (resid >= min_cost_e)
    finish = jnp.where(active, wall + cost, jnp.inf)
    return active, interval, cost, finish


def wave_safe_gap(min_edge_cost, cost_noise):
    """Lower bound (f32) on ANY rescheduled block's realized cost — the
    K-event wave-safety margin.

    ``schedule_block`` charges ``cost = fl(fl(fl(i·comp_e) + comm_e) ·
    mult)`` with ``i >= 1`` and ``mult >= 0.1`` (``== 1.0`` exactly when
    the noise knob is zero).  Round-to-nearest is monotone, so ``cost >=
    fl(min(min_edge_cost) · floor)`` — this gap.  A wave may therefore
    batch every lane ``j`` with ``f_(j) < fl(f_(0) + gap)`` (strict:
    rescheduled finishes ``fl(f_i + cost) >= fl(f_(0) + gap)`` land
    at-or-after the bound, and ties against in-wave lanes must fall to
    the next wave where argmin/top-k tie-breaking orders them), and the
    processed order equals the one-event-at-a-time program's exactly.
    """
    floor = jnp.where(cost_noise > 0, jnp.float32(0.1), jnp.float32(1.0))
    return jnp.min(min_edge_cost) * floor


def staleness_alpha(base, version, fetch_version, n_edges: int):
    """The staleness-discounted mixing rate in float32.

    Same math as the host loop: raw version staleness normalized by the
    fleet size (staleness in *epochs*), then the polynomial discount
    ``base / (1 + s)`` — all in f32 so the compiled and reference paths
    round identically.
    """
    s = (version - fetch_version).astype(jnp.float32) \
        / jnp.float32(max(n_edges, 1))
    return base / (1.0 + s)


def staleness_merge(global_params, edge_params, alpha):
    """Masked asynchronous global update ``G <- (1-a)·G + a·θ_e`` (f32
    accumulation, cast back to the leaf dtype) — the jnp twin of
    ``repro.federated.aggregation.staleness_mix`` with a traced alpha."""
    def mix(g, e):
        out = (1.0 - alpha) * g.astype(jnp.float32) \
            + alpha * e.astype(jnp.float32)
        return out.astype(g.dtype)

    return jax.tree.map(mix, global_params, edge_params)
