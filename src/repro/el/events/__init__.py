"""``repro.el.events`` — the compiled asynchronous EL engine.

Reformulates the paper's async event loop as a single XLA program with
no host priority queue: edge finish times live in an ``[n_edges]``
array, each ``lax.while_loop`` step pops the ``argmin`` finish time (the
event horizon), applies a staleness-weighted masked merge, updates that
edge's bandit and budget, and schedules the edge's next block — until
budget exhaustion or the fixed event horizon.

  * :func:`make_async_program` — ``program(init_params, rng, knobs)``,
    the knob-parameterized compiled run (vmapped by ``repro.el.sweep``);
  * :func:`async_knobs` / :data:`ASYNC_KNOB_NAMES` — the traced
    control-plane inputs (incl. ``async_alpha`` and ``cost_noise``);
  * :func:`default_event_horizon` — a budget/cost-derived horizon bound
    (no silent truncation);
  * :func:`run_async_reference` — the host event-queue twin on the same
    jax RNG streams (``ELSession.run_async(rng_streams="jax")``),
    bit-identical in fixed-cost mode.

Front doors: ``ELSession.run_async_ingraph()`` and async
``ELSession.sweep(spec)`` grids.
"""

from repro.el.events.knobs import (ASYNC_KNOB_NAMES, async_knob_names,
                                   async_knobs, bucket_event_horizon,
                                   default_event_horizon,
                                   padded_event_horizon,
                                   resolve_async_batch_k)
from repro.el.events.program import (make_async_cell, make_async_kernels,
                                     make_async_program)
from repro.el.events.reference import run_async_reference
from repro.el.events.scheduler import (schedule_block, split_event_keys,
                                       split_init_keys, staleness_alpha,
                                       staleness_merge)
from repro.el.events.state import (bandit_fleet_init, bandit_place,
                                   bandit_slice)

__all__ = [
    "ASYNC_KNOB_NAMES", "async_knob_names", "async_knobs",
    "bucket_event_horizon",
    "default_event_horizon", "padded_event_horizon",
    "resolve_async_batch_k", "make_async_cell",
    "make_async_program", "make_async_kernels", "run_async_reference",
    "schedule_block", "split_event_keys", "split_init_keys",
    "staleness_alpha", "staleness_merge",
    "bandit_fleet_init", "bandit_place", "bandit_slice",
]
