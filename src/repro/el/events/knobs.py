"""Control-plane inputs of the compiled async event-horizon program.

Mirrors ``repro.el.ingraph.sync_knobs``: everything a run's *values* can
change — exploration constant, budgets, cost arrays, cost-noise scale,
staleness-mix base rate — enters the compiled program as traced inputs,
so one program serves any knob point and ``repro.el.sweep`` can stack
the arrays along a leading ``[n_cells]`` axis and vmap.

The async program keeps one bandit PER EDGE (the paper's async §IV
formulation), so arm costs are the full per-edge matrix ``costs_ek``
``[E, K]`` rather than the sync path's binding-edge vector ``[K]``.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.config import OL4ELConfig
from repro.core.coordinator import edge_speed_factors
from repro.el.ingraph import base_cost_knobs

#: Traced inputs of ``make_async_program`` (the async analogue of
#: ``repro.el.ingraph.KNOB_NAMES``): scalars ``ucb_c`` / ``budget`` /
#: ``cost_noise`` / ``async_alpha``, the int32 ``event_cap`` (the exact
#: event budget of the run — the STATIC history length is bucketed to a
#: power of two, this traced cap is what terminates the loop, so nearby
#: caps share one executable), per-edge ``comp`` / ``comm`` /
#: ``min_edge_cost`` ``[E]``, and the per-edge arm costs ``costs_ek``
#: ``[E, K]``.
ASYNC_KNOB_NAMES = ("ucb_c", "budget", "comp", "comm", "costs_ek",
                    "min_edge_cost", "cost_noise", "async_alpha",
                    "event_cap")


def async_knobs(cfg: OL4ELConfig) -> Dict[str, np.ndarray]:
    """Host-side control-plane inputs of the compiled async program.

    All float32, shared with the sync path via ``base_cost_knobs`` so
    feasibility/termination arithmetic agrees with the host coordinator
    and the sync program.  The sweep engine calls this once per cell and
    stacks along ``[n_cells]``.
    """
    knobs = base_cost_knobs(cfg)
    intervals_f = np.arange(1, cfg.max_interval + 1, dtype=np.float32)
    # async bandits are per-edge: every edge scores its own arm costs
    knobs["costs_ek"] = (intervals_f[None, :] * knobs["comp"][:, None]
                         + knobs["comm"][:, None])                  # [E, K]
    knobs["async_alpha"] = np.float32(cfg.async_alpha)
    # the exact (un-bucketed) event budget; the loop stops at
    # min(static horizon, event_cap) so a bucketed history never runs
    # past the caller's cap
    knobs["event_cap"] = np.int32(default_event_horizon(cfg))
    if cfg.scenario is not None:
        from repro.el.scenarios.schedule import scenario_knobs
        knobs.update(scenario_knobs(cfg))
    return knobs


def async_knob_names(cfg: OL4ELConfig):
    """The traced-input names of this config's compiled async program:
    ``ASYNC_KNOB_NAMES``, plus the scenario schedule knobs when
    ``cfg.scenario`` is set (exactly the keys ``async_knobs(cfg)``
    returns)."""
    if cfg.scenario is not None:
        from repro.el.scenarios.schedule import scenario_knob_names
        return ASYNC_KNOB_NAMES + scenario_knob_names("async")
    return ASYNC_KNOB_NAMES


def default_event_horizon(cfg: OL4ELConfig) -> int:
    """An event horizon guaranteed to exceed any run's event count.

    Every completed block charges its edge at least ``comp_e + comm_e``
    (times the 0.1 multiplier floor in variable-cost mode), and an
    edge only schedules while its residual covers that minimum — so
    per-edge completions are bounded by ``budget / min_cost`` plus the
    one block in flight at the first infeasibility.  Unlike a fixed
    ``max_events`` cap this scales with budget/cost, so long runs are
    never silently truncated.
    """
    speed = edge_speed_factors(cfg.n_edges, cfg.heterogeneity)
    min_cost = cfg.comp_cost * speed + cfg.comm_cost                # [E]
    floor = 0.1 if (cfg.cost_model == "variable"
                    and cfg.cost_noise > 0) else 1.0
    per_edge = np.floor(cfg.budget / (floor * min_cost)) + 1.0
    return int(per_edge.sum())


def padded_event_horizon(cfg: OL4ELConfig) -> int:
    """:func:`default_event_horizon` rounded up to a power of two
    (floor 64).  The horizon sizes the compiled program's history
    arrays, so it is part of every compile-cache / cohort key — rounding
    keeps nearby budget/cost points on ONE program instead of
    recompiling per knob change.  Shared by ``run_async_ingraph`` and
    the fleet's async cohort bucketing, so a tenant's cohort program has
    exactly the horizon its independent verification run uses."""
    return max(64, 1 << (default_event_horizon(cfg) - 1).bit_length())


def bucket_event_horizon(cap: int) -> int:
    """An explicit event cap's STATIC history length: the next power of
    two (floor 64).  ``run_async_ingraph(max_events=...)`` sizes its
    compiled history arrays at this bucket and passes the exact cap as
    the traced ``event_cap`` knob, so nearby caps share one executable
    instead of recompiling per value."""
    return max(64, 1 << (max(int(cap), 1) - 1).bit_length())


def resolve_async_batch_k(cfg: OL4ELConfig, mesh=None) -> int:
    """The async engine's K-event wave width for this (config, mesh).

    ``cfg.async_batch_k > 0`` pins it (clamped to ``n_edges`` — waves
    pop distinct edges, so wider is meaningless).  ``0`` auto-tunes:
    replicated runs keep the single-event program (``K=1`` — the
    argmin-pop loop is already the fast path on one device), sharded
    runs batch up to 4 events per wave (the per-wave dispatch cost is
    what serializes the sharded control plane; batching amortizes it
    while the safe-gap criterion keeps event order exact).  At the
    bench scale (8 heterogeneous edges) K=4 waves measure ~3.5 events
    per loop step, and K in {2, 4} both beat K=1 on the 2x2 debug mesh;
    4 is kept as the auto width because real multi-host meshes amortize
    per-step latency further, where emulated CPU devices cannot.
    """
    if cfg.async_batch_k > 0:
        return max(1, min(int(cfg.async_batch_k), cfg.n_edges))
    # the scenario path (churn probes / per-event masks) is defined on
    # the single-event program only, so auto-K stays at 1; an explicit
    # K>1 pin with a scenario is rejected by make_async_cell
    if cfg.scenario is not None:
        return 1
    n_dev = 1
    if mesh is not None:
        n_dev = int(np.asarray(mesh.devices).size)
    if n_dev <= 1:
        return 1
    return max(1, min(4, cfg.n_edges))
