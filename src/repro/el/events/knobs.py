"""Control-plane inputs of the compiled async event-horizon program.

Mirrors ``repro.el.ingraph.sync_knobs``: everything a run's *values* can
change — exploration constant, budgets, cost arrays, cost-noise scale,
staleness-mix base rate — enters the compiled program as traced inputs,
so one program serves any knob point and ``repro.el.sweep`` can stack
the arrays along a leading ``[n_cells]`` axis and vmap.

The async program keeps one bandit PER EDGE (the paper's async §IV
formulation), so arm costs are the full per-edge matrix ``costs_ek``
``[E, K]`` rather than the sync path's binding-edge vector ``[K]``.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from repro.config import OL4ELConfig
from repro.core.coordinator import edge_speed_factors
from repro.el.ingraph import base_cost_knobs

#: Traced inputs of ``make_async_program`` (the async analogue of
#: ``repro.el.ingraph.KNOB_NAMES``): scalars ``ucb_c`` / ``budget`` /
#: ``cost_noise`` / ``async_alpha``, per-edge ``comp`` / ``comm`` /
#: ``min_edge_cost`` ``[E]``, and the per-edge arm costs ``costs_ek``
#: ``[E, K]``.
ASYNC_KNOB_NAMES = ("ucb_c", "budget", "comp", "comm", "costs_ek",
                    "min_edge_cost", "cost_noise", "async_alpha")


def async_knobs(cfg: OL4ELConfig) -> Dict[str, np.ndarray]:
    """Host-side control-plane inputs of the compiled async program.

    All float32, shared with the sync path via ``base_cost_knobs`` so
    feasibility/termination arithmetic agrees with the host coordinator
    and the sync program.  The sweep engine calls this once per cell and
    stacks along ``[n_cells]``.
    """
    knobs = base_cost_knobs(cfg)
    intervals_f = np.arange(1, cfg.max_interval + 1, dtype=np.float32)
    # async bandits are per-edge: every edge scores its own arm costs
    knobs["costs_ek"] = (intervals_f[None, :] * knobs["comp"][:, None]
                         + knobs["comm"][:, None])                  # [E, K]
    knobs["async_alpha"] = np.float32(cfg.async_alpha)
    return knobs


def default_event_horizon(cfg: OL4ELConfig) -> int:
    """An event horizon guaranteed to exceed any run's event count.

    Every completed block charges its edge at least ``comp_e + comm_e``
    (times the 0.1 multiplier floor in variable-cost mode), and an
    edge only schedules while its residual covers that minimum — so
    per-edge completions are bounded by ``budget / min_cost`` plus the
    one block in flight at the first infeasibility.  Unlike a fixed
    ``max_events`` cap this scales with budget/cost, so long runs are
    never silently truncated.
    """
    speed = edge_speed_factors(cfg.n_edges, cfg.heterogeneity)
    min_cost = cfg.comp_cost * speed + cfg.comm_cost                # [E]
    floor = 0.1 if (cfg.cost_model == "variable"
                    and cfg.cost_noise > 0) else 1.0
    per_edge = np.floor(cfg.budget / (floor * min_cost)) + 1.0
    return int(per_edge.sum())


def padded_event_horizon(cfg: OL4ELConfig) -> int:
    """:func:`default_event_horizon` rounded up to a power of two
    (floor 64).  The horizon sizes the compiled program's history
    arrays, so it is part of every compile-cache / cohort key — rounding
    keeps nearby budget/cost points on ONE program instead of
    recompiling per knob change.  Shared by ``run_async_ingraph`` and
    the fleet's async cohort bucketing, so a tenant's cohort program has
    exactly the horizon its independent verification run uses."""
    return max(64, 1 << (default_event_horizon(cfg) - 1).bit_length())
