"""``repro.el.fleet`` — multi-tenant EL-as-a-service.

A persistent, host-driven service over the compiled EL programs:

  * :class:`TenantRun` — one tenant's submission (config + executor +
    seed + knob point + priority);
  * :class:`FleetServer` — buckets tenants into cohorts (one compiled
    knob-parameterized slot-batch program per structural config),
    drives each cohort in fixed-width slot waves with mid-flight
    refill (continuous batching) and donated-buffer recycling;
  * :class:`RoundDelta` / :class:`ReportReady` — per-tenant events
    streamed to subscribers as rounds complete;
  * :class:`Cohort` — the per-structure slot/admission state machine.

Correctness bar: every tenant's streamed report is bit-identical to an
independent ``ELSession.run_sync_ingraph`` / ``run_async_ingraph`` of
that tenant alone (see ``tests/test_el_fleet.py``).

CLI front door: ``python -m repro.launch.fleet``.
"""

from repro.el.fleet.cohort import Cohort
from repro.el.fleet.server import DEFAULT_SYNC_HORIZON, FleetServer
from repro.el.fleet.tenant import ReportReady, RoundDelta, TenantRun

__all__ = [
    "FleetServer", "TenantRun", "RoundDelta", "ReportReady", "Cohort",
    "DEFAULT_SYNC_HORIZON",
]
