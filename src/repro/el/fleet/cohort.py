"""One cohort = one structural config = ONE compiled slot-batch program.

A :class:`Cohort` owns the runtime state behind a
:class:`repro.el.sweep.engine.CellBatch`: the stacked device carry, the
per-slot tenant bindings and knob rows, and a priority admission queue.
``wave()`` is the whole service loop body — admit pending tenants into
free slots, run ``rounds_per_wave`` masked iterations, stream each
slot's newly completed aggregations as :class:`RoundDelta` events, and
finalize slots whose runs terminated (freeing them for the next
admission).  The stacked carry is donated every wave, so a cohort
serving thousands of tenants recycles one set of device buffers.
"""

from __future__ import annotations

import heapq
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.el.report import ELReport, RoundRecord, records_from_out, \
    report_from_out
from repro.el.fleet.tenant import ReportReady, RoundDelta, TenantRun
from repro.el.sweep.engine import CellBatch

EmitFn = Callable[[Any], None]


class _Active:
    """A tenant occupying a slot: its submission, resolved knob row,
    streamed-record cursor and admission wall-clock."""

    __slots__ = ("tenant_id", "run", "knobs", "records", "t0")

    def __init__(self, tenant_id: str, run: TenantRun,
                 knobs: Dict[str, np.ndarray]):
        self.tenant_id = tenant_id
        self.run = run
        self.knobs = knobs
        self.records: List[RoundRecord] = []
        self.t0 = time.perf_counter()


class Cohort:
    """Slot-batched continuous service of one structural config."""

    def __init__(self, key: tuple, batch: CellBatch,
                 knobs_fn: Callable, n_samples: Optional[np.ndarray], *,
                 profile: bool = False, cache=None):
        self.key = key
        self.batch = batch
        self.knobs_fn = knobs_fn
        self.n_samples = n_samples
        self.waves = 0
        self.admitted = 0
        self.completed = 0
        # wave-batched data-plane dispatch counters: admits land as ONE
        # place_many scatter per wave and finalize reads as ONE
        # take_many gather per wave, regardless of how many tenants
        # joined/finished (the fleet smoke asserts these stay at one
        # dispatch per wave)
        self.place_dispatches = 0
        self.gather_dispatches = 0
        self._seq = 0
        self._pending: List[Tuple[int, int, str, TenantRun]] = []
        self._slots: List[Optional[_Active]] = [None] * batch.n_slots
        self._stacked = None                     # device carry [n_slots,...]
        self._knobs_np: Optional[Dict[str, np.ndarray]] = None
        # performance-observatory hooks (repro.obs.prof): when armed,
        # the first wave lazily profiles the compiled wave-step program
        # (one extra AOT compile per cohort) and the snapshot joins
        # every tenant report from this cohort
        self.profile_requested = bool(profile)
        self._cache = cache
        self._profile = None

    # -- admission ----------------------------------------------------------

    def submit(self, tenant_id: str, run: TenantRun) -> None:
        """Queue a tenant (higher ``priority`` first, FIFO within)."""
        heapq.heappush(self._pending,
                       (-run.priority, self._seq, tenant_id, run))
        self._seq += 1

    @property
    def has_work(self) -> bool:
        return bool(self._pending) or any(s is not None
                                          for s in self._slots)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def _admit(self) -> int:
        """Fill free slots from the queue (continuous batching: runs
        admitted mid-flight join the next wave; occupied slots are
        untouched — the scatter only writes the freed rows).  The whole
        wave's admissions land in ONE ``place_many`` dispatch: per-slot
        carries are initialized host-side, then scattered together.
        Returns the number of tenants admitted; each admission emits a
        ``cohort.refill`` trace event."""
        from repro.obs import trace as obs_trace
        admitted: List[Tuple[int, Any]] = []     # (slot, carry)
        for s in range(self.batch.n_slots):
            if self._slots[s] is not None or not self._pending:
                continue
            _, _, tenant_id, run = heapq.heappop(self._pending)
            knobs = self.knobs_fn(run.cfg)
            params = (run.init_params if run.init_params is not None
                      else run.executor.init_params(run.cfg.seed))
            carry = self.batch.init_slot(
                params, jax.random.key(run.cfg.seed + 17),
                {k: jnp.asarray(v) for k, v in knobs.items()})
            if self._stacked is None:
                self._stacked = self.batch.broadcast(carry)
                # per-knob dtypes: the async control plane carries the
                # int32 event_cap alongside the float32 scalars
                self._knobs_np = {
                    k: np.zeros((self.batch.n_slots,) + np.shape(v),
                                np.asarray(v).dtype)
                    for k, v in knobs.items()}
            for k, v in knobs.items():
                self._knobs_np[k][s] = v
            self._slots[s] = _Active(tenant_id, run, knobs)
            self.admitted += 1
            admitted.append((s, carry))
            obs_trace.event("cohort.refill", slot=s, tenant=tenant_id,
                            queue_depth=len(self._pending))
        if admitted:
            # fixed-arity scatter: pad to n_slots by repeating the last
            # (carry, slot) pair — duplicate writes are idempotent and
            # the pytree shape never changes, so this stays one
            # compiled program across every refill pattern
            pad = self.batch.n_slots - len(admitted)
            slots = np.asarray([s for s, _ in admitted]
                               + [admitted[-1][0]] * pad, np.int32)
            carries = tuple(c for _, c in admitted) \
                + (admitted[-1][1],) * pad
            self._stacked = self.batch.place_many(
                self._stacked, carries, jnp.asarray(slots))
            self.place_dispatches += 1
        return len(admitted)

    # -- the service loop body ----------------------------------------------

    def wave(self, emit: EmitFn) -> List[Tuple[str, ELReport]]:
        """Admit, step one wave, stream deltas, finalize finished slots.

        Returns the ``(tenant_id, report)`` pairs completed this wave
        (also emitted as :class:`ReportReady` events, after that
        tenant's final :class:`RoundDelta`\\ s).  The whole body runs
        inside an ``obs.span("cohort.wave")`` recording slot occupancy,
        queue depth, refill count and completions.
        """
        from repro.obs import trace as obs_trace
        with obs_trace.span("cohort.wave", mode=self.batch.mode) as sp:
            refilled = self._admit()
            sp["refilled"] = refilled
            sp["queue_depth"] = len(self._pending)
            active = np.array([s is not None for s in self._slots])
            sp["slots_active"] = int(active.sum())
            if not active.any():
                sp["completed"] = 0
                return []
            if self.profile_requested and self._profile is None:
                self._profile_step(active)
            self._stacked, running = self.batch.step(
                self._stacked,
                {k: jnp.asarray(v) for k, v in self._knobs_np.items()},
                jnp.asarray(active))
            running = np.asarray(running)
            self.waves += 1

            # stream the wave's newly completed aggregations from the
            # live history — the same arrays the final report is built
            # from, so accumulated deltas == report.records bit for bit
            t_host = np.asarray(self._stacked["t"])
            hist = jax.tree.map(np.asarray, self._stacked["hist"])
            done: List[Tuple[str, ELReport]] = []
            finished: List[int] = []
            for s, slot in enumerate(self._slots):
                if slot is None:
                    continue
                hi = int(t_host[s])
                if hi > len(slot.records):
                    fresh = records_from_out(
                        {k: v[s] for k, v in hist.items()},
                        len(slot.records), hi)
                    slot.records.extend(fresh)
                    for rec in fresh:
                        emit(RoundDelta(slot.tenant_id, rec))
                if not running[s]:
                    finished.append(s)
            if finished:
                # the wave's finished rows come off the stacked carry in
                # ONE take_many gather (fixed shape: pad the slot list
                # by repeating the last index), then finalize per tenant
                # from the gathered sub-stack
                pad = self.batch.n_slots - len(finished)
                slots = np.asarray(finished + [finished[-1]] * pad,
                                   np.int32)
                rows = self.batch.take_many(self._stacked,
                                            jnp.asarray(slots))
                self.gather_dispatches += 1
                for i, s in enumerate(finished):
                    carry = jax.tree.map(lambda a, i=i: a[i], rows)
                    done.append(self._finalize(s, emit, carry))
            sp["completed"] = len(done)
            return done

    def _profile_step(self, active: np.ndarray) -> None:
        """Extract the cohort's :class:`repro.obs.prof.ProgramProfile`
        from the compiled wave-step program, with the live carry as the
        example arguments (``lower()`` reads shapes only — the donated
        carry is not consumed).  Runs once per cohort; the snapshot is
        also attached to the shared program cache entry."""
        from repro.obs import prof as obs_prof, trace as obs_trace
        with obs_trace.span("cohort.profile", mode=self.batch.mode):
            prof = obs_prof.profile_jit(
                self.batch.step, self._stacked,
                {k: jnp.asarray(v) for k, v in self._knobs_np.items()},
                jnp.asarray(active), donated=True)
        self._profile = prof
        if self._cache is not None:
            self._cache.set_profile(self.key, prof)

    def _finalize(self, s: int, emit: EmitFn,
                  carry: Any = None) -> Tuple[str, ELReport]:
        slot = self._slots[s]
        if carry is None:        # direct callers outside the wave path
            carry = self.batch.take_slot(self._stacked, jnp.int32(s))
        params, out = self.batch.finalize_slot(
            carry, {k: jnp.asarray(v) for k, v in slot.knobs.items()})
        # tree.map (not a dict comprehension): ``out`` carries a nested
        # telemetry subtree when the cohort's rings are on
        out = jax.tree.map(np.asarray, out)
        final = slot.run.executor.evaluate(params)[slot.run.metric_name]
        report = report_from_out(
            out, mode=self.batch.mode, policy=slot.run.cfg.policy,
            horizon=self.batch.horizon, final_metric=final,
            final_params=params,
            elapsed_s=time.perf_counter() - slot.t0,
            records=slot.records)
        if self._profile is not None:
            tele = dict(report.telemetry or {})
            tele["profile"] = self._profile.to_json()
            report.telemetry = tele
        self._slots[s] = None                    # frees the row; the mask
        self.completed += 1                      # keeps it inert until reuse
        emit(ReportReady(slot.tenant_id, report))
        return slot.tenant_id, report

    def release(self) -> None:
        """Drop the device carry (buffer release is then a GC away);
        queued/active tenants are discarded."""
        self._stacked = None
        self._knobs_np = None
        self._slots = [None] * self.batch.n_slots
        self._pending = []
