"""The fleet server: multi-tenant EL-as-a-service over cohort batches.

:class:`FleetServer` accepts :class:`TenantRun` submissions, buckets
them into cohorts keyed on the STRUCTURAL config (mode, data plane,
metric, horizon — everything that shapes the compiled program; knob
values and seeds are traced inputs), and drives every cohort in slot
waves: a fixed ``[n_slots]`` batch stepped ``rounds_per_wave``
iterations at a time with an activity mask, finished slots refilled
from the admission queue mid-flight (continuous batching).  Per-tenant
progress streams to subscribers as :class:`RoundDelta` /
:class:`ReportReady` events as waves complete.

Every tenant's trajectory is bit-identical to an independent
``ELSession.run_sync_ingraph`` / ``run_async_ingraph`` of that
submission alone — the cohort program is the very same
:class:`repro.el.ingraph.ELCell` the single-run programs recompose, and
inactive slots run zero iterations (see ``make_cell_batch``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.el.cache import ProgramCache
from repro.el.executor import validate_executor
from repro.el.fleet.cohort import Cohort
from repro.el.fleet.tenant import ReportReady, RoundDelta, TenantRun
from repro.el.report import ELReport

#: sync cohorts' default compiled history length (``max_rounds``) —
#: the ``run_sync_ingraph`` default, so default submissions verify
#: against default single runs.
DEFAULT_SYNC_HORIZON = 512


class FleetServer:
    """Slot-batched cohort server over the compiled EL programs.

    ``n_slots`` fixes each cohort's batch width (tenants beyond it
    queue and admit as slots free up); ``rounds_per_wave`` is the
    device-side iteration chunk between host harvest points — larger
    waves amortize dispatch, smaller waves tighten streaming latency.
    ``mesh`` shards every cohort's slot dim over the mesh's edge axes
    (``repro.sharding.el_cohort_state_specs``).  ``cache`` lets the
    server share an ``ELSession.compile_cache`` so cohort programs and
    the session's verification runs pool one bounded cache (and one
    hit/miss counter); by default the server owns a private one.

    ``telemetry=`` gates the in-graph observability rings for every
    cohort program (``repro.obs``; off — the default — compiles
    today's programs bit-for-bit).  Each tenant's report then carries
    its own ring snapshot in ``report.telemetry["rings"]``.  The gate
    joins the cohort key, so on/off tenants never share a cohort.

    ``profile=`` arms the performance observatory
    (``repro.obs.prof``): each cohort lazily extracts a
    ``ProgramProfile`` of its compiled wave-step program (XLA
    cost/memory analysis + the HLO collective census) at its first
    wave — one extra AOT compile per cohort — and every tenant report
    from that cohort carries it as ``report.telemetry["profile"]``.
    ``REPRO_EL_PROFILE=1`` arms it process-wide.
    """

    def __init__(self, *, n_slots: int = 4, rounds_per_wave: int = 32,
                 mesh=None, cache: Optional[ProgramCache] = None,
                 max_cached: int = 8, telemetry=None,
                 profile: bool = False):
        import os
        from repro.obs.rings import as_spec
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = int(n_slots)
        self.rounds_per_wave = int(rounds_per_wave)
        self.mesh = mesh
        self.telemetry = as_spec(telemetry)
        self.profile = bool(profile
                            or os.environ.get("REPRO_EL_PROFILE"))
        self._owns_cache = cache is None
        self._cache = ProgramCache(max_cached) if cache is None else cache
        self._cohorts: Dict[tuple, Cohort] = {}
        self._subscribers: List[Callable[[Any], None]] = []
        self._reports: Dict[str, ELReport] = {}
        self._submitted = 0
        self.compiles = 0                # cohort programs actually built
        self._closed = False

    # -- subscription --------------------------------------------------------

    def subscribe(self, callback: Callable[[Any], None]) -> "FleetServer":
        """Register a subscriber; called with every :class:`RoundDelta`
        and :class:`ReportReady` as waves complete."""
        self._subscribers.append(callback)
        return self

    def _emit(self, event: Any) -> None:
        for cb in self._subscribers:
            cb(event)

    # -- admission -----------------------------------------------------------

    def _cohort_key(self, run: TenantRun, horizon: int) -> tuple:
        from repro.el.session import ELSession
        n_samples = (None if run.cfg.mode == "async"
                     or run.n_samples is None
                     else tuple(float(x) for x in run.n_samples))
        return ("fleet", run.executor,
                ELSession._structural_cfg(run.cfg), run.metric_fn,
                run.metric_name, n_samples, horizon, self.n_slots,
                self.rounds_per_wave, self.mesh, self.telemetry)

    def _horizon(self, run: TenantRun) -> int:
        if run.cfg.mode == "async":
            # padded (power-of-two) so nearby budget/cost points bucket
            # into ONE cohort program — the run_async_ingraph default
            from repro.el.events.knobs import padded_event_horizon
            return padded_event_horizon(run.cfg)
        return int(run.max_rounds or DEFAULT_SYNC_HORIZON)

    def submit(self, run: TenantRun) -> str:
        """Admit a tenant: validate, bucket into its cohort (building
        and caching the cohort's slot-batch program on first sight of
        the structure), queue for the next free slot.  Returns the
        tenant id events will carry."""
        if self._closed:
            raise RuntimeError("FleetServer is closed")
        from repro.el.ingraph import check_ingraph_support
        validate_executor(run.executor)
        check_ingraph_support(run.cfg, run.executor,
                              caller="FleetServer.submit")
        tenant_id = run.tenant_id or f"tenant-{self._submitted:04d}"
        if tenant_id in self._reports or any(
                tenant_id == a.tenant_id
                for c in self._cohorts.values()
                for a in c._slots if a is not None) or any(
                tenant_id == p[2]
                for c in self._cohorts.values() for p in c._pending):
            raise ValueError(f"duplicate tenant_id {tenant_id!r}")
        self._submitted += 1
        horizon = self._horizon(run)
        key = self._cohort_key(run, horizon)
        cohort = self._cohorts.get(key)
        if cohort is None:
            cohort = Cohort(key, self._batch_for(run, horizon),
                            self._knobs_fn(run),
                            self._n_samples_of(run),
                            profile=self.profile, cache=self._cache)
            self._cohorts[key] = cohort
        cohort.submit(tenant_id, run)
        return tenant_id

    @staticmethod
    def _knobs_fn(run: TenantRun) -> Callable:
        if run.cfg.mode == "async":
            from repro.el.events.knobs import async_knobs
            return async_knobs
        from repro.el.ingraph import sync_knobs
        return sync_knobs

    @staticmethod
    def _n_samples_of(run: TenantRun) -> Optional[np.ndarray]:
        # async single runs ignore n_samples (run_async_ingraph takes
        # none) — mirror that so fleet == independent run, bit for bit
        if run.cfg.mode == "async" or run.n_samples is None:
            return None
        return np.asarray(run.n_samples, np.float64)

    def _batch_for(self, run: TenantRun, horizon: int):
        """The cohort's compiled slot-batch engine, via the shared
        program cache — one build (and one XLA compile) per structure."""
        from repro.el.sweep.engine import make_cell_batch
        from repro.obs import trace as obs_trace
        key = self._cohort_key(run, horizon)
        batch = self._cache.get(key)
        if batch is None:
            ex = run.executor
            with obs_trace.span("fleet.compile", mode=run.cfg.mode,
                                n_slots=self.n_slots,
                                telemetry=self.telemetry is not None):
                batch = make_cell_batch(
                    ex.model, ex.edge_data, ex.eval_set, run.cfg,
                    n_slots=self.n_slots,
                    rounds_per_wave=self.rounds_per_wave,
                    lr=ex.lr, batch=ex.batch,
                    n_samples=self._n_samples_of(run),
                    metric_fn=run.metric_fn, metric_name=run.metric_name,
                    horizon=horizon, mesh=self.mesh,
                    telemetry=self.telemetry)
                self._cache.put(key, batch)
                self.compiles += 1
        return batch

    # -- the service loop ----------------------------------------------------

    def step(self) -> Dict[str, ELReport]:
        """One wave across every cohort with work.  Streams events and
        returns the reports completed by this step (also retrievable
        later via :meth:`report`)."""
        if self._closed:
            raise RuntimeError("FleetServer is closed")
        done: Dict[str, ELReport] = {}
        for cohort in self._cohorts.values():
            if cohort.has_work:
                for tenant_id, report in cohort.wave(self._emit):
                    done[tenant_id] = report
        self._reports.update(done)
        return done

    def drain(self) -> Dict[str, ELReport]:
        """Step until every admitted tenant has completed; returns ALL
        reports the server has delivered (tenant_id → report)."""
        while any(c.has_work for c in self._cohorts.values()):
            self.step()
        return dict(self._reports)

    def report(self, tenant_id: str) -> Optional[ELReport]:
        return self._reports.get(tenant_id)

    # -- introspection / lifecycle -------------------------------------------

    def stats(self) -> Dict[str, Any]:
        return {
            "tenants_submitted": self._submitted,
            "tenants_done": len(self._reports),
            "tenants_pending": sum(c.n_pending
                                   for c in self._cohorts.values()),
            "tenants_active": sum(c.n_active
                                  for c in self._cohorts.values()),
            "cohorts": len(self._cohorts),
            "compiles": self.compiles,
            "cache_hits": self._cache.hits,
            "cache_misses": self._cache.misses,
            "cache_evictions": self._cache.evictions,
            "waves": sum(c.waves for c in self._cohorts.values()),
            # wave-batched data-plane dispatches: one place_many scatter
            # per admitting wave, one take_many gather per finalizing
            # wave — never per tenant
            "place_dispatches": sum(c.place_dispatches
                                    for c in self._cohorts.values()),
            "gather_dispatches": sum(c.gather_dispatches
                                     for c in self._cohorts.values()),
        }

    def close(self) -> None:
        """Release every cohort's device carry and (when the server owns
        its cache) the compiled programs — after this the server refuses
        submissions.  Delivered reports stay readable.  Idempotent."""
        for cohort in self._cohorts.values():
            cohort.release()
        self._cohorts = {}
        if self._owns_cache:
            self._cache.clear()
        self._closed = True
