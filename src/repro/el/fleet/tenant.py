"""Tenant-facing records of the fleet server: what a caller submits
(:class:`TenantRun`) and the two event types streamed back to
subscribers (:class:`RoundDelta` per completed aggregation,
:class:`ReportReady` when the tenant's run finishes)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

from repro.config import OL4ELConfig
from repro.el.report import ELReport, RoundRecord


@dataclasses.dataclass(frozen=True)
class TenantRun:
    """One tenant's EL run, as submitted to :class:`FleetServer.submit`.

    ``cfg`` carries both the structure (mode, n_edges, utility — the
    cohort key) and the knob point (budget, ucb_c, seed, ... — traced
    inputs of the cohort's one compiled program).  ``executor`` is the
    tenant's in-graph data plane (e.g. ``ClassicExecutor``); tenants
    sharing an executor + structural config share a cohort and its
    compiled slot-batch program.

    ``init_params=None`` resolves to ``executor.init_params(cfg.seed)``
    at admission — the same default an ``ELSession`` uses, which is what
    keeps a fleet tenant bit-identical to an independent
    ``run_sync_ingraph`` / ``run_async_ingraph`` of the same submission.
    ``n_samples`` (per-edge aggregation weights) applies to sync runs
    only, mirroring the session fast paths.  Higher ``priority`` admits
    first; ties admit in submission order.
    """

    cfg: OL4ELConfig
    executor: Any
    tenant_id: Optional[str] = None
    priority: int = 0
    metric_fn: Optional[Callable] = None
    metric_name: str = "accuracy"
    n_samples: Optional[Sequence[float]] = None
    init_params: Any = None
    #: sync history length (compiled ``max_rounds``); ``None`` → 512.
    #: Async cohorts size their history from the padded event horizon.
    max_rounds: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class RoundDelta:
    """Streamed to subscribers after each wave, once per aggregation the
    tenant completed in that wave — read straight from the live device
    history, so the deltas a subscriber accumulates are the finished
    report's ``records`` (same arrays, read incrementally)."""

    tenant_id: str
    record: RoundRecord


@dataclasses.dataclass(frozen=True)
class ReportReady:
    """Streamed when a tenant's run terminates and its slot is freed."""

    tenant_id: str
    report: ELReport
