"""Run artifacts of the EL runtime: per-round records + the final report."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class RoundRecord:
    """One global aggregation (sync round or async merge event)."""

    wall_time: float
    total_consumed: float
    metric: float
    utility: float
    interval: float            # mean interval this event/round
    edge: int                  # -1 for sync rounds
    n_aggregations: int


@dataclasses.dataclass
class ELReport:
    """What an ``ELSession`` run returns.

    Field-compatible with the legacy ``SimResult`` (which is now an alias)
    plus provenance (policy/mode), the bandit's arm-pull histogram and the
    host wall-clock the run took.
    """

    records: List[RoundRecord]
    final_metric: float
    n_aggregations: int
    total_consumed: float
    wall_time: float
    terminated_reason: str
    policy: str = ""
    mode: str = ""
    arm_pulls: Optional[List[int]] = None
    elapsed_s: float = 0.0
    final_params: Any = None           # the trained global model

    def metric_at_consumption(self, budget_frac: float,
                              total_budget: float) -> float:
        """Metric achieved by the time a consumption level is reached."""
        target = budget_frac * total_budget
        best = 0.0
        for r in self.records:
            if r.total_consumed <= target:
                best = r.metric
        return best

    def to_dict(self) -> Dict:
        return {
            "policy": self.policy,
            "mode": self.mode,
            "final_metric": self.final_metric,
            "n_aggregations": self.n_aggregations,
            "total_consumed": self.total_consumed,
            "wall_time": self.wall_time,
            "terminated_reason": self.terminated_reason,
            "arm_pulls": self.arm_pulls,
            "elapsed_s": self.elapsed_s,
        }

    def summary(self) -> str:
        return (f"{self.policy or '?'}-{self.mode or '?'}: "
                f"metric={self.final_metric:.4f} "
                f"aggs={self.n_aggregations} "
                f"consumed={self.total_consumed:.0f} "
                f"({self.terminated_reason})")
