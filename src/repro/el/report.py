"""Run artifacts of the EL runtime: per-round records + the final report,
plus the builders that turn a compiled program's ``out`` dict into them
(shared by ``ELSession.run_*_ingraph`` and the fleet server, so a
tenant's streamed report is built by the same arithmetic as a
single-run one)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass
class RoundRecord:
    """One global aggregation (sync round or async merge event)."""

    wall_time: float
    total_consumed: float
    metric: float
    utility: float
    interval: float            # mean interval this event/round
    edge: int                  # -1 for sync rounds
    n_aggregations: int


@dataclasses.dataclass
class ELReport:
    """What an ``ELSession`` run returns.

    Field-compatible with the legacy ``SimResult`` (which is now an alias)
    plus provenance (policy/mode), the bandit's arm-pull histogram and the
    host wall-clock the run took.
    """

    records: List[RoundRecord]
    final_metric: float
    n_aggregations: int
    total_consumed: float
    wall_time: float
    terminated_reason: str
    policy: str = ""
    mode: str = ""
    arm_pulls: Optional[List[int]] = None
    elapsed_s: float = 0.0
    final_params: Any = None           # the trained global model
    #: observability payload (``repro.obs``): ``"rings"`` holds the
    #: in-graph telemetry buffers (numpy, when the run recorded them),
    #: ``"cache"`` the driver's ``ProgramCache.stats()`` snapshot, and
    #: ``"profile"`` the compiled program's ``ProgramProfile.to_json()``
    #: (XLA cost/memory analysis + collective census, when profiled).
    telemetry: Optional[Dict[str, Any]] = None

    def metric_at_consumption(self, budget_frac: float,
                              total_budget: float) -> float:
        """Metric achieved by the time a consumption level is reached."""
        target = budget_frac * total_budget
        best = 0.0
        for r in self.records:
            if r.total_consumed <= target:
                best = r.metric
        return best

    def to_dict(self) -> Dict:
        return {
            "policy": self.policy,
            "mode": self.mode,
            "final_metric": self.final_metric,
            "n_aggregations": self.n_aggregations,
            "total_consumed": self.total_consumed,
            "wall_time": self.wall_time,
            "terminated_reason": self.terminated_reason,
            "arm_pulls": self.arm_pulls,
            "elapsed_s": self.elapsed_s,
        }

    def summary(self) -> str:
        return (f"{self.policy or '?'}-{self.mode or '?'}: "
                f"metric={self.final_metric:.4f} "
                f"aggs={self.n_aggregations} "
                f"consumed={self.total_consumed:.0f} "
                f"({self.terminated_reason})")


def records_from_out(out: Dict[str, Any], lo: int, hi: int
                     ) -> List[RoundRecord]:
    """``RoundRecord``s for rounds/events ``[lo, hi)`` of a compiled
    program's history arrays (``out`` may be the final ``out`` dict or a
    live ``carry["hist"]`` — same arrays either way, which is what makes
    the fleet's streamed deltas equal the finished report's records).
    Sync histories carry no ``edge`` array; those records get ``-1``."""
    edge = out.get("edge")
    return [
        RoundRecord(float(out["wall"][t]), float(out["consumed"][t]),
                    float(out["metric"][t]), float(out["utility"][t]),
                    float(out["interval"][t]),
                    int(edge[t]) if edge is not None else -1, t + 1)
        for t in range(lo, hi)
    ]


def report_from_out(out: Dict[str, Any], *, mode: str, policy: str,
                    horizon: int, final_metric: float, final_params: Any,
                    elapsed_s: float,
                    records: Optional[List[RoundRecord]] = None
                    ) -> "ELReport":
    """Assemble an :class:`ELReport` from a compiled program's ``out``.

    One builder for both modes and all drivers (``run_sync_ingraph``,
    ``run_async_ingraph``, the fleet cohorts): the termination reason
    comes from ``n_active`` when present (the async in-flight count),
    else from the round count against ``horizon``; async ``[E, K]`` arm
    pulls are summed to the sync ``[K]`` histogram shape.
    """
    import numpy as np
    n = int(out["n_rounds"])
    if records is None:
        records = records_from_out(out, 0, n)
    pulls = np.asarray(out["arm_pulls"])
    if pulls.ndim == 2:                                # async [E,K] -> [K]
        pulls = pulls.sum(axis=0)
    if "n_active" in out:
        reason = ("budget_exhausted" if int(out["n_active"]) == 0
                  else "max_events")
    else:
        reason = "max_rounds" if n >= horizon else "budget_exhausted"
    telemetry = None
    if "telemetry" in out:                 # the in-graph rings, to host
        import jax
        telemetry = {"rings": jax.tree.map(np.asarray,
                                           dict(out["telemetry"]))}
    return ELReport(
        records=records,
        final_metric=float(final_metric),
        n_aggregations=n,
        total_consumed=float(out["consumed"][n - 1]) if n else 0.0,
        wall_time=float(out["wall_time"]),
        terminated_reason=reason,
        policy=policy,
        mode=mode,
        arm_pulls=[int(c) for c in pulls],
        elapsed_s=elapsed_s,
        final_params=final_params,
        telemetry=telemetry,
    )
