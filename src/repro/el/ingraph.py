"""The fully in-graph sync fast path: one XLA program per EL run.

The host-driven runtime round-trips cloud↔device once per round: a numpy
bandit picks the interval, a jitted scan runs the local iterations, numpy
charges the budgets.  This module stages the *entire* budgeted sync loop —

    in-graph bandit select  (``jax_selection_weights`` + categorical)
      → ``lax.scan`` local iterations, vmapped over edges
      → weighted parameter aggregation
      → in-graph utility (eval-gain or param-delta)
      → ``jax_bandit_update`` + budget charge

— into a single ``lax.while_loop``, so an entire run (hundreds of rounds)
is ONE compiled program with zero host synchronization.  This is what the
previously-dormant ``jax_bandit_*`` functions exist for.

The control-plane knobs (exploration constant, per-edge budget, cost
arrays) are *inputs* of the compiled program, not trace-time constants —
``make_sync_program`` returns ``program(init_params, rng, knobs)`` and
``sync_knobs(cfg)`` derives the knob arrays on the host.  That is what
lets ``repro.el.sweep`` vmap the very same program over a flattened
``[n_cells]`` ablation grid (ucb_c × budget × heterogeneity × seed) and
run a whole sweep as one XLA program.

Supported configuration matrix (see ``check_ingraph_support``) — shared
with the async event-horizon program in ``repro.el.events``:

  ==============  =======================================================
  dimension        supported in-graph
  ==============  =======================================================
  mode             ``sync`` (this module) and ``async`` (the
                   ``repro.el.events`` event-horizon program)
  policy           ``ol4el`` (the compiled 3-step KUBE bandit; one
                   shared bandit in sync, one bandit per edge in async —
                   the policy registry records this as
                   ``Policy.ingraph_modes``); with a ``ScenarioSpec``
                   the sync program routes selection through a traced
                   policy switch that adds the task-allocation
                   baselines (``repro.el.scenarios.baselines``)
  cost_model       ``fixed`` and ``variable`` (the noise scale is the
                   traced ``cost_noise`` knob: i.i.d. multipliers drawn
                   via ``jax.random``, clipped at the host path's 0.1
                   floor; ``cost_noise=0`` multiplies by exactly 1.0, so
                   the fixed program is the noise-0 program bit-for-bit);
                   heavy-tailed / trace-replayed models are
                   ``ScenarioSpec`` cost kinds, layered on top
  scenario         ``None`` — today's programs bit-for-bit — or a
                   ``repro.el.scenarios.ScenarioSpec`` (churn activity
                   masks, straggler cost schedules, data drift as traced
                   knobs; async requires K=1 event waves)
  utility          ``eval_gain`` (needs a jittable metric) and
                   ``param_delta``
  executor         ``InGraphExecutor`` shape — raw per-edge arrays + a
                   jittable ``model.local_step`` (``ClassicExecutor``)
  ==============  =======================================================

Everything else stays on the host paths (``ELSession.run_sync`` /
``run_async``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config import OL4ELConfig
from repro.core.bandit import (jax_bandit_init, jax_bandit_update,
                               jax_selection_weights)
from repro.core.coordinator import edge_speed_factors

Params = Any

#: Names (and shapes) of the per-run control-plane inputs of the compiled
#: program: scalars ``ucb_c`` / ``budget`` / ``cost_noise``, per-edge
#: ``comp`` / ``comm`` / ``min_edge_cost`` ``[E]``, and the binding-edge
#: arm costs ``costs_k`` ``[K]``.  The sweep engine stacks each along a
#: leading ``[n_cells]`` axis and vmaps.
KNOB_NAMES = ("ucb_c", "budget", "comp", "comm", "costs_k", "min_edge_cost",
              "cost_noise")

_INGRAPH_UTILITIES = ("eval_gain", "param_delta")
_INGRAPH_COST_MODELS = ("fixed", "variable")

#: Attributes an executor must expose to be in-graph capable
#: (the ``InGraphExecutor`` Protocol, satisfied by ``ClassicExecutor``).
INGRAPH_EXECUTOR_ATTRS = ("model", "edge_data", "eval_set", "batch", "lr")


def _combo(cfg: OL4ELConfig, executor: Any) -> str:
    ex_name = type(executor).__name__ if executor is not None else "<unset>"
    scn = "None" if cfg.scenario is None else type(cfg.scenario).__name__
    return (f"(policy={cfg.policy!r}, cost_model={cfg.cost_model!r}, "
            f"scenario={scn}, executor={ex_name})")


def support_matrix() -> str:
    """The scenario/cost-model support matrix, rendered for error
    messages — so an unsupported combination is rejected at the front
    door with the full menu, instead of failing late inside tracing."""
    from repro.el.scenarios.baselines import INGRAPH_POLICY_ORDER
    return (
        "supported in-graph matrix:\n"
        "  mode        'sync' (repro.el.ingraph) | 'async' "
        "(repro.el.events)\n"
        "  policy      scenario=None: 'ol4el' only; with a ScenarioSpec "
        f"the sync policy switch adds {INGRAPH_POLICY_ORDER[1:]} (other "
        "registry policies run host-side only; async is always the "
        "per-edge 'ol4el' bandit)\n"
        f"  cost_model  cfg.cost_model in {_INGRAPH_COST_MODELS}; "
        "heavy-tailed / replayed models ('pareto' | 'lognormal' | "
        "'trace:<path>') are ScenarioSpec COST KINDS — set "
        "cfg.scenario=ScenarioSpec(cost=CostSpec(kind=...)) (the "
        "--cost-model launch flag builds this for you)\n"
        "  scenario    None (today's programs bit-for-bit) | ScenarioSpec "
        "(churn/straggler/drift schedules; async requires K=1 event "
        "waves)\n"
        f"  utility     {_INGRAPH_UTILITIES}\n"
        "  executor    InGraphExecutor shape (raw per-edge arrays + a "
        "jittable model.local_step, e.g. ClassicExecutor)")


def check_ingraph_support(cfg: OL4ELConfig, executor: Any = None, *,
                          caller: str = "the in-graph fast path"
                          ) -> None:
    """Validate a config/executor combination against the supported matrix.

    Raises ``ValueError`` naming the unsupported (policy, cost_model,
    scenario, executor) combination — every message carries the full
    :func:`support_matrix` so the caller sees the menu, not just the
    rejection — or ``TypeError`` when the executor is not in-graph
    capable.  The per-policy mode support lives in the policy registry
    (``Policy.ingraph_modes``): ``ol4el`` compiles in both modes — one
    shared bandit in sync, per-edge bandits in async — and the
    task-allocation baselines compile through the sync scenario policy
    switch (``repro.el.scenarios.baselines``).
    """
    from repro.el import policies as el_policies
    from repro.el.scenarios.spec import ScenarioSpec
    if cfg.mode not in ("sync", "async"):
        raise ValueError(
            f"{caller} does not support mode={cfg.mode!r}; in-graph modes "
            "are 'sync' (repro.el.ingraph) and 'async' (repro.el.events)\n"
            + support_matrix())
    scn = cfg.scenario
    if scn is not None and not isinstance(scn, ScenarioSpec):
        raise TypeError(
            f"{caller}: cfg.scenario must be a "
            "repro.el.scenarios.ScenarioSpec (or None), got "
            f"{type(scn).__name__}\n" + support_matrix())
    if cfg.mode not in el_policies.ingraph_modes(cfg.policy):
        raise ValueError(
            f"{caller} does not support {_combo(cfg, executor)} in "
            f"mode={cfg.mode!r}: the compiled programs implement the "
            "'ol4el' selection rule (shared bandit in sync, one bandit "
            "per edge in async) plus the sync scenario policy switch; "
            "run other policies through the host paths "
            "ELSession.run_sync()/run_async()\n" + support_matrix())
    if cfg.policy != "ol4el":
        if scn is None:
            raise ValueError(
                f"{caller} does not support {_combo(cfg, executor)}: "
                f"policy {cfg.policy!r} compiles only through the "
                "scenario policy switch — set cfg.scenario "
                "(ScenarioSpec() is the identity scenario)\n"
                + support_matrix())
        if cfg.mode != "sync":
            raise ValueError(
                f"{caller} does not support {_combo(cfg, executor)} in "
                f"mode={cfg.mode!r}: the policy switch is sync-only (the "
                "async program keeps the paper's per-edge 'ol4el' "
                "bandit)\n" + support_matrix())
    if cfg.cost_model not in _INGRAPH_COST_MODELS:
        hint = ""
        if cfg.cost_model in ("pareto", "lognormal") or str(
                cfg.cost_model).startswith("trace"):
            hint = (f" — {cfg.cost_model!r} is a ScenarioSpec cost KIND, "
                    "not a cfg.cost_model: set cfg.scenario="
                    "ScenarioSpec(cost=CostSpec(kind=...))")
        raise ValueError(
            f"{caller} does not support {_combo(cfg, executor)}: "
            f"cost_model must be one of {_INGRAPH_COST_MODELS}{hint}\n"
            + support_matrix())
    if cfg.utility not in _INGRAPH_UTILITIES:
        raise ValueError(
            f"{caller} does not support utility={cfg.utility!r} with "
            f"{_combo(cfg, executor)}: in-graph utilities are "
            f"{_INGRAPH_UTILITIES}\n" + support_matrix())
    if executor is not None:
        missing = [a for a in INGRAPH_EXECUTOR_ATTRS
                   if not hasattr(executor, a)]
        if missing:
            raise TypeError(
                f"{type(executor).__name__} is not in-graph capable "
                f"(missing .{missing[0]}); {caller} with "
                f"{_combo(cfg, executor)} needs an InGraphExecutor such "
                "as ClassicExecutor (raw per-edge arrays + a jittable "
                "model.local_step)")


def base_cost_knobs(cfg: OL4ELConfig) -> Dict[str, np.ndarray]:
    """The mode-independent control-plane knobs both compiled programs
    share: scalars ``ucb_c`` / ``budget`` / ``cost_noise`` and the
    per-edge cost arrays.  One derivation keeps the sync round and the
    async event-horizon program (``repro.el.events``) in lockstep with
    the host coordinator's feasibility/termination arithmetic."""
    speed = edge_speed_factors(cfg.n_edges, cfg.heterogeneity)
    comp = np.asarray(cfg.comp_cost * speed, np.float32)            # [E]
    comm = np.full((cfg.n_edges,), cfg.comm_cost, np.float32)       # [E]
    return {
        "ucb_c": np.float32(cfg.ucb_c),
        "budget": np.float32(cfg.budget),
        "comp": comp,
        "comm": comm,
        "min_edge_cost": comp + comm,                               # [E]
        # noise applies only in variable-cost mode (host realized_cost
        # semantics); the programs always trace the noise path — a 0.0
        # knob multiplies costs by exactly 1.0, bit-for-bit fixed.
        "cost_noise": np.float32(cfg.cost_noise
                                 if cfg.cost_model == "variable" else 0.0),
    }


def sync_knobs(cfg: OL4ELConfig) -> Dict[str, np.ndarray]:
    """Host-side control-plane inputs of the compiled sync program.

    All float32, computed with the exact numpy arithmetic the scalar fast
    path used to bake in as constants, so passing them as traced inputs
    reproduces the same program bit-for-bit.  The sweep engine calls this
    once per cell and stacks along a leading ``[n_cells]`` axis.
    """
    knobs = base_cost_knobs(cfg)
    intervals_f = np.arange(1, cfg.max_interval + 1, dtype=np.float32)
    # sync feasibility is scored against the binding (slowest) edge
    worst = int(np.argmax(knobs["comp"]))
    knobs["costs_k"] = (intervals_f * knobs["comp"][worst]
                        + knobs["comm"][worst])                     # [K]
    if cfg.scenario is not None:
        from repro.el.scenarios.schedule import scenario_knobs
        knobs.update(scenario_knobs(cfg))
    return knobs


def sync_knob_names(cfg: OL4ELConfig) -> Tuple[str, ...]:
    """The traced-input names of this config's compiled sync program:
    ``KNOB_NAMES``, plus the scenario schedule knobs and the policy
    selector when ``cfg.scenario`` is set (exactly the keys
    ``sync_knobs(cfg)`` returns)."""
    if cfg.scenario is not None:
        from repro.el.scenarios.schedule import scenario_knob_names
        return KNOB_NAMES + scenario_knob_names("sync")
    return KNOB_NAMES


def _pad_edge_data(edge_data: List[Dict[str, np.ndarray]]
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stack per-edge datasets [E, Nmax, d] / [E, Nmax] with wraparound
    padding (padding rows repeat real rows, so uniform index sampling over
    [0, n_e) never sees them)."""
    n = np.array([len(d["y"]) for d in edge_data], np.int32)
    n_max = int(n.max())
    dim = edge_data[0]["x"].shape[-1]
    xs = np.zeros((len(edge_data), n_max, dim), np.float32)
    ys = np.zeros((len(edge_data), n_max), np.int32)
    for e, d in enumerate(edge_data):
        reps = -(-n_max // len(d["y"]))
        xs[e] = np.tile(np.asarray(d["x"], np.float32), (reps, 1))[:n_max]
        ys[e] = np.tile(np.asarray(d["y"], np.int32), reps)[:n_max]
    return jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(n)


def default_metric_fn(model, eval_set, metric_name: str
                      ) -> Optional[Callable[[Params], jax.Array]]:
    """A jittable eval metric when the model supports one (SVM accuracy);
    None means the in-graph path must run with a params-only utility."""
    if metric_name == "accuracy" and hasattr(model, "scores"):
        xe = jnp.asarray(eval_set["x"], jnp.float32)
        ye = jnp.asarray(eval_set["y"], jnp.int32)

        def accuracy(params):
            pred = jnp.argmax(model.scores(params, xe), -1)
            return jnp.mean((pred == ye).astype(jnp.float32))

        return accuracy
    return None


def _tree_l2(a: Params, b: Params) -> jax.Array:
    total = sum(jnp.sum((x.astype(jnp.float32) - y.astype(jnp.float32)) ** 2)
                for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    return jnp.sqrt(total)


def make_local_block(model, xs: jax.Array, ys: jax.Array,
                     n_per_edge: jax.Array, batch: int, lr: float,
                     k: int, *, drift: bool = False) -> Callable:
    """``local_block(params, edge, interval, key)`` — ``interval`` masked
    local iterations on one edge's shard (a fixed-length ``lax.scan`` of
    ``k`` steps, steps past ``interval`` masked out).  Shared by the sync
    round body, the async event body (``repro.el.events``) and its host
    reference loop, so all three sample identical minibatch streams from
    identical keys.

    ``drift=True`` (the scenario path) adds a trailing ``shift`` argument
    — the traced drift phase ``scn_drift * t`` — and rotates every
    sampled index by ``floor(shift * n_e) mod n_e``, so the effective
    local distribution walks over the edge's shard round by round
    (non-stationary data drift).  ``shift=0`` rotates by zero, and with
    ``drift=False`` the rotation is statically absent — the classic
    block, unchanged.
    """

    def local_block(params: Params, edge: jax.Array, interval: jax.Array,
                    key: jax.Array, shift: jax.Array = None) -> Params:
        def body(p, step):
            u = jax.random.uniform(jax.random.fold_in(key, step), (batch,))
            idx = (u * n_per_edge[edge].astype(jnp.float32)).astype(jnp.int32)
            if drift:
                off = (shift * n_per_edge[edge].astype(jnp.float32)
                       ).astype(jnp.int32)
                idx = jnp.mod(idx + off, n_per_edge[edge])
            b = {"x": xs[edge][idx], "y": ys[edge][idx]}
            p2, _ = model.local_step(p, b, lr)
            take = step < interval
            return jax.tree.map(
                lambda a, c: jnp.where(take, c, a), p, p2), None

        params, _ = lax.scan(body, params, jnp.arange(k))
        return params

    return local_block


def _shard_edge_data(mesh, n_edges: int, *arrays: jax.Array):
    """Place the ``[E, ...]`` padded datasets on the mesh with their edge
    dim over (``pod``, ``data``) — replicated when the fleet does not
    tile the edge axes (the ``el_run_partition_specs`` policy)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding import el_run_partition_specs
    edge_spec, _ = el_run_partition_specs(
        mesh.axis_names, dict(zip(mesh.axis_names,
                                  np.shape(mesh.devices))), n_edges, ())
    return tuple(
        jax.device_put(a, NamedSharding(
            mesh, P(*edge_spec, *([None] * (a.ndim - 1)))))
        for a in arrays)


def _edge_stack_constraints(mesh, n_edges: int
                            ) -> Tuple[Callable, Callable]:
    """Two trace-time pytree constraints for the ``[E, ...]`` per-edge
    parameter stack: ``constrain`` pins it to the sharded
    ``el_stacked_param_specs`` layout (edge dim over pod/data, tensor
    dims by the per-arch resolver), ``gather`` pins it replicated — the
    explicit all-gather in front of every cross-edge reduction that
    keeps sharded runs bit-identical to unsharded ones.  Both are
    identity when ``mesh`` is None.
    """
    if mesh is None:
        ident = lambda tree: tree                              # noqa: E731
        return ident, ident

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding import el_stacked_param_specs, to_shardings

    def constrain(tree):
        specs = el_stacked_param_specs(mesh, n_edges, tree)
        return lax.with_sharding_constraint(tree,
                                            to_shardings(mesh, specs))

    def gather(tree):
        return lax.with_sharding_constraint(
            tree, jax.tree.map(lambda _: NamedSharding(mesh, P()), tree))

    return constrain, gather


@dataclasses.dataclass(frozen=True)
class ELCell:
    """One EL run's compiled loop, split into composable pieces.

    The four closures share the program's dict carry (``carry["t"]`` is
    the round/event counter, ``carry["hist"]`` the ``[horizon]`` history
    arrays) and all take the traced knob dict explicitly, so callers can
    compose them into different drivers:

      * ``make_sync_program`` / ``make_async_program`` fuse
        ``init → while(cond, body) → finalize`` into ONE program per run
        (the single-run and sweep fast paths);
      * the fleet server (``repro.el.fleet``) instead vmaps a bounded
        chunk of ``body`` over tenant *slots* and carries the stacked
        state across calls — continuous batching over the same cell,
        bit-identical because ``body`` is the same traced function.
    """

    init: Callable       # (init_params, rng, knobs) -> carry
    cond: Callable       # (carry, knobs) -> bool scalar (continue?)
    body: Callable       # (carry, knobs) -> carry (one round/event)
    finalize: Callable   # (carry, knobs) -> (params, out dict)
    horizon: int         # history length (max_rounds / max_events)


def make_sync_cell(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                   lr: float, batch: int,
                   n_samples: Optional[np.ndarray] = None,
                   metric_fn: Optional[Callable] = None,
                   metric_name: str = "accuracy",
                   max_rounds: int = 512, mesh=None,
                   telemetry=None) -> ELCell:
    """The budgeted sync round as an :class:`ELCell` — the unfused form
    of ``make_sync_program`` (which recomposes exactly these closures
    into one ``lax.while_loop``); see that function for the semantics,
    knob contract and mesh placement.

    ``telemetry=`` is the static in-graph observability gate
    (``repro.obs.rings.as_spec`` coercions: None/False off, True/int/
    ``TelemetrySpec`` on).  Off builds exactly the carry below — no
    extra key, no extra op, the same traced program bit-for-bit.  On
    adds a ``carry["telem"]`` ring subtree, each round recording arm,
    straggler cost, residual budget and the bandit's per-arm statistics
    at ``t % ring_size`` (under ``jax.named_scope("obs.telemetry")``),
    surfaced by ``finalize`` as ``out["telemetry"]``.
    """
    from repro.obs.rings import (as_spec, finalize_telemetry,
                                 sync_ring_init, sync_ring_record)
    spec = as_spec(telemetry)
    check_ingraph_support(cfg, caller="make_sync_program")
    # fleet-dynamics scenario: None keeps every closure below EXACTLY
    # today's traced code (the scenario branch is statically absent);
    # a ScenarioSpec swaps in the mask-aware cond/body variants.
    scn = cfg.scenario
    period = scn.period if scn is not None else 0

    n_edges, k = cfg.n_edges, cfg.max_interval

    xs, ys, n_per_edge = _pad_edge_data(edge_data)
    constrain_edge_stack, gather_edge_stack = _edge_stack_constraints(
        mesh, n_edges)
    if mesh is not None:
        xs, ys = _shard_edge_data(mesh, n_edges, xs, ys)
    w_agg = (np.ones(n_edges) if n_samples is None
             else np.asarray(n_samples, np.float64))
    w_agg = jnp.asarray(w_agg / w_agg.sum(), jnp.float32)

    if metric_fn is None:
        metric_fn = default_metric_fn(model, eval_set, metric_name)
    if cfg.utility == "eval_gain" and metric_fn is None:
        raise ValueError(
            "utility='eval_gain' needs a jittable metric; pass metric_fn= "
            "or use utility='param_delta'")

    local_block = make_local_block(model, xs, ys, n_per_edge, batch, lr, k,
                                   drift=scn is not None)

    def weighted_mean(trees: Params) -> Params:
        return jax.tree.map(
            lambda leaf: jnp.einsum(
                "e...,e->...", leaf.astype(jnp.float32), w_agg
            ).astype(leaf.dtype), trees)

    def init(init_params: Params, rng: jax.Array,
             knobs: Dict[str, jax.Array]) -> Dict[str, Any]:
        bstate = jax_bandit_init(k)
        consumed = jnp.zeros((n_edges,), jnp.float32)
        if metric_fn is not None:
            prev_metric = metric_fn(init_params)
        else:
            prev_metric = jnp.float32(jnp.nan)
        hist = {
            "metric": jnp.full((max_rounds,), jnp.nan, jnp.float32),
            "utility": jnp.zeros((max_rounds,), jnp.float32),
            "interval": jnp.zeros((max_rounds,), jnp.int32),
            "consumed": jnp.zeros((max_rounds,), jnp.float32),
            "wall": jnp.zeros((max_rounds,), jnp.float32),
        }
        if scn is not None:
            hist["active_edges"] = jnp.zeros((max_rounds,), jnp.int32)
        carry = {"params": init_params, "bstate": bstate,
                 "consumed": consumed, "t": jnp.int32(0), "rng": rng,
                 "prev_metric": prev_metric, "wall": jnp.float32(0.0),
                 "hist": hist}
        if spec is not None:
            carry["telem"] = sync_ring_init(spec, k,
                                            scenario=scn is not None)
        return carry

    def cond(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        resid = knobs["budget"] - carry["consumed"]                  # [E]
        affordable = (jnp.min(resid)
                      >= jnp.min(knobs["costs_k"]) - 1e-12)
        exhausted = jnp.any(resid < knobs["min_edge_cost"])
        return (carry["t"] < max_rounds) & affordable & ~exhausted

    def body(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        ucb_c = knobs["ucb_c"]
        budget = knobs["budget"]
        comp, comm = knobs["comp"], knobs["comm"]
        costs_k = knobs["costs_k"]
        cost_noise = knobs["cost_noise"]
        params, bstate = carry["params"], carry["bstate"]
        consumed, t = carry["consumed"], carry["t"]
        prev_metric, wall = carry["prev_metric"], carry["wall"]
        hist = carry["hist"]

        rng, k_sel, k_data = jax.random.split(carry["rng"], 3)
        resid = jnp.min(budget - consumed)
        w = jax_selection_weights(bstate, resid, costs_k, ucb_c)
        logits = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-30)),
                           -jnp.inf)
        arm = jax.random.categorical(k_sel, logits)
        interval = arm + 1

        edge_ids = jnp.arange(n_edges)
        keys = jax.vmap(lambda e: jax.random.fold_in(k_data, e))(edge_ids)
        bcast = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_edges,) + x.shape), params)
        # data plane: the per-edge param stack (and with it the
        # vmapped local blocks) shards over the mesh's edge axes ...
        bcast = constrain_edge_stack(bcast)
        edge_params = jax.vmap(local_block, in_axes=(0, 0, None, 0))(
            bcast, edge_ids, interval, keys)
        # ... and is all-gathered BEFORE the aggregation so the
        # einsum reduces replicated, in the unsharded program's
        # exact accumulation order (bit-identity; a psum over the
        # sharded edge dim would be an ulp off)
        edge_params = gather_edge_stack(edge_params)
        new_params = weighted_mean(edge_params)

        # straggler semantics: every edge's clock advances by the
        # slowest edge's round time (matches CloudCoordinator.charge
        # in run_sync)
        round_costs = interval.astype(jnp.float32) * comp + comm  # [E]
        # host semantics (CloudCoordinator.realized_cost): each
        # edge's realized cost is the expected cost times an
        # i.i.d. multiplier max(0.1, 1 + noise·N(0,1)).  The key
        # is derived from k_data OUTSIDE the per-edge fold range
        # [0, n_edges), so the fixed-cost RNG streams are
        # untouched.  ``cost_noise`` is a TRACED knob (sweepable):
        # a 0.0 knob multiplies by exactly 1.0, so fixed-cost runs
        # are the noise-0 program bit-for-bit.
        k_cost = jax.random.fold_in(k_data, n_edges)
        eps = jax.random.normal(k_cost, (n_edges,))
        mult = jnp.maximum(0.1, 1.0 + cost_noise * eps)
        round_costs = round_costs * mult
        slot = jnp.max(round_costs)
        consumed = consumed + slot

        if metric_fn is not None:
            metric = metric_fn(new_params)
        else:
            metric = jnp.float32(jnp.nan)
        if cfg.utility == "eval_gain":
            utility = metric - prev_metric
        else:                              # param_delta (§III.A)
            utility = 1.0 / (1.0 + _tree_l2(params, new_params))

        bstate = jax_bandit_update(bstate, arm, utility, slot)
        wall = wall + slot
        hist = {
            "metric": hist["metric"].at[t].set(metric),
            "utility": hist["utility"].at[t].set(utility),
            "interval": hist["interval"].at[t].set(interval),
            "consumed": hist["consumed"].at[t].set(jnp.sum(consumed)),
            "wall": hist["wall"].at[t].set(wall),
        }
        new_carry = {"params": new_params, "bstate": bstate,
                     "consumed": consumed, "t": t + 1, "rng": rng,
                     "prev_metric": metric, "wall": wall, "hist": hist}
        if spec is not None:
            with jax.named_scope("obs.telemetry"):
                new_carry["telem"] = sync_ring_record(
                    carry["telem"], spec, t=t, arm=arm, round_cost=slot,
                    budget_resid=jnp.min(budget - consumed),
                    bstate=bstate)
        return new_carry

    def cond_scn(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        # feasibility paces on the tightest ACTIVE edge this round —
        # dropped edges neither spend nor constrain the fleet
        resid = knobs["budget"] - carry["consumed"]                  # [E]
        act = knobs["scn_active"][jnp.mod(carry["t"], period)] > 0
        affordable = (jnp.min(jnp.where(act, resid, jnp.inf))
                      >= jnp.min(knobs["costs_k"]) - 1e-12)
        exhausted = jnp.any(act & (resid < knobs["min_edge_cost"]))
        return (carry["t"] < max_rounds) & affordable & ~exhausted

    def body_scn(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        from repro.el.scenarios.baselines import select_arm_switch
        ucb_c = knobs["ucb_c"]
        budget = knobs["budget"]
        comp, comm = knobs["comp"], knobs["comm"]
        costs_k = knobs["costs_k"]
        cost_noise = knobs["cost_noise"]
        scn_active, scn_mult = knobs["scn_active"], knobs["scn_mult"]
        params, bstate = carry["params"], carry["bstate"]
        consumed, t = carry["consumed"], carry["t"]
        prev_metric, wall = carry["prev_metric"], carry["wall"]
        hist = carry["hist"]

        slot_i = jnp.mod(t, period)
        act = scn_active[slot_i] > 0                                 # [E]

        rng, k_sel, k_data = jax.random.split(carry["rng"], 3)
        resid = jnp.min(jnp.where(act, budget - consumed, jnp.inf))
        # traced policy switch: OL4EL bandit vs the task-allocation
        # baselines, selected by the policy_id knob (sweepable axis)
        arm = select_arm_switch(knobs["policy_id"], bstate, resid,
                                costs_k, ucb_c, k_sel)
        interval = arm + 1

        edge_ids = jnp.arange(n_edges)
        keys = jax.vmap(lambda e: jax.random.fold_in(k_data, e))(edge_ids)
        bcast = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_edges,) + x.shape), params)
        bcast = constrain_edge_stack(bcast)
        # a dropped edge runs ZERO masked work: interval 0 masks every
        # scan step; the drift shift rotates its sampling window
        edge_iv = jnp.where(act, interval, 0)
        shift = knobs["scn_drift"] * t.astype(jnp.float32)
        edge_params = jax.vmap(local_block, in_axes=(0, 0, 0, 0, None))(
            bcast, edge_ids, edge_iv, keys, shift)
        edge_params = gather_edge_stack(edge_params)
        # mask-aware aggregation: dead edges carry zero weight and the
        # live weights renormalize (the merge chain skips them)
        w_act = w_agg * act.astype(jnp.float32)
        w_act = w_act / jnp.maximum(jnp.sum(w_act), 1e-12)
        new_params = jax.tree.map(
            lambda leaf: jnp.einsum(
                "e...,e->...", leaf.astype(jnp.float32), w_act
            ).astype(leaf.dtype), edge_params)

        round_costs = interval.astype(jnp.float32) * comp + comm  # [E]
        k_cost = jax.random.fold_in(k_data, n_edges)
        eps = jax.random.normal(k_cost, (n_edges,))
        mult = jnp.maximum(0.1, 1.0 + cost_noise * eps)
        # scenario straggler spikes compose with the i.i.d. noise model
        round_costs = round_costs * mult * scn_mult[slot_i]
        # the slot paces on the slowest ACTIVE edge, and only active
        # edges are charged — a dropped edge's budget is untouched
        slot = jnp.max(jnp.where(act, round_costs, 0.0))
        consumed = consumed + jnp.where(act, slot, 0.0)

        if metric_fn is not None:
            metric = metric_fn(new_params)
        else:
            metric = jnp.float32(jnp.nan)
        if cfg.utility == "eval_gain":
            utility = metric - prev_metric
        else:                              # param_delta (§III.A)
            utility = 1.0 / (1.0 + _tree_l2(params, new_params))

        bstate = jax_bandit_update(bstate, arm, utility, slot)
        wall = wall + slot
        n_active = jnp.sum(act.astype(jnp.int32))
        hist = {
            "metric": hist["metric"].at[t].set(metric),
            "utility": hist["utility"].at[t].set(utility),
            "interval": hist["interval"].at[t].set(interval),
            "consumed": hist["consumed"].at[t].set(jnp.sum(consumed)),
            "wall": hist["wall"].at[t].set(wall),
            "active_edges": hist["active_edges"].at[t].set(n_active),
        }
        new_carry = {"params": new_params, "bstate": bstate,
                     "consumed": consumed, "t": t + 1, "rng": rng,
                     "prev_metric": metric, "wall": wall, "hist": hist}
        if spec is not None:
            # dropout/rejoin deltas vs the previous round's mask (round
            # 0 measures against the nominal full fleet)
            prev = jnp.where(t > 0,
                             scn_active[jnp.mod(t - 1, period)],
                             jnp.ones((n_edges,), jnp.float32)) > 0
            dropouts = jnp.sum((prev & ~act).astype(jnp.int32))
            rejoins = jnp.sum((~prev & act).astype(jnp.int32))
            with jax.named_scope("obs.telemetry"):
                new_carry["telem"] = sync_ring_record(
                    carry["telem"], spec, t=t, arm=arm, round_cost=slot,
                    budget_resid=jnp.min(budget - consumed),
                    bstate=bstate, scn=(n_active, dropouts, rejoins))
        return new_carry

    def finalize(carry: Dict[str, Any], knobs: Dict[str, jax.Array]):
        out = dict(carry["hist"])
        out["n_rounds"] = carry["t"]
        out["budgets_left"] = knobs["budget"] - carry["consumed"]
        out["arm_pulls"] = carry["bstate"]["counts"]
        out["wall_time"] = carry["wall"]
        if spec is not None:
            out["telemetry"] = finalize_telemetry(carry["telem"],
                                                  carry["t"], spec)
        return carry["params"], out

    if scn is not None:
        cond, body = cond_scn, body_scn
    return ELCell(init=init, cond=cond, body=body, finalize=finalize,
                  horizon=max_rounds)


def make_sync_program(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                      lr: float, batch: int,
                      n_samples: Optional[np.ndarray] = None,
                      metric_fn: Optional[Callable] = None,
                      metric_name: str = "accuracy",
                      max_rounds: int = 512, mesh=None, telemetry=None):
    """Build ``program(init_params, rng, knobs) -> (params, out)`` — the
    whole budgeted sync run as one ``lax.while_loop``, with the
    control-plane knobs (see ``KNOB_NAMES`` / ``sync_knobs``) as traced
    inputs so one compiled program serves any (ucb_c, budget, cost) point
    — and so ``repro.el.sweep`` can vmap it over a whole ablation grid.

    With ``mesh=`` the run's ``[n_edges, ...]`` data plane shards over
    the mesh's (``pod``, ``data``) axes and model tensors over ``model``
    (``repro.sharding.el_run_partition_specs`` placement): the per-edge
    datasets and the broadcast per-edge parameter stack live sharded, so
    the vmapped local blocks — the hot path — run edge-parallel.  The
    control plane (bandit state, budgets, history) stays replicated, and
    the per-edge params are explicitly all-gathered *before* the
    aggregation einsum so every reduction executes replicated in the
    same order as the unsharded program — that is what makes a sharded
    run bit-identical to the mesh-less one (tested on a debug mesh)
    rather than an ulp off from partial-sum reordering.

    ``out`` is a dict of device arrays: per-round ``metric``, ``utility``,
    ``interval``, ``consumed`` (cumulative total across edges), ``wall``
    (cumulative straggler time), plus scalars ``n_rounds`` and the final
    per-edge ``budgets_left``.  With ``telemetry=`` (see
    ``make_sync_cell``) it gains a nested ``out["telemetry"]`` ring
    subtree; without it the program is today's, bit-for-bit.
    """
    cell = make_sync_cell(
        model, edge_data, eval_set, cfg, lr=lr, batch=batch,
        n_samples=n_samples, metric_fn=metric_fn, metric_name=metric_name,
        max_rounds=max_rounds, mesh=mesh, telemetry=telemetry)

    def program(init_params: Params, rng: jax.Array,
                knobs: Dict[str, jax.Array]):
        carry = lax.while_loop(lambda c: cell.cond(c, knobs),
                               lambda c: cell.body(c, knobs),
                               cell.init(init_params, rng, knobs))
        return cell.finalize(carry, knobs)

    return program

