"""The fully in-graph sync fast path: one XLA program per EL run.

The host-driven runtime round-trips cloud↔device once per round: a numpy
bandit picks the interval, a jitted scan runs the local iterations, numpy
charges the budgets.  This module stages the *entire* budgeted sync loop —

    in-graph bandit select  (``jax_selection_weights`` + categorical)
      → ``lax.scan`` local iterations, vmapped over edges
      → weighted parameter aggregation
      → in-graph utility (eval-gain or param-delta)
      → ``jax_bandit_update`` + budget charge

— into a single ``lax.while_loop``, so an entire run (hundreds of rounds)
is ONE compiled program with zero host synchronization.  This is what the
previously-dormant ``jax_bandit_*`` functions exist for.

Restrictions (asserted by the builder): sync mode, the ``ol4el`` policy,
the fixed cost model, and a jax-pure executor (``InGraphExecutor`` — i.e.
``ClassicExecutor``-shaped: raw per-edge arrays + a jittable
``model.local_step``).  Everything else stays on the host path.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config import OL4ELConfig
from repro.core.bandit import (jax_bandit_init, jax_bandit_update,
                               jax_selection_weights)
from repro.core.coordinator import edge_speed_factors

Params = Any


def _pad_edge_data(edge_data: List[Dict[str, np.ndarray]]
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stack per-edge datasets [E, Nmax, d] / [E, Nmax] with wraparound
    padding (padding rows repeat real rows, so uniform index sampling over
    [0, n_e) never sees them)."""
    n = np.array([len(d["y"]) for d in edge_data], np.int32)
    n_max = int(n.max())
    dim = edge_data[0]["x"].shape[-1]
    xs = np.zeros((len(edge_data), n_max, dim), np.float32)
    ys = np.zeros((len(edge_data), n_max), np.int32)
    for e, d in enumerate(edge_data):
        reps = -(-n_max // len(d["y"]))
        xs[e] = np.tile(np.asarray(d["x"], np.float32), (reps, 1))[:n_max]
        ys[e] = np.tile(np.asarray(d["y"], np.int32), reps)[:n_max]
    return jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(n)


def default_metric_fn(model, eval_set, metric_name: str
                      ) -> Optional[Callable[[Params], jax.Array]]:
    """A jittable eval metric when the model supports one (SVM accuracy);
    None means the in-graph path must run with a params-only utility."""
    if metric_name == "accuracy" and hasattr(model, "scores"):
        xe = jnp.asarray(eval_set["x"], jnp.float32)
        ye = jnp.asarray(eval_set["y"], jnp.int32)

        def accuracy(params):
            pred = jnp.argmax(model.scores(params, xe), -1)
            return jnp.mean((pred == ye).astype(jnp.float32))

        return accuracy
    return None


def _tree_l2(a: Params, b: Params) -> jax.Array:
    total = sum(jnp.sum((x.astype(jnp.float32) - y.astype(jnp.float32)) ** 2)
                for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    return jnp.sqrt(total)


def make_sync_fastpath(model, edge_data, eval_set, cfg: OL4ELConfig, *,
                       lr: float, batch: int,
                       n_samples: Optional[np.ndarray] = None,
                       metric_fn: Optional[Callable] = None,
                       metric_name: str = "accuracy",
                       max_rounds: int = 512):
    """Build ``program(init_params, rng) -> (params, out)`` — the whole
    budgeted sync run as one jitted ``lax.while_loop``.

    ``out`` is a dict of device arrays: per-round ``metric``, ``utility``,
    ``interval``, ``consumed`` (cumulative total across edges), ``wall``
    (cumulative straggler time), plus scalars ``n_rounds`` and the final
    per-edge ``budgets_left``.
    """
    if cfg.mode != "sync":
        raise ValueError("the in-graph fast path is sync-only "
                         f"(cfg.mode={cfg.mode!r})")
    if cfg.policy != "ol4el":
        raise ValueError("the in-graph fast path implements the ol4el "
                         f"selection rule only (cfg.policy={cfg.policy!r})")
    if cfg.cost_model != "fixed":
        raise ValueError("variable-cost mode draws host-side noise; use the "
                         "host path (cfg.cost_model must be 'fixed')")
    if cfg.utility not in ("eval_gain", "param_delta"):
        raise ValueError(f"unsupported in-graph utility {cfg.utility!r}")

    n_edges, k = cfg.n_edges, cfg.max_interval
    speed = edge_speed_factors(n_edges, cfg.heterogeneity)
    comp = jnp.asarray(cfg.comp_cost * speed, jnp.float32)          # [E]
    comm = jnp.full((n_edges,), cfg.comm_cost, jnp.float32)         # [E]
    intervals_f = jnp.arange(1, k + 1, dtype=jnp.float32)
    # sync feasibility is scored against the binding (slowest) edge
    worst = int(np.argmax(np.asarray(comp)))
    costs_k = intervals_f * comp[worst] + comm[worst]               # [K]
    min_edge_cost = comp + comm                                     # [E]

    xs, ys, n_per_edge = _pad_edge_data(edge_data)
    w_agg = (np.ones(n_edges) if n_samples is None
             else np.asarray(n_samples, np.float64))
    w_agg = jnp.asarray(w_agg / w_agg.sum(), jnp.float32)

    if metric_fn is None:
        metric_fn = default_metric_fn(model, eval_set, metric_name)
    if cfg.utility == "eval_gain" and metric_fn is None:
        raise ValueError(
            "utility='eval_gain' needs a jittable metric; pass metric_fn= "
            "or use utility='param_delta'")

    def local_block(params: Params, edge: jax.Array, interval: jax.Array,
                    key: jax.Array) -> Params:
        """`interval` masked local iterations on one edge's shard."""

        def body(p, step):
            u = jax.random.uniform(jax.random.fold_in(key, step), (batch,))
            idx = (u * n_per_edge[edge].astype(jnp.float32)).astype(jnp.int32)
            b = {"x": xs[edge][idx], "y": ys[edge][idx]}
            p2, _ = model.local_step(p, b, lr)
            take = step < interval
            return jax.tree.map(
                lambda a, c: jnp.where(take, c, a), p, p2), None

        params, _ = lax.scan(body, params, jnp.arange(k))
        return params

    def weighted_mean(trees: Params) -> Params:
        return jax.tree.map(
            lambda leaf: jnp.einsum(
                "e...,e->...", leaf.astype(jnp.float32), w_agg
            ).astype(leaf.dtype), trees)

    def cond(carry):
        (_, _, consumed, t, _, _, _, _) = carry
        resid = cfg.budget - consumed                                # [E]
        affordable = jnp.min(resid) >= jnp.min(costs_k) - 1e-12
        exhausted = jnp.any(resid < min_edge_cost)
        return (t < max_rounds) & affordable & ~exhausted

    def body(carry):
        (params, bstate, consumed, t, rng, prev_metric, wall, hist) = carry
        rng, k_sel, k_data = jax.random.split(rng, 3)
        resid = jnp.min(cfg.budget - consumed)
        w = jax_selection_weights(bstate, resid, costs_k, cfg.ucb_c)
        logits = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-30)), -jnp.inf)
        arm = jax.random.categorical(k_sel, logits)
        interval = arm + 1

        edge_ids = jnp.arange(n_edges)
        keys = jax.vmap(lambda e: jax.random.fold_in(k_data, e))(edge_ids)
        bcast = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_edges,) + x.shape), params)
        edge_params = jax.vmap(local_block, in_axes=(0, 0, None, 0))(
            bcast, edge_ids, interval, keys)
        new_params = weighted_mean(edge_params)

        # straggler semantics: every edge's clock advances by the slowest
        # edge's round time (matches CloudCoordinator.charge in run_sync)
        round_costs = interval.astype(jnp.float32) * comp + comm     # [E]
        slot = jnp.max(round_costs)
        consumed = consumed + slot

        if metric_fn is not None:
            metric = metric_fn(new_params)
        else:
            metric = jnp.float32(jnp.nan)
        if cfg.utility == "eval_gain":
            utility = metric - prev_metric
        else:                                  # param_delta (§III.A)
            utility = 1.0 / (1.0 + _tree_l2(params, new_params))

        bstate = jax_bandit_update(bstate, arm, utility, slot)
        wall = wall + slot
        hist = {
            "metric": hist["metric"].at[t].set(metric),
            "utility": hist["utility"].at[t].set(utility),
            "interval": hist["interval"].at[t].set(interval),
            "consumed": hist["consumed"].at[t].set(
                jnp.sum(consumed)),
            "wall": hist["wall"].at[t].set(wall),
        }
        return (new_params, bstate, consumed, t + 1, rng, metric, wall,
                hist)

    def program(init_params: Params, rng: jax.Array):
        bstate = jax_bandit_init(k)
        consumed = jnp.zeros((n_edges,), jnp.float32)
        if metric_fn is not None:
            prev_metric = metric_fn(init_params)
        else:
            prev_metric = jnp.float32(jnp.nan)
        hist = {
            "metric": jnp.full((max_rounds,), jnp.nan, jnp.float32),
            "utility": jnp.zeros((max_rounds,), jnp.float32),
            "interval": jnp.zeros((max_rounds,), jnp.int32),
            "consumed": jnp.zeros((max_rounds,), jnp.float32),
            "wall": jnp.zeros((max_rounds,), jnp.float32),
        }
        carry = (init_params, bstate, consumed, jnp.int32(0), rng,
                 prev_metric, jnp.float32(0.0), hist)
        (params, bstate, consumed, t, _, _, wall, hist) = \
            lax.while_loop(cond, body, carry)
        out = dict(hist)
        out["n_rounds"] = t
        out["budgets_left"] = cfg.budget - consumed
        out["arm_pulls"] = bstate["counts"]
        out["wall_time"] = wall
        return params, out

    return program
