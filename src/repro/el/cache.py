"""Bounded compiled-program cache, shared by sessions and fleet cohorts.

Every compiled EL program's closure pins a device-resident copy of the
padded per-edge datasets, so an unbounded cache leaks device memory
under ever-changing keys (e.g. fresh ``metric_fn`` lambdas).  This is
the bounded FIFO ``ELSession`` has kept inline since the donation PR,
extracted so a :class:`repro.el.fleet.FleetServer` can share one cache
(and its hit/miss counters — the fleet's compiles-per-cohort assertion)
with the sessions that verify its tenants, and so ``close()`` /
``clear()`` can release the pinned buffers of long-lived servers.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, Optional


class ProgramCache:
    """Insertion-ordered dict of compiled programs with FIFO eviction.

    Mapping-shaped on purpose: ``len`` / ``in`` / iteration behave like
    the plain dict it replaces, so session internals (and the tests that
    poke them) keep working.  ``hits`` / ``misses`` / ``evictions``
    count ``get()``/``put()`` outcomes — a fleet cohort compiles exactly
    once iff every later lookup of its key is a hit — and are surfaced
    as a snapshot by :meth:`stats` (``ELReport.telemetry["cache"]``,
    the fleet CLI summary line).  Lookups and evictions also emit
    ``cache.hit`` / ``cache.miss`` / ``cache.evict`` events on the
    process tracer (``repro.obs.trace``), so a JSONL span stream shows
    exactly when a server recompiled.
    """

    def __init__(self, max_entries: int = 8):
        self.max_entries = int(max_entries)
        self._entries: Dict[tuple, Any] = {}
        # per-entry ProgramProfile side-store (repro.obs.prof): kept out
        # of _entries so cached values stay bare callables — session
        # internals (and the tests that poke them) treat entries as the
        # programs themselves.  Evicted with the entry.
        self._profiles: Dict[tuple, Any] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: tuple, default: Optional[Any] = None) -> Any:
        from repro.obs import trace
        entry = self._entries.get(key, default)
        if entry is default:
            self.misses += 1
            trace.event("cache.miss", misses=self.misses)
        else:
            self.hits += 1
            trace.event("cache.hit", hits=self.hits)
        return entry

    def put(self, key: tuple, program: Any) -> Any:
        """Insert, evicting oldest entries past ``max_entries`` (any
        alias the caller keeps — e.g. the session's last-used fast-path
        handle — keeps an evicted program alive until replaced)."""
        from repro.obs import trace
        self._entries[key] = program
        while len(self._entries) > self.max_entries:
            evicted = next(iter(self._entries))
            self._entries.pop(evicted)
            self._profiles.pop(evicted, None)
            self.evictions += 1
            trace.event("cache.evict", evictions=self.evictions)
        return program

    def set_profile(self, key: tuple, profile: Any) -> Any:
        """Attach a :class:`repro.obs.prof.ProgramProfile` to a cached
        program (no-op for unknown keys — the entry may have been
        evicted between compile and profile)."""
        if key in self._entries:
            self._profiles[key] = profile
        return profile

    def profile(self, key: tuple) -> Optional[Any]:
        """The profile attached to a cached program (None when never
        profiled, or evicted)."""
        return self._profiles.get(key)

    def profiles(self) -> Dict[tuple, Any]:
        """Snapshot of every attached profile (key → ProgramProfile)."""
        return dict(self._profiles)

    def stats(self) -> Dict[str, int]:
        """Counter snapshot: entries/max_entries/hits/misses/evictions
        (+ how many entries carry a profile)."""
        return {
            "entries": len(self._entries),
            "max_entries": self.max_entries,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "profiled": len(self._profiles),
        }

    def clear(self) -> int:
        """Drop every cached program, returning how many were dropped.
        The programs' closures (and with them the device-resident
        datasets they pin) become collectible once callers also drop
        their aliases."""
        n = len(self._entries)
        self._entries.clear()
        self._profiles.clear()
        return n

    # -- dict-compatible surface ---------------------------------------------

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries

    def __iter__(self) -> Iterator[tuple]:
        return iter(self._entries)

    def __getitem__(self, key: tuple) -> Any:
        return self._entries[key]

    def __setitem__(self, key: tuple, program: Any) -> None:
        self.put(key, program)

    def keys(self):
        return self._entries.keys()

    def values(self):
        return self._entries.values()

    def items(self):
        return self._entries.items()
