"""The shared classic-workload (SVM / K-means) EL data plane.

One source of truth for the per-arch fixture every classic launcher
builds — ``repro.launch.train`` (compiled single runs),
``repro.launch.sweep`` (compiled grids) and ``scripts/bench_el.py``
(the benchmark artifact) previously kept three drifting copies of the
dataset builder plus the metric/lr/batch/utility constants.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from repro.config import get_config
from repro.data import (make_traffic_dataset, make_wafer_dataset,
                        partition_edges)
from repro.federated import ClassicExecutor
from repro.models import build_model

#: Per-arch data-plane recipe: (metric, lr, batch, utility).  The
#: utility matches the paper's pairing — eval-gain for the SVM testbed,
#: the model-specific param-delta for K-means (no jittable F1).
CLASSIC_RECIPES = {
    "svm-wafer": ("accuracy", 0.05, 64, "eval_gain"),
    "kmeans-traffic": ("f1", 1.0, 128, "param_delta"),
}


def classic_fixture(arch: str, *, samples: int, n_edges: int,
                    alpha: float = 100.0, data_seed: int = 0,
                    kmeans_impl: str = "jnp",
                    batch: Optional[int] = None) -> Dict[str, Any]:
    """Build the classic EL data plane: dataset → Dirichlet edge split →
    ``ClassicExecutor``, plus the arch's recipe constants.

    Returns a dict with ``exp`` (the ExperimentConfig), ``model``,
    ``executor``, ``metric``, ``lr``, ``utility``, ``init_params`` (from
    ``model.init(key(data_seed))``) and ``n_samples`` (per-edge sizes,
    the aggregation weights).  ``batch`` overrides the recipe's
    minibatch size (benchmarks use a larger one).
    """
    import jax
    metric, lr, recipe_batch, utility = CLASSIC_RECIPES[arch]
    exp = get_config(arch)
    if arch == "kmeans-traffic":
        train, test = make_traffic_dataset(n=samples, seed=data_seed)
        model = build_model(exp.model, impl=kmeans_impl)
    else:
        train, test = make_wafer_dataset(n=samples, seed=data_seed)
        model = build_model(exp.model)
    edges = partition_edges(train, n_edges, alpha=alpha, seed=data_seed)
    ex = ClassicExecutor(model, edges, test,
                         batch=batch or recipe_batch, lr=lr)
    return {
        "exp": exp, "model": model, "executor": ex, "metric": metric,
        "lr": lr, "utility": utility,
        "init_params": model.init(jax.random.key(data_seed)),
        "n_samples": [len(e["y"]) for e in edges],
    }
