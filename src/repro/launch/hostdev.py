"""Pre-jax-init forced-host-device plumbing, shared by the launchers.

jax locks the device count on first backend init, so any CLI that wants
a CPU-emulated multi-device fleet must append
``--xla_force_host_platform_device_count`` to ``XLA_FLAGS`` *before*
importing jax.  This module imports only ``os``/``sys`` (and the empty
``repro``/``repro.launch`` package inits), so launchers can safely call
:func:`force_host_devices` as their first statement —
``repro.launch.sweep``, ``repro.launch.train`` and
``scripts/bench_el.py`` all route through here instead of keeping
hand-rolled copies in sync.  (``repro.launch.dryrun`` keeps its own
env-var preamble: it needs 512 placeholder devices unconditionally.)
"""

from __future__ import annotations

import os
import sys
from typing import Sequence


def force_host_devices(flag: str = "--mesh", *,
                       skip: Sequence[str] = ("none",),
                       env: str = "REPRO_SWEEP_DEVICES",
                       default: str = "4",
                       count_from_flag: bool = False,
                       always: bool = False) -> None:
    """Append the forced host-device count when ``flag`` asks for it.

    Scans ``sys.argv`` for ``flag`` (both ``--flag value`` and
    ``--flag=value`` spellings).  When its value is present and not in
    ``skip`` — or unconditionally with ``always=True`` — the device
    count is taken from the flag itself (``count_from_flag=True``, e.g.
    ``--devices 8``) or from the ``env`` variable (default ``4``).
    MUST run before jax initializes its backends.
    """
    val = None
    for i, arg in enumerate(sys.argv):
        if arg == flag and i + 1 < len(sys.argv):
            val = sys.argv[i + 1]
        elif arg.startswith(flag + "="):
            val = arg.split("=", 1)[1]
    if val is None or val in skip:
        if not always:
            return
    n = val if (count_from_flag and val is not None) \
        else os.environ.get(env, default)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=" + n)
