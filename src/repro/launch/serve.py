"""Serving launcher: batched prefill + decode with a KV/SSM cache.

``python -m repro.launch.serve --arch qwen3-1.7b --smoke --tokens 32``
runs a batch of synthetic requests end to end: prefill the prompts, then
greedy-decode N tokens per request.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import get_config, get_smoke_config
from repro.data import SyntheticLMData
from repro.models import build_model


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    exp = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mc = exp.model
    model = build_model(mc)
    params = model.init(jax.random.key(0))
    data = SyntheticLMData.for_model(mc, args.batch, args.prompt_len)
    prompts = data.batch(0, 0)["tokens"]

    max_len = args.prompt_len + args.tokens + 1
    cache = model.init_cache(args.batch, max_len)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"({t_prefill * 1e3:.1f} ms)")

    def sample(lg, key):
        lg = lg[..., -1, :] if lg.ndim == 3 else lg[:, :, -1, :]
        if args.temperature <= 0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(key, lg / args.temperature, axis=-1)

    tok = sample(logits, jax.random.key(1))
    generated = [tok]
    t0 = time.time()
    for i in range(args.tokens):
        if mc.n_codebooks > 1:
            inp = tok.reshape(args.batch, mc.n_codebooks, 1)
        else:
            inp = tok.reshape(args.batch, 1)
        logits, cache = decode(params, inp, cache)
        tok = sample(logits, jax.random.key(2 + i))
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode: {args.tokens} steps x batch {args.batch} "
          f"-> {args.tokens * args.batch / dt:.1f} tok/s "
          f"({dt / args.tokens * 1e3:.1f} ms/step)")
    out = jnp.stack([g.reshape(args.batch, -1)[:, 0] for g in generated], 1)
    print("generated token ids (first request):",
          out[0][:16].tolist())


if __name__ == "__main__":
    main()
