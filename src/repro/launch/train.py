"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Two execution modes:
  * ``--mode standard`` — plain synchronous training (train_step loop).
  * ``--mode ol4el``    — the paper's edge-cloud collaborative loop: E
    simulated edges, per-round intervals chosen by the budget-limited MAB,
    masked local steps + weighted aggregation (``el_round``), budgets
    charged per the heterogeneous cost model.

On a real TPU cluster the same code runs under the production mesh (see
``repro.launch.mesh``); on this CPU host it runs on the default device
with the smoke-scale configs.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, get_smoke_config
from repro.core.coordinator import CloudCoordinator
from repro.data import SyntheticLMData
from repro.federated import init_el_state, make_el_round
from repro.models import build_model
from repro.train import (checkpoint, init_train_state, make_train_step)


def train_standard(exp, args) -> None:
    model = build_model(exp.model)
    state = init_train_state(model, exp.train, jax.random.key(exp.train.seed))
    data = SyntheticLMData.for_model(exp.model, args.batch, args.seq)
    step = jax.jit(make_train_step(model, exp.train))
    for i in range(args.steps):
        t0 = time.time()
        state, metrics = step(state, data.batch(0, i))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"dt={time.time() - t0:.2f}s", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, state, step=args.steps)
        print(f"saved checkpoint to {args.ckpt}")


def train_ol4el(exp, args) -> None:
    model = build_model(exp.model)
    ol = dataclasses.replace(exp.ol4el, n_edges=args.edges,
                             heterogeneity=args.heterogeneity,
                             budget=args.budget, mode=args.el_mode)
    coord = CloudCoordinator(ol, args.edges, lr=exp.train.peak_lr)
    h_max = ol.max_interval
    state = init_el_state(model, exp.train, args.edges,
                          jax.random.key(exp.train.seed))
    data = SyntheticLMData.for_model(exp.model, args.batch, args.seq)
    el_round = jax.jit(make_el_round(model, exp.train, h_max=h_max,
                                     mode="sync" if ol.mode == "sync"
                                     else "async"))
    prev_loss = None
    rnd = 0
    step_counter = np.zeros(args.edges, np.int64)
    while rnd < args.steps:
        intervals = []
        for e in range(args.edges):
            i = coord.decide(0 if ol.mode == "sync" else e)
            if i < 0:
                print(f"round {rnd}: edge {e} budget exhausted -> stop")
                return
            intervals.append(i)
        if ol.mode == "sync":
            intervals = [intervals[0]] * args.edges
        batches = {"tokens": jnp.stack([
            jnp.stack([data.batch(e, int(step_counter[e]) + s)["tokens"]
                       for s in range(h_max)])
            for e in range(args.edges)])}
        ivec = jnp.asarray(intervals, jnp.int32)
        state, metrics = el_round(state, batches, ivec,
                                  jnp.ones(args.edges, jnp.float32))
        loss = float(metrics["mean_loss"])
        for e in range(args.edges):
            step_counter[e] += intervals[e]
            cost = coord.realized_cost(e, intervals[e])
            coord.charge(e, cost)
            utility = 0.0 if prev_loss is None else max(prev_loss - loss, 0.0)
            coord.observe(0 if ol.mode == "sync" else e, intervals[e],
                          utility, cost)
        prev_loss = loss
        rnd += 1
        if rnd % args.log_every == 0:
            cons = coord.total_consumed()
            print(f"round {rnd:4d} loss={loss:.4f} "
                  f"intervals={intervals} consumed={cons:.0f}/"
                  f"{args.edges * args.budget:.0f}", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, state, step=rnd)
        print(f"saved EL checkpoint to {args.ckpt}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--mode", default="standard",
                    choices=["standard", "ol4el"])
    ap.add_argument("--el-mode", default="async", choices=["sync", "async"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--heterogeneity", type=float, default=4.0)
    ap.add_argument("--budget", type=float, default=1e5)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    exp = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.mode == "standard":
        train_standard(exp, args)
    else:
        train_ol4el(exp, args)


if __name__ == "__main__":
    main()
