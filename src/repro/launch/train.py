"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Two execution modes:
  * ``--mode standard`` — plain synchronous training (train_step loop).
  * ``--mode ol4el``    — the paper's edge-cloud collaborative loop via
    the ``repro.el.ELSession`` façade: E simulated edges, per-block
    intervals chosen by the budget-limited MAB, local-SGD blocks +
    aggregation, budgets charged per the heterogeneous cost model.

Classic archs (``svm-wafer`` / ``kmeans-traffic``) under ``--mode
ol4el`` run the COMPILED single-run programs (``run_sync_ingraph`` /
``run_async_ingraph``).  ``--mesh debug|prod`` shards that single run's
``[n_edges, ...]`` data plane over a mesh (``debug``: a 2x2 forced
host-device mesh; ``prod``: ``repro.launch.mesh.make_production_mesh``,
which ``REPRO_DEBUG_MESH=d`` shrinks to ``d x d`` for CI) — bit-identical
to the unsharded run.  ``--donate`` donates the initial params' buffers
so aggregations update the fleet parameters in place.

On a real TPU cluster the same code runs under the production mesh; on
this CPU host ``--mesh`` emulates a small fleet via forced host devices
(``REPRO_SWEEP_DEVICES``, default 4) and LM archs run on the default
device with the smoke-scale configs.
"""

from __future__ import annotations

from repro.launch.hostdev import force_host_devices

force_host_devices()     # must precede the jax import (emulated fleet)

import argparse
import dataclasses
import time

import jax

from repro.config import get_config, get_smoke_config
from repro.data import SyntheticLMData
from repro.el import ELSession
from repro.federated import LMExecutor
from repro.models import build_model
from repro.obs.cli import (add_metrics_args, begin_observability,
                           finish_observability, telemetry_arg)
from repro.train import (checkpoint, init_train_state, make_train_step)


def train_standard(exp, args) -> None:
    n_steps = args.steps if args.steps is not None else 50
    model = build_model(exp.model)
    state = init_train_state(model, exp.train, jax.random.key(exp.train.seed))
    data = SyntheticLMData.for_model(exp.model, args.batch, args.seq)
    step = jax.jit(make_train_step(model, exp.train))
    for i in range(n_steps):
        t0 = time.time()
        state, metrics = step(state, data.batch(0, i))
        if i % args.log_every == 0 or i == n_steps - 1:
            print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"dt={time.time() - t0:.2f}s", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, state, step=n_steps)
        print(f"saved checkpoint to {args.ckpt}")
    return None


def _build_mesh(args):
    import os
    if args.mesh == "none":
        return None
    from repro.launch.mesh import make_debug_mesh_for, make_production_mesh
    if args.mesh == "debug":
        n_dev = jax.device_count()
        if n_dev == 1:
            # the forced-host-device preamble scans sys.argv, so a
            # programmatic main(argv=[... , "--mesh", "debug"]) call
            # misses it — run unsharded loudly rather than silently
            print("WARNING: --mesh debug but only 1 device is visible "
                  "(forced host devices are set from sys.argv before "
                  "jax init — invoke via the CLI, or set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=N yourself); "
                  "running on a 1x1 mesh", flush=True)
        return make_debug_mesh_for(n_dev)
    if not os.environ.get("REPRO_DEBUG_MESH") and jax.device_count() < 256:
        raise SystemExit(
            "--mesh prod needs the production fleet (a 16x16 = 256-chip "
            "pod); on a CPU host set REPRO_DEBUG_MESH=2 (with "
            "REPRO_SWEEP_DEVICES=4) for the debug-scale 2x2 production "
            "mesh, or use --mesh debug")
    return make_production_mesh()


def train_classic_ol4el(exp, args) -> None:
    """Classic archs through the compiled single-run EL programs —
    optionally mesh-sharded (``--mesh``), buffer-donating
    (``--donate``) and scenario-injected (``--churn``/``--cost-model``/
    ``--drift``, see ``repro.el.scenarios``)."""
    from repro.el.scenarios.cli import scenario_from_args
    from repro.launch.classic import classic_fixture

    fx = classic_fixture(args.arch, samples=args.samples,
                         n_edges=args.edges, alpha=args.alpha,
                         kmeans_impl=args.kmeans_impl)
    metric = fx["metric"]
    scenario, base_cost_model = scenario_from_args(args)
    ol = dataclasses.replace(fx["exp"].ol4el, n_edges=args.edges,
                             heterogeneity=args.heterogeneity,
                             budget=args.budget, mode=args.el_mode,
                             async_alpha=args.async_alpha,
                             async_batch_k=args.async_batch_k,
                             policy="ol4el", utility=fx["utility"],
                             cost_model=base_cost_model,
                             scenario=scenario)
    mesh = _build_mesh(args)
    session = (ELSession(ol, metric_name=metric, lr=fx["lr"])
               .with_executor(fx["executor"],
                              init_params=fx["init_params"],
                              n_samples=fx["n_samples"]))
    desc = (f"compiled {ol.mode} run, {args.edges} edges"
            + (f", mesh {tuple(mesh.shape.items())}" if mesh else "")
            + (", donated params" if args.donate else ""))
    print(f"ol4el {args.arch}: {desc}", flush=True)
    if ol.mode == "sync":
        report = session.run_sync_ingraph(
            max_rounds=args.steps if args.steps is not None else 256,
            mesh=mesh, donate=args.donate, telemetry=args.telemetry)
    else:
        # same announced-cap contract as train_ol4el: an explicit
        # --steps bounds the run at steps*edges events, never silently
        if args.steps is not None:
            print(f"async: --steps caps the run at "
                  f"{args.steps * args.edges} events (omit --steps to "
                  "run to budget exhaustion)", flush=True)
        report = session.run_async_ingraph(
            max_events=None if args.steps is None
            else args.steps * args.edges,
            mesh=mesh, donate=args.donate, telemetry=args.telemetry)
    print(f"done: {report.n_aggregations} aggregations, "
          f"final {metric} {report.final_metric:.4f}, "
          f"consumed {report.total_consumed:.0f} "
          f"({report.terminated_reason}); arm pulls {report.arm_pulls}")
    cache = (report.telemetry or {}).get("cache")
    if cache:
        print(f"compile cache: {cache['entries']} programs "
              f"({cache['hits']} hits, {cache['misses']} misses, "
              f"{cache['evictions']} evictions)", flush=True)
    if args.ckpt:
        checkpoint.save(args.ckpt, report.final_params,
                        step=report.n_aggregations)
        print(f"saved EL checkpoint to {args.ckpt}")
    return report


def train_ol4el(exp, args) -> None:
    model = build_model(exp.model)
    ol = dataclasses.replace(exp.ol4el, n_edges=args.edges,
                             heterogeneity=args.heterogeneity,
                             budget=args.budget, mode=args.el_mode,
                             async_alpha=args.async_alpha,
                             utility="loss_delta")
    ex = LMExecutor(model, exp.model, exp.train, batch=args.batch,
                    seq_len=args.seq, seed=exp.train.seed)

    def progress(rec):
        if rec.n_aggregations % args.log_every == 0:
            print(f"agg {rec.n_aggregations:4d} loss={rec.metric:.4f} "
                  f"interval={rec.interval:.0f} edge={rec.edge} "
                  f"consumed={rec.total_consumed:.0f}/"
                  f"{args.edges * args.budget:.0f}", flush=True)

    session = (ELSession(ol, metric_name="loss", lr=exp.train.peak_lr)
               .with_executor(ex)
               .on_round(progress))
    if ol.mode == "sync":
        report = session.run_sync(
            max_rounds=args.steps if args.steps is not None else 50)
    else:
        # without an explicit --steps the event horizon is derived from
        # budget/cost (repro.el.events.default_event_horizon): async
        # runs terminate on budget exhaustion — the old steps-based
        # default silently truncated long runs.  An explicit --steps
        # still caps the run (steps * edges events).
        if args.steps is not None:
            print(f"async: --steps caps the run at "
                  f"{args.steps * args.edges} events (omit --steps to "
                  "run to budget exhaustion)", flush=True)
        report = session.run_async(
            max_events=None if args.steps is None
            else args.steps * args.edges)
    print(f"done: {report.n_aggregations} aggregations, "
          f"final loss {report.final_metric:.4f}, "
          f"consumed {report.total_consumed:.0f} "
          f"({report.terminated_reason}); arm pulls {report.arm_pulls}")
    if args.ckpt:
        checkpoint.save(args.ckpt, report.final_params,
                        step=report.n_aggregations)
        print(f"saved EL checkpoint to {args.ckpt}")
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--mode", default="standard",
                    choices=["standard", "ol4el"])
    ap.add_argument("--el-mode", default="async", choices=["sync", "async"])
    ap.add_argument("--async-alpha", type=float, default=0.5,
                    help="async staleness-mix base rate (cfg.async_alpha)")
    ap.add_argument("--async-batch-k", type=int, default=0,
                    help="async K-event wave width (cfg.async_batch_k; "
                         "0 = auto: 1 replicated, mesh-tuned sharded)")
    ap.add_argument("--steps", type=int, default=None,
                    help="standard/sync: training steps/rounds (default "
                         "50); async: optional event cap of steps*edges "
                         "— omitted, the run goes to budget exhaustion")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--heterogeneity", type=float, default=4.0)
    ap.add_argument("--budget", type=float, default=1e5)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "debug", "prod"],
                    help="shard a classic-arch single EL run: 'debug' "
                         "builds a mesh over the forced host devices "
                         "(REPRO_SWEEP_DEVICES, default 4); 'prod' uses "
                         "repro.launch.mesh.make_production_mesh "
                         "(REPRO_DEBUG_MESH=d shrinks it to d x d)")
    ap.add_argument("--donate", action="store_true",
                    help="donate the initial params' buffers to the "
                         "compiled run (in-place fleet update; classic "
                         "ol4el only)")
    ap.add_argument("--samples", type=int, default=4000,
                    help="classic-arch dataset size (ol4el mode)")
    ap.add_argument("--alpha", type=float, default=100.0,
                    help="Dirichlet concentration of the classic edge "
                         "data split (matches repro.launch.sweep)")
    ap.add_argument("--kmeans-impl", default="jnp",
                    choices=["jnp", "pallas"],
                    help="K-means E-step engine for the local blocks "
                         "(pallas: the repro.kernels.kmeans_assign "
                         "kernel; interpret mode off-TPU)")
    from repro.el.scenarios.cli import add_scenario_args
    add_scenario_args(ap)
    add_metrics_args(ap, trace_dir=True)
    telemetry_arg(ap)
    args = ap.parse_args(argv)

    exp = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    classic_el = args.mode == "ol4el" and exp.model.family == "classic"
    scenario_flags = (args.churn is not None or args.drift is not None
                      or args.cost_model not in ("fixed", "variable"))
    if not classic_el and (args.mesh != "none" or args.donate
                          or args.telemetry is not None or scenario_flags):
        ap.error("--mesh/--donate/--telemetry/--churn/--drift and the "
                 "scenario --cost-model kinds drive the compiled "
                 "single-run programs, which need a classic arch under "
                 "--mode ol4el (LM archs and --mode standard run the "
                 "host loops)")
    begin_observability(args)
    if args.mode == "standard":
        report = train_standard(exp, args)
    elif classic_el:
        report = train_classic_ol4el(exp, args)
    else:
        report = train_ol4el(exp, args)
    registry = None
    if args.metrics_out and report is not None:
        from repro.obs import registry_from_report
        registry = registry_from_report(
            report, labels={"arch": args.arch, "mode": report.mode})
    finish_observability(args, registry)


if __name__ == "__main__":
    main()
