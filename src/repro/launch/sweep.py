"""Ablation-sweep launcher: a whole hyperparameter grid as ONE program.

    PYTHONPATH=src python -m repro.launch.sweep --arch svm-wafer \
        --ucb-c 1.0 2.0 --budget 2000 4000 --seeds 0 1 2

Flattens the grid (ucb_c × budget × heterogeneity × cost_noise ×
async_alpha × seeds) into ``[n_cells]``, vmaps the compiled in-graph EL
program over it (``repro.el.sweep``) — the sync round or, with
``--el-mode async``, the event-horizon async engine
(``repro.el.events``) — and prints per-cell rows, seed-mean curves and
the accuracy-vs-resource Pareto frontier.

``--mesh debug`` runs the sharded path on forced host devices (the sweep
dim over the mesh's ``data`` axis, the knob edge dim over ``model``) —
the same placement a TPU fleet uses via ``repro.launch.mesh``.
``REPRO_SWEEP_DEVICES`` sets the forced device count (default 4); the
debug mesh takes shape ``(count//2, 2)``, so 8 devices give a 4-wide
sweep (``data``) axis.
"""

from __future__ import annotations

from repro.launch.hostdev import force_host_devices

force_host_devices()     # must precede the jax import (emulated fleet)

import argparse
import dataclasses

import jax

from repro.config import CLASSIC_IDS
from repro.el import ELSession
from repro.el.sweep import spec_from_sequences
from repro.launch.classic import classic_fixture
from repro.launch.mesh import make_debug_mesh_for
from repro.obs.cli import (add_metrics_args, begin_observability,
                           finish_observability, telemetry_arg)


def build_session(args, scenario=None,
                  base_cost_model=None) -> ELSession:
    fx = classic_fixture(args.arch, samples=args.samples,
                         n_edges=args.edges, alpha=args.alpha,
                         data_seed=args.data_seed,
                         kmeans_impl=args.kmeans_impl)
    ol = dataclasses.replace(
        fx["exp"].ol4el, mode=args.el_mode, policy="ol4el",
        n_edges=args.edges, utility=fx["utility"],
        cost_model=(base_cost_model if base_cost_model is not None
                    else args.cost_model),
        scenario=scenario, max_interval=args.max_interval)
    return (ELSession(ol, metric_name=fx["metric"], lr=fx["lr"])
            .with_executor(fx["executor"],
                           init_params=fx["init_params"],
                           n_samples=fx["n_samples"]))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="run an OL4EL ablation grid as one compiled program")
    ap.add_argument("--arch", default="svm-wafer", choices=CLASSIC_IDS)
    ap.add_argument("--ucb-c", type=float, nargs="*", default=[],
                    help="ol4el exploration-constant grid")
    ap.add_argument("--budget", type=float, nargs="*", default=[],
                    help="per-edge budget grid")
    ap.add_argument("--heterogeneity", type=float, nargs="*", default=[],
                    help="fleet heterogeneity (H) grid")
    ap.add_argument("--cost-noise", type=float, nargs="*", default=[],
                    help="variable-cost noise-scale grid (>0 implies "
                         "cost_model=variable for that cell)")
    ap.add_argument("--async-alpha", type=float, nargs="*", default=[],
                    help="async staleness-mix base-rate grid "
                         "(a no-op axis for sync grids)")
    ap.add_argument("--async-batch-k", type=int, nargs="*", default=[],
                    help="async K-event wave-width grid (one compiled "
                         "sub-sweep per K; 0 = auto — throughput axis, "
                         "every K computes identical results)")
    ap.add_argument("--policy", nargs="*", default=[],
                    help="competitor-policy grid (ol4el task_alloc "
                         "delay_energy) — traced through the scenario "
                         "engine's policy switch, one program for all "
                         "(sync; implies an identity scenario)")
    ap.add_argument("--churn-rate", type=float, nargs="*", default=[],
                    help="churn-rate grid: re-draws each cell's dropout "
                         "schedule (needs a base --churn RATE)")
    ap.add_argument("--seeds", type=int, nargs="*", default=[0, 1])
    ap.add_argument("--el-mode", default="sync", choices=["sync", "async"],
                    help="'async': every cell runs the compiled "
                         "event-horizon program (repro.el.events); "
                         "max-rounds then bounds merge EVENTS")
    ap.add_argument("--max-rounds", type=int, default=256)
    ap.add_argument("--edges", type=int, default=3)
    ap.add_argument("--samples", type=int, default=4000)
    ap.add_argument("--alpha", type=float, default=100.0,
                    help="Dirichlet concentration of the edge data split")
    ap.add_argument("--max-interval", type=int, default=10)
    ap.add_argument("--kmeans-impl", default="jnp",
                    choices=["jnp", "pallas"],
                    help="K-means E-step engine inside the compiled "
                         "local blocks (pallas: the "
                         "repro.kernels.kmeans_assign kernel; interpret "
                         "mode off-TPU)")
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--mesh", default="none", choices=["none", "debug"],
                    help="'debug': shard the sweep over a 2x2 host-device "
                         "mesh (the production placement, CPU-emulated)")
    from repro.el.scenarios.cli import add_scenario_args
    add_scenario_args(ap)
    add_metrics_args(ap)
    telemetry_arg(ap)
    args = ap.parse_args()

    from repro.el.scenarios.cli import scenario_from_args
    scenario, base_cost_model = scenario_from_args(args)
    if args.policy and scenario is None:
        # the policy switch lives on the scenario program path; an
        # identity scenario (all edges up, multipliers 1) is enough
        from repro.el.scenarios import ScenarioSpec
        scenario = ScenarioSpec()
    if args.churn_rate and (scenario is None or scenario.churn is None):
        ap.error("--churn-rate re-draws the dropout schedule per cell "
                 "and needs a base --churn RATE")
    begin_observability(args)

    spec = spec_from_sequences(
        ucb_c=args.ucb_c, budget=args.budget,
        heterogeneity=args.heterogeneity, cost_noise=args.cost_noise,
        async_alpha=args.async_alpha, async_batch_k=args.async_batch_k,
        policy=args.policy, churn_rate=args.churn_rate,
        seeds=args.seeds, max_rounds=args.max_rounds)
    mesh = None
    if args.mesh == "debug":
        # mesh shape follows the forced device count: (count//2, 2) —
        # REPRO_SWEEP_DEVICES=8 gives a (4, 2) mesh, 4 (default) a (2, 2)
        mesh = make_debug_mesh_for(jax.device_count())
    session = build_session(args, scenario, base_cost_model)
    print(f"sweep {args.arch}: {spec.describe(session.cfg)}"
          + (f" on mesh {tuple(mesh.shape.items())}" if mesh else ""),
          flush=True)

    report = session.sweep(spec, mesh=mesh, telemetry=args.telemetry)

    scn_cols = bool(args.policy or args.churn_rate)
    print(f"\n{'ucb_c':>6s} {'budget':>8s} {'H':>5s} {'noise':>6s} "
          f"{'alpha':>6s} {'seed':>5s} "
          + (f"{'policy':>12s} {'churn':>6s} " if scn_cols else "")
          + f"{'rounds':>6s} {'metric':>8s} {'consumed':>9s}")
    for row in report.to_rows():
        print(f"{row['ucb_c']:6.2f} {row['budget']:8.0f} "
              f"{row['heterogeneity']:5.1f} {row['cost_noise']:6.2f} "
              f"{row['async_alpha']:6.2f} {row['seed']:5.0f} "
              + (f"{row['policy']:>12s} {row['churn_rate']:6.2f} "
                 if scn_cols else "")
              + f"{row['n_rounds']:6d} {row['final_metric']:8.4f} "
              f"{row['total_consumed']:9.0f}")

    trunc = report.truncated()
    if trunc.any():
        print(f"\nWARNING: {int(trunc.sum())}/{report.n_cells} cells hit "
              f"the max-rounds cap ({spec.max_rounds}) before budget "
              "exhaustion — metrics are mid-run; raise --max-rounds for "
              "full runs")

    print("\nPareto frontier (consumed ↑ ⇒ metric ↑, seed-means):")
    for p in report.pareto_frontier():
        print(f"  ucb_c={p['ucb_c']:.2f} budget={p['budget']:.0f} "
              f"H={p['heterogeneity']:.1f}: metric={p['final_metric']:.4f} "
              f"@ consumed={p['total_consumed']:.0f}")
    print("\n" + report.summary())
    cache = session.compile_cache.stats()
    print(f"compile cache: {cache['entries']} programs "
          f"({cache['hits']} hits, {cache['misses']} misses, "
          f"{cache['evictions']} evictions)", flush=True)

    registry = None
    if args.metrics_out:
        from repro.obs.metrics import MetricsRegistry
        registry = MetricsRegistry()
        registry.gauge("sweep_cells", "grid cells in the compiled sweep"
                       ).set(report.n_cells)
        registry.gauge("sweep_truncated_cells",
                       "cells that hit the max-rounds cap"
                       ).set(int(trunc.sum()))
        hist = registry.histogram(
            "sweep_final_metric", "per-cell final metric",
            buckets=(0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0))
        hist.observe_many([row["final_metric"]
                           for row in report.to_rows()])
    finish_observability(args, registry)


if __name__ == "__main__":
    main()
