"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) combo.

``input_specs`` never allocates device memory — everything is a
ShapeDtypeStruct (weak-type-correct, shardable), the pattern required for
the multi-pod dry-run.  ``decode`` shapes describe ONE new token against a
KV/SSM cache of ``seq_len``; ``train``/``prefill`` describe full sequences.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import (ExperimentConfig, InputShape, INPUT_SHAPES,
                          ModelConfig)

SDS = jax.ShapeDtypeStruct


# Sliding window applied to full-attention archs for the long-context shape
# (DESIGN.md carve-out: long_500k needs sub-quadratic attention).
LONG_CONTEXT_WINDOW = 8192


def adapt_model_for_shape(model_cfg: ModelConfig,
                          shape: InputShape) -> ModelConfig:
    """Per-shape config adjustments (documented in DESIGN.md):

    * ``long_500k`` on attention architectures enables sliding-window
      attention (window 8192).  Pure/hybrid SSM archs run natively: mamba2
      has no attention; jamba keeps its 9 full-attention layers (KV fits
      once sharded).
    """
    if shape.name == "long_500k" and model_cfg.family not in ("ssm", "hybrid"):
        return dataclasses.replace(model_cfg,
                                   sliding_window=LONG_CONTEXT_WINDOW)
    return model_cfg


def batch_struct(model_cfg: ModelConfig, batch: int, seq_len: int
                 ) -> Dict[str, Any]:
    """Training/prefill batch stand-in for one global step."""
    out: Dict[str, Any] = {}
    if model_cfg.n_codebooks > 1:
        out["tokens"] = SDS((batch, model_cfg.n_codebooks, seq_len),
                            jnp.int32)
    else:
        out["tokens"] = SDS((batch, seq_len), jnp.int32)
    if model_cfg.num_prefix_embeddings:
        out["prefix_emb"] = SDS(
            (batch, model_cfg.num_prefix_embeddings, model_cfg.d_model),
            jnp.dtype(model_cfg.dtype))
    return out


def decode_token_struct(model_cfg: ModelConfig, batch: int) -> Any:
    if model_cfg.n_codebooks > 1:
        return SDS((batch, model_cfg.n_codebooks, 1), jnp.int32)
    return SDS((batch, 1), jnp.int32)


def input_specs(model_cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """The model-input stand-ins for one assigned input shape."""
    shape = INPUT_SHAPES[shape_name]
    model_cfg = adapt_model_for_shape(model_cfg, shape)
    if shape.kind in ("train", "prefill"):
        return batch_struct(model_cfg, shape.global_batch, shape.seq_len)
    return {"tokens": decode_token_struct(model_cfg, shape.global_batch)}


def el_round_batch_struct(model_cfg: ModelConfig, n_edges: int, h_max: int,
                          batch: int, seq_len: int) -> Dict[str, Any]:
    """Batch stand-in for one OL4EL round: per-edge, per-local-step."""
    per_edge = batch // n_edges
    if model_cfg.n_codebooks > 1:
        tokens = SDS((n_edges, h_max, per_edge, model_cfg.n_codebooks,
                      seq_len), jnp.int32)
    else:
        tokens = SDS((n_edges, h_max, per_edge, seq_len), jnp.int32)
    out: Dict[str, Any] = {"tokens": tokens}
    if model_cfg.num_prefix_embeddings:
        out["prefix_emb"] = SDS(
            (n_edges, h_max, per_edge, model_cfg.num_prefix_embeddings,
             model_cfg.d_model), jnp.dtype(model_cfg.dtype))
    return out
