"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; callers control when devices are materialized.

Target hardware (roofline constants in benchmarks/roofline.py):
  TPU v5e, 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
  Single pod: 16x16 = 256 chips, axes (data, model).
  Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    import os
    if os.environ.get("REPRO_DEBUG_MESH"):        # tiny-mesh CI/debug mode
        d = int(os.environ["REPRO_DEBUG_MESH"])
        shape = (2, d, d) if multi_pod else (d, d)
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (subprocesses set
    ``--xla_force_host_platform_device_count`` accordingly)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_debug_mesh_for(n_devices: int):
    """The debug mesh over a forced host-device fleet: shape
    ``(n_devices//2, 2)``, so 4 devices give a 2x2 (data, model) mesh
    and 8 a 4-wide ``data`` axis — the one sizing rule every launcher
    (``repro.launch.train``/``sweep``, ``scripts/bench_el.py``) shares."""
    d = max(n_devices // 2, 1)
    return make_debug_mesh(d, n_devices // d)
