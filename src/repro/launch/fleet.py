"""Multi-tenant EL-as-a-service launcher.

    PYTHONPATH=src python -m repro.launch.fleet --demo
    PYTHONPATH=src python -m repro.launch.fleet --manifest tenants.yaml \
        --mesh debug --slots 4

Feeds a manifest of tenant runs (JSON or YAML; see ``--demo`` for the
shape) into a :class:`repro.el.fleet.FleetServer`: tenants bucket into
cohorts by structural config — one compiled slot-batch program per
cohort — and are served in slot waves with mid-flight refill, their
reports streamed as they complete.

``--mesh debug`` shards every cohort's slot dim over a host-device mesh
(the production placement, CPU-emulated); ``REPRO_SWEEP_DEVICES`` sets
the forced device count (default 4 → a 2x2 mesh).  ``--assert-compiles``
exits non-zero unless the server compiled exactly that many cohort
programs — the CI smoke uses it to pin "one compile per cohort".
"""

from __future__ import annotations

from repro.launch.hostdev import force_host_devices

force_host_devices()     # must precede the jax import (emulated fleet)

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, List

import jax

from repro.config import CLASSIC_IDS
from repro.el.fleet import FleetServer, ReportReady, RoundDelta, TenantRun
from repro.launch.classic import classic_fixture
from repro.launch.mesh import make_debug_mesh_for
from repro.obs.cli import (add_metrics_args, begin_observability,
                           finish_observability, telemetry_arg)

#: the --demo manifest: 8 tenants across TWO structural cohorts — a sync
#: SVM cohort and an async K-means cohort (the async budgets all pad to
#: one event horizon, so they share a program).  Doubles as the CI fleet
#: smoke workload.
DEMO_MANIFEST: Dict[str, Any] = {
    "tenants": [
        {"arch": "svm-wafer", "mode": "sync", "budget": 900.0,
         "ucb_c": 1.0, "seed": 0},
        {"arch": "svm-wafer", "mode": "sync", "budget": 1500.0,
         "ucb_c": 0.5, "seed": 1, "priority": 2},
        {"arch": "svm-wafer", "mode": "sync", "budget": 600.0,
         "ucb_c": 2.0, "seed": 2},
        {"arch": "svm-wafer", "mode": "sync", "budget": 1200.0,
         "ucb_c": 1.0, "seed": 3},
        {"arch": "kmeans-traffic", "mode": "async", "budget": 700.0,
         "ucb_c": 1.0, "seed": 4},
        {"arch": "kmeans-traffic", "mode": "async", "budget": 800.0,
         "ucb_c": 0.7, "seed": 5, "priority": 1},
        {"arch": "kmeans-traffic", "mode": "async", "budget": 850.0,
         "ucb_c": 1.5, "seed": 6},
        {"arch": "kmeans-traffic", "mode": "async", "budget": 900.0,
         "ucb_c": 1.0, "seed": 7},
    ],
}


def load_manifest(path: str) -> Dict[str, Any]:
    with open(path) as f:
        text = f.read()
    if path.endswith((".yaml", ".yml")):
        import yaml
        return yaml.safe_load(text)
    return json.loads(text)


def tenant_runs(manifest: Dict[str, Any], args) -> List[TenantRun]:
    """Materialize the manifest: one data-plane fixture per (arch,
    dataset) — tenants of a cohort must SHARE an executor, that is what
    buckets them onto one compiled program."""
    fixtures: Dict[tuple, Dict[str, Any]] = {}
    runs: List[TenantRun] = []
    for t in manifest["tenants"]:
        arch = t["arch"]
        if arch not in CLASSIC_IDS:
            raise SystemExit(f"unknown arch {arch!r} (choices: "
                             f"{sorted(CLASSIC_IDS)})")
        fkey = (arch, t.get("samples", args.samples),
                t.get("edges", args.edges), t.get("alpha", args.alpha),
                t.get("data_seed", args.data_seed))
        fx = fixtures.get(fkey)
        if fx is None:
            fx = fixtures[fkey] = classic_fixture(
                arch, samples=fkey[1], n_edges=fkey[2], alpha=fkey[3],
                data_seed=fkey[4])
        mode = t.get("mode", "sync")
        ol = dataclasses.replace(
            fx["exp"].ol4el, mode=mode, policy="ol4el",
            n_edges=fkey[2], utility=fx["utility"],
            budget=float(t.get("budget", fx["exp"].ol4el.budget)),
            ucb_c=float(t.get("ucb_c", fx["exp"].ol4el.ucb_c)),
            async_batch_k=int(t.get("async_batch_k",
                                    args.async_batch_k)),
            seed=int(t.get("seed", 0)))
        runs.append(TenantRun(
            cfg=ol, executor=fx["executor"],
            tenant_id=t.get("tenant_id"),
            priority=int(t.get("priority", 0)),
            metric_name=fx["metric"],
            n_samples=fx["n_samples"] if mode == "sync" else None,
            init_params=fx["init_params"]))
    return runs


def main() -> None:
    ap = argparse.ArgumentParser(
        description="serve a manifest of EL tenants as slot-batched "
                    "cohorts")
    ap.add_argument("--manifest", default=None,
                    help="JSON/YAML tenant manifest (see --demo)")
    ap.add_argument("--demo", action="store_true",
                    help="run the built-in 8-tenant / 2-cohort demo "
                         "manifest")
    ap.add_argument("--slots", type=int, default=4,
                    help="cohort batch width (tenants beyond it queue)")
    ap.add_argument("--rounds-per-wave", type=int, default=8,
                    help="device iterations between host harvest points")
    ap.add_argument("--samples", type=int, default=512,
                    help="default dataset size per arch fixture")
    ap.add_argument("--edges", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=100.0)
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--mesh", default="none", choices=["none", "debug"],
                    help="'debug': shard every cohort's slot dim over a "
                         "host-device mesh")
    ap.add_argument("--async-batch-k", type=int, default=0,
                    help="default async K-event wave width for tenants "
                         "that don't set async_batch_k themselves "
                         "(cfg.async_batch_k; 0 = auto)")
    ap.add_argument("--assert-compiles", type=int, default=None,
                    metavar="N",
                    help="exit non-zero unless exactly N cohort programs "
                         "were compiled (CI: one per cohort)")
    ap.add_argument("--verbose", action="store_true",
                    help="print every streamed round delta")
    add_metrics_args(ap)
    telemetry_arg(ap)
    args = ap.parse_args()

    if args.demo == (args.manifest is not None):
        ap.error("pass exactly one of --demo / --manifest")
    manifest = DEMO_MANIFEST if args.demo else load_manifest(args.manifest)

    begin_observability(args)
    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh_for(jax.device_count())
    server = FleetServer(n_slots=args.slots,
                         rounds_per_wave=args.rounds_per_wave, mesh=mesh,
                         telemetry=args.telemetry)

    def on_event(ev):
        if isinstance(ev, RoundDelta) and args.verbose:
            r = ev.record
            print(f"  [{ev.tenant_id}] agg {r.n_aggregations}: "
                  f"consumed={r.total_consumed:.0f} "
                  f"utility={r.utility:.4f}", flush=True)
        elif isinstance(ev, ReportReady):
            print(f"done {ev.tenant_id}: {ev.report.summary()}",
                  flush=True)

    server.subscribe(on_event)
    runs = tenant_runs(manifest, args)
    t0 = time.perf_counter()
    ids = [server.submit(run) for run in runs]
    print(f"fleet: {len(ids)} tenants, slots={args.slots}, "
          f"wave={args.rounds_per_wave}"
          + (f", mesh {tuple(mesh.shape.items())}" if mesh else ""),
          flush=True)
    reports = server.drain()
    elapsed = time.perf_counter() - t0

    st = server.stats()
    print(f"\n{'tenant':>12s} {'mode':>6s} {'rounds':>6s} "
          f"{'consumed':>9s} {'metric':>8s}  reason")
    for tid in ids:
        r = reports[tid]
        print(f"{tid:>12s} {r.mode:>6s} {r.n_aggregations:6d} "
              f"{r.total_consumed:9.0f} {r.final_metric:8.4f}  "
              f"{r.terminated_reason}")
    print(f"\n{len(reports)}/{len(ids)} reports in {elapsed:.2f}s — "
          f"{st['cohorts']} cohorts, {st['compiles']} compiles "
          f"({st['cache_hits']} cache hits, {st['cache_misses']} misses, "
          f"{st['cache_evictions']} evictions), {st['waves']} waves, "
          f"{st['place_dispatches']} place / {st['gather_dispatches']} "
          f"gather dispatches")

    # wave batching invariant: admits scatter as ONE place_many per
    # admitting wave and finalizes gather as ONE take_many per
    # finalizing wave — never per tenant.  A per-tenant regression shows
    # up as dispatch counts above the wave count.
    if reports and not (1 <= st["place_dispatches"] <= st["waves"]
                        and 1 <= st["gather_dispatches"] <= st["waves"]):
        print(f"ERROR: per-wave dispatch invariant broken — "
              f"{st['place_dispatches']} place / "
              f"{st['gather_dispatches']} gather dispatches over "
              f"{st['waves']} waves", file=sys.stderr)
        raise SystemExit(1)

    registry = None
    if args.metrics_out:
        from repro.obs import registry_from_fleet
        registry = registry_from_fleet(st)
    finish_observability(args, registry)

    if len(reports) != len(ids):
        print("ERROR: missing tenant reports", file=sys.stderr)
        raise SystemExit(1)
    if (args.assert_compiles is not None
            and st["compiles"] != args.assert_compiles):
        print(f"ERROR: expected {args.assert_compiles} cohort compiles, "
              f"got {st['compiles']} (cohorts={st['cohorts']})",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
