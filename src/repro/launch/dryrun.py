import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
    os.environ.get("REPRO_DRYRUN_DEVICES", "512")

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST stay the first statements of this module (before
any jax import): jax locks the device count on first backend init, and the
production meshes need 512 host placeholder devices.  Do not replicate
this env var anywhere global (conftest/pyproject) — smoke tests and
benches must see 1 device.

Per combo this driver:
  1. builds the model from the arch config (with per-shape adaptations),
  2. resolves parameter / batch / cache PartitionSpecs from the per-arch
     sharding resolver,
  3. ``jax.jit(step, in_shardings=...).lower(**ShapeDtypeStructs)``,
  4. ``.compile()`` — success proves the distribution config is coherent,
  5. records ``memory_analysis()``, ``cost_analysis()`` and the collective
     bytes parsed from the optimized HLO into a JSONL row that
     ``benchmarks/roofline.py`` consumes.

Step per shape kind:
  train    -> ``train_step``  (AdamW, FSDP param layout)   [baseline]
              or ``el_round`` (--step el_round): the paper's OL4EL round
  prefill  -> ``prefill_step`` (forward, full sequence)
  decode   -> ``decode_step``  (ONE token vs a seq_len KV/SSM cache)
"""

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (ARCH_IDS, INPUT_SHAPES, TrainConfig, get_config)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (adapt_model_for_shape, el_round_batch_struct,
                                input_specs)
from repro.models import build_model
from repro.sharding import (batch_spec, cache_specs, edge_axes, param_specs,
                            to_shardings)
from repro.train.optimizer import init_opt_state
from repro.train.state import (TrainState, make_prefill_step,
                               make_train_step)

# the HLO collective parser and the memory/cost readers live in
# repro.obs.prof now (nothing observability-side may import THIS module
# — the XLA_FLAGS mutation above locks the device count); re-exported
# here for back-compat with existing imports.
from repro.obs.prof import (COLLECTIVES, _DTYPE_BYTES,  # noqa: F401
                            _SHAPE_RE, _type_bytes, parse_collectives)
from repro.obs.prof import cost_dict as _cost_dict
from repro.obs.prof import memory_dict as _mem_dict


# ---------------------------------------------------------------------------
# Lowering per combo
# ---------------------------------------------------------------------------


def _dryrun_train_cfg(shape, opt_state_dtype: str = "float32"
                      ) -> TrainConfig:
    return TrainConfig(optimizer="adamw", global_batch=shape.global_batch,
                       seq_len=shape.seq_len, total_steps=1000,
                       opt_state_dtype=opt_state_dtype)


def lower_combo(arch: str, shape_name: str, multi_pod: bool,
                step_mode: str = "auto", h_max: int = 4,
                window_slice: bool = False,
                fused_xent: bool = False,
                no_remat: bool = False,
                moe_sort_dispatch: bool = False,
                prefill_last_only: bool = False,
                ring_cache: bool = False,
                moe_groups: int = 0,
                opt_state_dtype: str = "float32",
                extra_tag: str = "",
                depth_groups: Optional[int] = None) -> Dict[str, Any]:
    """Lower + compile one combo.

    ``depth_groups``: calibration mode — lower a depth-reduced UNROLLED
    variant (prefix + depth_groups * group layers, scan_layers=False).
    XLA's HloCostAnalysis counts while-loop (lax.scan) bodies exactly once,
    so scanned-layer lowerings under-report flops/bytes/collectives by
    ~n_groups x.  Two calibration points (1 and 2 groups) give exact
    per-group deltas; benchmarks/roofline.py extrapolates
    ``total = c1 + (n_groups - 1) * (c2 - c1)``.
    """
    t0 = time.time()
    shape = INPUT_SHAPES[shape_name]
    exp = get_config(arch)
    model_cfg = adapt_model_for_shape(exp.model, shape)
    n_groups_full = None
    if depth_groups is not None:
        from repro.models.transformer import layer_groups
        pre, grp, n_groups_full = layer_groups(model_cfg)
        model_cfg = dataclasses.replace(
            model_cfg,
            n_layers=len(pre) + depth_groups * max(len(grp), 1),
            scan_layers=False)
        extra_tag = ((extra_tag + "|") if extra_tag else "") \
            + f"calib{depth_groups}"
    if no_remat:
        model_cfg = dataclasses.replace(model_cfg, remat=False)
    if moe_sort_dispatch and model_cfg.moe.enabled:
        model_cfg = dataclasses.replace(
            model_cfg,
            moe=dataclasses.replace(model_cfg.moe, dispatch="sort"))
    if moe_groups and model_cfg.moe.enabled:
        model_cfg = dataclasses.replace(
            model_cfg,
            moe=dataclasses.replace(model_cfg.moe,
                                    dispatch_groups=moe_groups))
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    logits_spec = None
    if fused_xent and shape.kind == "train":
        logits_spec = P(edge_axes(mesh), None, "model")
    model = build_model(model_cfg, window_slice=window_slice,
                        fused_xent=fused_xent, logits_spec=logits_spec,
                        ring_cache=ring_cache)
    rng = jax.random.key(0)
    params_shape = jax.eval_shape(model.init, rng)

    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "step": step_mode,
        "tag": extra_tag,
        "params": int(model_cfg.num_params()),
        "active_params": int(model_cfg.num_active_params()),
        "sliding_window": model_cfg.sliding_window,
    }
    if depth_groups is not None:
        record["depth_groups"] = depth_groups
        record["n_groups_full"] = n_groups_full
        record["n_layers_reduced"] = model_cfg.n_layers

    if shape.kind == "train" and step_mode in ("auto", "train_step"):
        record["step"] = "train_step"
        tc = _dryrun_train_cfg(shape, opt_state_dtype)
        p_specs = param_specs(model_cfg, mesh, params_shape, fsdp=True)
        opt_shape = jax.eval_shape(
            lambda p: init_opt_state(tc, p), params_shape)
        state_shape = TrainState(params_shape, opt_shape)
        mu_specs, nu_specs = p_specs, p_specs
        if (jax.tree_util.tree_structure(opt_shape.nu)
                != jax.tree_util.tree_structure(params_shape)):
            nu_specs = jax.tree.map(lambda x: P(), opt_shape.nu)
        state_specs = TrainState(
            p_specs, type(opt_shape)(step=P(), mu=mu_specs, nu=nu_specs))
        batch_shape = input_specs(model_cfg, shape_name)
        b_specs = jax.tree.map(
            lambda x: P(edge_axes(mesh), *([None] * (len(x.shape) - 1))),
            batch_shape)
        step_fn = make_train_step(model, tc)
        fn = jax.jit(step_fn,
                     in_shardings=(to_shardings(mesh, state_specs),
                                   to_shardings(mesh, b_specs)))
        with mesh:
            lowered = fn.lower(state_shape, batch_shape)
    elif shape.kind == "train" and step_mode == "el_round":
        record["step"] = "el_round"
        from repro.federated.local_sgd import (el_state_specs, init_el_state,
                                               make_el_round)
        tc = _dryrun_train_cfg(shape)
        n_edges = 1
        for ax, s in zip(mesh.axis_names, mesh.devices.shape):
            if ax in ("pod", "data"):
                n_edges *= s
        record["n_edges"] = n_edges
        record["h_max"] = h_max
        el_shape = jax.eval_shape(
            lambda r: init_el_state(model, tc, n_edges, r), rng)
        el_specs = el_state_specs(model_cfg, mesh, el_shape)
        batch_shape = el_round_batch_struct(
            model_cfg, n_edges, h_max, shape.global_batch, shape.seq_len)
        ea = edge_axes(mesh)
        b_specs = jax.tree.map(
            lambda x: P(ea, *([None] * (len(x.shape) - 1))), batch_shape)
        ivec = jax.ShapeDtypeStruct((n_edges,), jnp.int32)
        wvec = jax.ShapeDtypeStruct((n_edges,), jnp.float32)
        el_round = make_el_round(model, tc, h_max=h_max)
        fn = jax.jit(el_round, in_shardings=(
            to_shardings(mesh, el_specs), to_shardings(mesh, b_specs),
            NamedSharding(mesh, P(ea)), NamedSharding(mesh, P(ea))))
        with mesh:
            lowered = fn.lower(el_shape, batch_shape, ivec, wvec)
    elif shape.kind == "prefill":
        record["step"] = "prefill_step"
        p_specs = param_specs(model_cfg, mesh, params_shape, fsdp=False)
        batch_shape = input_specs(model_cfg, shape_name)
        b_specs = jax.tree.map(
            lambda x: P(edge_axes(mesh), *([None] * (len(x.shape) - 1))),
            batch_shape)
        fn = jax.jit(make_prefill_step(model, last_only=prefill_last_only),
                     in_shardings=(to_shardings(mesh, p_specs),
                                   to_shardings(mesh, b_specs)))
        with mesh:
            lowered = fn.lower(params_shape, batch_shape)
    else:  # decode
        record["step"] = "decode_step"
        p_specs = param_specs(model_cfg, mesh, params_shape, fsdp=False)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        c_specs = cache_specs(model_cfg, mesh, cache_shape,
                              shape.global_batch)
        tok_shape = input_specs(model_cfg, shape_name)["tokens"]
        ea = edge_axes(mesh)
        n_edge = 1
        for ax, s in zip(mesh.axis_names, mesh.devices.shape):
            if ax in ("pod", "data"):
                n_edge *= s
        tok_spec = (P(ea, *([None] * (len(tok_shape.shape) - 1)))
                    if tok_shape.shape[0] % n_edge == 0 else
                    P(*([None] * len(tok_shape.shape))))

        def decode_fn(params, tokens, cache):
            return model.decode_step(params, tokens, cache)

        fn = jax.jit(decode_fn, in_shardings=(
            to_shardings(mesh, p_specs),
            NamedSharding(mesh, tok_spec),
            to_shardings(mesh, c_specs)))
        with mesh:
            lowered = fn.lower(params_shape, tok_shape, cache_shape)

    record["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 2)
    record["memory"] = _mem_dict(compiled)
    record["cost"] = _cost_dict(compiled)
    try:
        hlo = compiled.as_text()
        record["collectives"] = parse_collectives(hlo)
        record["hlo_lines"] = hlo.count("\n")
    except Exception as e:                                  # pragma: no cover
        record["collectives"] = {"error": str(e)}
    record["ok"] = True
    return record


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--step", default="auto",
                    choices=["auto", "train_step", "el_round"])
    ap.add_argument("--h-max", type=int, default=4)
    ap.add_argument("--window-slice", action="store_true",
                    help="enable KV-slice optimization for sliding-window")
    ap.add_argument("--fused-xent", action="store_true",
                    help="sharded cross-entropy (no logits all-gather)")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation checkpointing")
    ap.add_argument("--moe-sort-dispatch", action="store_true",
                    help="sort-based MoE position-in-expert (O(Tk) mem)")
    ap.add_argument("--prefill-last-only", action="store_true",
                    help="serving prefill: emit only last-position logits")
    ap.add_argument("--ring-cache", action="store_true",
                    help="rolling window-length KV cache for decode")
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="group-local MoE dispatch (set to n data shards)")
    ap.add_argument("--opt-state-dtype", default="float32",
                    help="Adam moment dtype (bf16 halves optimizer memory)")
    ap.add_argument("--calibrate", action="store_true",
                    help="run the 2-point depth calibration (unrolled "
                         "prefix+G and prefix+2G) for scan-aware roofline "
                         "flop/byte/collective extrapolation")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    done = set()
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r["step"],
                              r.get("tag", "")))
                except Exception:
                    pass

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    failures = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape_name in shapes:
                for mp in meshes:
                    mesh_name = "2x16x16" if mp else "16x16"
                    step = args.step
                    key_step = ("train_step" if step in ("auto",)
                                and INPUT_SHAPES[shape_name].kind == "train"
                                else step)
                    if (not args.calibrate
                            and (arch, shape_name, mesh_name, key_step,
                                 args.tag) in done):
                        continue
                    if (step == "el_round"
                            and INPUT_SHAPES[shape_name].kind != "train"):
                        continue
                    depths = [1, 2] if args.calibrate else [None]
                    for dg in depths:
                        tag = args.tag
                        if dg:
                            tag = ((tag + "|") if tag else "") + f"calib{dg}"
                        if dg and (arch, shape_name, mesh_name, key_step,
                                   tag) in done:
                            continue
                        try:
                            rec = lower_combo(
                                arch, shape_name, mp, step,
                                h_max=args.h_max,
                                window_slice=args.window_slice,
                                fused_xent=args.fused_xent,
                                no_remat=args.no_remat,
                                moe_sort_dispatch=args.moe_sort_dispatch,
                                prefill_last_only=args.prefill_last_only,
                                ring_cache=args.ring_cache,
                                moe_groups=args.moe_groups,
                                opt_state_dtype=args.opt_state_dtype,
                                extra_tag=args.tag, depth_groups=dg)
                        except Exception as e:
                            rec = {"arch": arch, "shape": shape_name,
                                   "mesh": mesh_name, "step": step,
                                   "tag": tag, "ok": False,
                                   "error": f"{type(e).__name__}: {e}"}
                            failures += 1
                        f.write(json.dumps(rec) + "\n")
                        f.flush()
                        status = "OK" if rec.get("ok") else "FAIL"
                        mem = rec.get("memory", {}).get(
                            "argument_size_in_bytes", 0)
                        print(f"[{status}] {arch} {shape_name} {mesh_name} "
                              f"{rec.get('step')} tag={rec.get('tag', '')} "
                              f"args={mem/2**30:.2f}GiB "
                              f"compile={rec.get('compile_s', '-')}s",
                              flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
