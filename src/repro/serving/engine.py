"""Batched serving engine: continuous batching over a fixed-slot cache.

Production-shaped serving loop for the model zoo:
  * a fixed number of batch *slots*, each owning a segment of the KV/SSM
    cache (ring-cache aware for sliding-window archs);
  * waiting requests are admitted in waves into free slots (left-padded
    to a common length), prefilled as one batch, then decoded in
    lock-step; finished slots free early (EOS / max tokens) while the
    rest keep decoding;
  * greedy or temperature sampling, max-token / EOS termination.

The engine is deliberately host-driven (admission control is control
plane); the only jitted device functions are the model's ``prefill`` and
``decode_step``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig

Params = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] or [CB, S] token ids
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params: Params, n_slots: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.rng = jax.random.key(seed)
        # one shared cache with a batch dim == n_slots; slots stay
        # position-aligned by LEFT-padding prompts at admission time
        self.cache = model.init_cache(n_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.waiting: List[Request] = []
        self._decode = jax.jit(model.decode_step)
        self._last_tok = None
        self._cur_len = 0          # shared position of every live slot

    # -- queue API -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.active > 0

    # -- internals ------------------------------------------------------------

    def _slot_temperatures(self) -> np.ndarray:
        """Each slot samples with its own request's temperature (empty
        slots decode greedily — their tokens are discarded anyway)."""
        return np.array([r.temperature if r is not None else 0.0
                         for r in self.slot_req], np.float32)

    def _sample(self, logits, temperatures: np.ndarray) -> jax.Array:
        lg = logits[..., -1, :]                    # [B, V] or [B, CB, V]
        greedy = jnp.argmax(lg, axis=-1)
        t = jnp.asarray(temperatures).reshape(
            (-1,) + (1,) * (lg.ndim - 2))          # broadcast over CB dims
        if not np.any(temperatures > 0):
            return greedy
        self.rng, sub = jax.random.split(self.rng)
        sampled = jax.random.categorical(
            sub, lg / jnp.maximum(t, 1e-6)[..., None], axis=-1)
        return jnp.where(t <= 0, greedy, sampled)

    # -- main loop -------------------------------------------------------------

    @staticmethod
    def _prompt_len(req: Request) -> int:
        p = np.asarray(req.prompt)
        return int(p.shape[-1])

    def _pad_prompt(self, req: Request, to_len: int) -> np.ndarray:
        p = np.asarray(req.prompt)
        pad = to_len - p.shape[-1]
        if p.ndim == 1:
            return np.pad(p, (pad, 0))
        return np.pad(p, ((0, 0), (pad, 0)))

    def _admit_free_slots(self, completed: List[Request]) -> None:
        """Mid-flight admission: fill free slots from the queue without
        resetting the wave.  A queued prompt joins only if it fits the
        slots' shared position (left-padded to ``_cur_len``); it is
        prefilled on a scratch cache and only the admitted slots' cache
        rows are scattered into the live cache, so occupied slots'
        state is untouched.  Longer prompts stay queued until the batch
        drains and a fresh wave restarts at their length."""
        free = [s for s, r in enumerate(self.slot_req) if r is None]
        admitted: List[int] = []
        keep: List[Request] = []
        for req in self.waiting:
            if free and self._prompt_len(req) <= self._cur_len:
                slot = free.pop(0)
                self.slot_req[slot] = req
                admitted.append(slot)
            else:
                keep.append(req)
        self.waiting = keep
        if not admitted:
            return
        shape = np.asarray(self.slot_req[admitted[0]].prompt).shape
        batch = np.zeros((self.n_slots,) + shape[:-1] + (self._cur_len,),
                         np.int32)
        for slot in admitted:
            batch[slot] = self._pad_prompt(self.slot_req[slot],
                                           self._cur_len)
        scratch = self.model.init_cache(self.n_slots, self.max_len)
        logits, scratch = self.model.prefill(
            self.params, jnp.asarray(batch), scratch)
        rows = np.asarray(admitted)
        # scan-stacked "groups" caches carry a leading [n_groups] dim
        # (their batch axis is 1); everything else is batch-leading.
        # Scalar leaves (the shared position index) are equal by
        # construction — live and scratch both sit at _cur_len.
        groups_stacked = not isinstance(self.cache.get("groups"), list)

        def scatter(path, live, new):
            if getattr(live, "ndim", 0) == 0:
                return live
            axis = 1 if (groups_stacked and path
                         and getattr(path[0], "key", None) == "groups"
                         and live.ndim >= 2) else 0
            if live.shape[axis] != self.n_slots:
                return live
            if axis == 0:
                return live.at[rows].set(new[rows])
            return live.at[:, rows].set(new[:, rows])

        self.cache = jax.tree_util.tree_map_with_path(
            scatter, self.cache, scratch)
        tok = self._sample(logits, self._slot_temperatures())
        last = jnp.asarray(self._last_tok)
        for slot in admitted:
            last = last.at[slot].set(tok[slot])
        self._last_tok = last
        flat = np.asarray(tok).reshape(self.n_slots, -1)
        for slot in admitted:
            self._append_and_check(slot, self.slot_req[slot],
                                   int(flat[slot, 0]), completed)

    def step(self) -> List[Request]:
        """Admit + decode one step. Returns requests completed this step.

        Continuous batching: all active slots share one decode cadence,
        and admission happens whenever a slot is free — a queued request
        whose prompt fits the shared position is left-padded to
        ``_cur_len``, prefilled on a scratch cache and scattered into
        its slot mid-flight, while the other slots keep decoding.  An
        empty batch restarts a fresh wave at the longest queued prompt's
        length (which is how prompts longer than the shared position
        eventually admit).
        """
        completed: List[Request] = []
        # admission: all slots empty -> start a fresh generation wave
        if self.active == 0 and self.waiting:
            wave = self.waiting[: self.n_slots]
            self.waiting = self.waiting[len(wave):]
            self.cache = self.model.init_cache(self.n_slots, self.max_len)
            max_prompt = max(self._prompt_len(r) for r in wave)
            prompts = []
            for slot, req in enumerate(wave):
                self.slot_req[slot] = req
                prompts.append(self._pad_prompt(req, max_prompt))
            batch = np.zeros((self.n_slots,) + prompts[0].shape, np.int32)
            for i, p in enumerate(prompts):
                batch[i] = p
            logits, self.cache = self.model.prefill(
                self.params, jnp.asarray(batch), self.cache)
            self._cur_len = max_prompt
            tok = self._sample(logits, self._slot_temperatures())
            self._last_tok = tok
            flat = np.asarray(tok).reshape(self.n_slots, -1)
            for slot, req in enumerate(self.slot_req):
                if req is not None:
                    self._append_and_check(slot, req, int(flat[slot, 0]),
                                           completed)
            return completed

        if self.active == 0:
            return completed

        # free-slot refill before the lock-step decode
        if self.waiting and self.active < self.n_slots:
            self._admit_free_slots(completed)
            if self.active == 0:         # everything admitted finished at
                return completed         # its first token (EOS / max=1)

        # decode step for all active slots
        tok = self._last_tok
        if self.cfg.n_codebooks > 1:
            inp = tok.reshape(self.n_slots, self.cfg.n_codebooks, 1)
        else:
            inp = tok.reshape(self.n_slots, 1)
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(inp, jnp.int32),
                                          self.cache)
        self._cur_len += 1
        tok = self._sample(logits, self._slot_temperatures())
        self._last_tok = tok
        flat = np.asarray(tok).reshape(self.n_slots, -1)
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                self._append_and_check(slot, req, int(flat[slot, 0]),
                                       completed)
        return completed

    def _append_and_check(self, slot: int, req: Request, t: int,
                          completed: List[Request]) -> None:
        req.output.append(t)
        if (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and t == req.eos_id)):
            req.done = True
            completed.append(req)
            self.slot_req[slot] = None

    def run(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.has_work():
                break
            done += self.step()
        return done
