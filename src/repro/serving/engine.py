"""Batched serving engine: continuous batching over a fixed-slot cache.

Production-shaped serving loop for the model zoo:
  * a fixed number of batch *slots*, each owning a segment of the KV/SSM
    cache (ring-cache aware for sliding-window archs);
  * waiting requests are admitted in waves into free slots (left-padded
    to a common length), prefilled as one batch, then decoded in
    lock-step; finished slots free early (EOS / max tokens) while the
    rest keep decoding;
  * greedy or temperature sampling, max-token / EOS termination.

The engine is deliberately host-driven (admission control is control
plane); the only jitted device functions are the model's ``prefill`` and
``decode_step``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig

Params = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] or [CB, S] token ids
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params: Params, n_slots: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.rng = jax.random.key(seed)
        # one shared cache with a batch dim == n_slots; slots stay
        # position-aligned by LEFT-padding prompts at admission time
        self.cache = model.init_cache(n_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.waiting: List[Request] = []
        self._decode = jax.jit(model.decode_step)

    # -- queue API -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.active > 0

    # -- internals ------------------------------------------------------------

    def _slot_temperatures(self) -> np.ndarray:
        """Each slot samples with its own request's temperature (empty
        slots decode greedily — their tokens are discarded anyway)."""
        return np.array([r.temperature if r is not None else 0.0
                         for r in self.slot_req], np.float32)

    def _sample(self, logits, temperatures: np.ndarray) -> jax.Array:
        lg = logits[..., -1, :]                    # [B, V] or [B, CB, V]
        greedy = jnp.argmax(lg, axis=-1)
        t = jnp.asarray(temperatures).reshape(
            (-1,) + (1,) * (lg.ndim - 2))          # broadcast over CB dims
        if not np.any(temperatures > 0):
            return greedy
        self.rng, sub = jax.random.split(self.rng)
        sampled = jax.random.categorical(
            sub, lg / jnp.maximum(t, 1e-6)[..., None], axis=-1)
        return jnp.where(t <= 0, greedy, sampled)

    # -- main loop -------------------------------------------------------------

    def step(self) -> List[Request]:
        """Admit + decode one step. Returns requests completed this step.

        Simplified continuous batching: all active slots share one decode
        cadence; admission happens whenever a slot is free.  To keep the
        single shared ``index`` consistent across slots, the engine admits
        only when the queue position matches — prompts are left-padded to
        the current shared length (standard same-length batching).
        """
        completed: List[Request] = []
        # admission: all slots empty -> start a fresh generation wave
        if self.active == 0 and self.waiting:
            wave = self.waiting[: self.n_slots]
            self.waiting = self.waiting[len(wave):]
            self.cache = self.model.init_cache(self.n_slots, self.max_len)
            max_prompt = max(len(r.prompt if r.prompt.ndim == 1
                                 else r.prompt[0]) for r in wave)
            prompts = []
            for slot, req in enumerate(wave):
                self.slot_req[slot] = req
                p = np.asarray(req.prompt)
                pad = max_prompt - (len(p) if p.ndim == 1 else p.shape[-1])
                if p.ndim == 1:
                    p = np.pad(p, (pad, 0))
                else:
                    p = np.pad(p, ((0, 0), (pad, 0)))
                prompts.append(p)
            batch = np.zeros((self.n_slots,) + prompts[0].shape, np.int32)
            for i, p in enumerate(prompts):
                batch[i] = p
            logits, self.cache = self.model.prefill(
                self.params, jnp.asarray(batch), self.cache)
            tok = self._sample(logits, self._slot_temperatures())
            self._last_tok = tok
            flat = np.asarray(tok).reshape(self.n_slots, -1)
            for slot, req in enumerate(self.slot_req):
                if req is not None:
                    self._append_and_check(slot, req, int(flat[slot, 0]),
                                           completed)
            return completed

        if self.active == 0:
            return completed

        # decode step for all active slots
        tok = self._last_tok
        if self.cfg.n_codebooks > 1:
            inp = tok.reshape(self.n_slots, self.cfg.n_codebooks, 1)
        else:
            inp = tok.reshape(self.n_slots, 1)
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(inp, jnp.int32),
                                          self.cache)
        tok = self._sample(logits, self._slot_temperatures())
        self._last_tok = tok
        flat = np.asarray(tok).reshape(self.n_slots, -1)
        for slot, req in enumerate(self.slot_req):
            if req is not None:
                self._append_and_check(slot, req, int(flat[slot, 0]),
                                       completed)
        return completed

    def _append_and_check(self, slot: int, req: Request, t: int,
                          completed: List[Request]) -> None:
        req.output.append(t)
        if (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and t == req.eos_id)):
            req.done = True
            completed.append(req)
            self.slot_req[slot] = None

    def run(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.has_work():
                break
            done += self.step()
        return done
