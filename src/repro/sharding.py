"""Per-architecture sharding-rule resolver.

Maps every parameter / activation / cache tensor to a PartitionSpec for the
production meshes.  Rules are *name + shape* based and divisibility-checked
against the actual mesh axis sizes, because the assigned architectures have
head counts (40, 56, 36, 24...) that do not all divide the 16-way model
axis: the resolver prefers sharding heads, falls back to head_dim, then to
replication — recorded per-arch by the dry-run.

Conventions:
  * ``model`` axis: tensor-parallel dim (heads / d_ff / experts / d_inner).
  * ``data`` (+ ``pod``) axes: the batch — and, for the batch=1 long-context
    shape, the KV-cache *sequence* dim instead (flash-decoding style).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig


#: Mesh axes the per-edge (batch/fleet) dims spread over.
_EDGE_AXIS_NAMES = ("pod", "data")


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def edge_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in _EDGE_AXIS_NAMES if a in mesh.axis_names)


def _leaf_path_keys(path) -> list:
    return [getattr(k, "key", getattr(k, "idx", None)) for k in path]


def _leaf_param_name(keys) -> str:
    """The rule-lookup name of a param-tree leaf: the last string key on
    its path, ignoring ``sub*`` wrapper levels — the one resolver every
    spec builder in this module shares."""
    return next((k for k in reversed(keys) if isinstance(k, str)
                 and not k.startswith("sub")), "")


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def _param_spec(name: str, shape: Tuple[int, ...], ms: int) -> P:
    """PartitionSpec for one parameter leaf (no stacking dim)."""
    nd = len(shape)

    def pick(*cands: Tuple[int, str]) -> P:
        """First candidate dim divisible by the model-axis size wins."""
        spec: list = [None] * nd
        for dim, axis in cands:
            if _div(shape[dim], ms):
                spec[dim] = axis
                return P(*spec)
        return P(*spec)

    if name == "embed":
        if nd == 3:                       # [CB, V, d]
            return pick((1, "model"), (2, "model"))
        return pick((0, "model"), (1, "model"))          # [V, d]
    if name == "lm_head":
        if nd == 3:                       # [CB, d, V]
            return pick((2, "model"), (1, "model"))
        return pick((1, "model"), (0, "model"))          # [d, V]
    if name in ("wq", "wk", "wv"):        # [d, H, hd]
        return pick((1, "model"), (2, "model"))
    if name == "wo" and nd == 3:          # [H, hd, d]
        return pick((0, "model"), (1, "model"))
    if name == "wo" and nd == 2:          # mlp down [f, d]
        return pick((0, "model"))
    if name in ("bq", "bk", "bv"):        # [H, hd]
        return pick((0, "model"), (1, "model"))
    if name in ("wi_gate", "wi_up", "ws_gate", "ws_up"):  # [d, f]
        return pick((1, "model"))
    if name == "ws_down":                 # [f, d]
        return pick((0, "model"))
    if name == "router":                  # [d, E]
        return pick((1, "model"))
    if name in ("we_gate", "we_up"):      # [E, d, f]
        return pick((0, "model"), (2, "model"))
    if name == "we_down":                 # [E, f, d]
        return pick((0, "model"), (1, "model"))
    if name == "in_proj":                 # [d, 2di+2n+nh]
        return pick((1, "model"))
    if name == "conv_w":                  # [K, C]
        return pick((1, "model"))
    if name in ("conv_b", "gate_norm", "A_log", "D", "dt_bias"):
        return pick((0, "model"))
    if name == "out_proj":                # [di, d]
        return pick((0, "model"))
    # norms, scalars, classic-model params: replicate
    return P(*([None] * nd))


def param_specs(cfg: ModelConfig, mesh: Mesh, params_shape: Any,
                fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (eval_shape output).

    ``fsdp=True`` additionally shards each parameter's largest still-
    unsharded dim over the edge (pod+data) axes when divisible — the
    ZeRO-3/FSDP layout used by the baseline ``train_step`` so that e.g.
    jamba-398B optimizer state spreads over all chips, not just the
    ``model`` axis.
    """
    ms = _axis_size(mesh, "model")
    ea = edge_axes(mesh)
    n_edge = _prod(_axis_size(mesh, a) for a in ea)

    def add_fsdp(spec: P, shape: Tuple[int, ...]) -> P:
        if not fsdp or len(shape) < 2 or n_edge <= 1:
            return spec
        dims = sorted(range(len(shape)), key=lambda i: -shape[i])
        out = list(spec) + [None] * (len(shape) - len(spec))
        for i in dims:
            if out[i] is None and _div(shape[i], n_edge):
                out[i] = ea
                return P(*out)
        return spec

    def leaf_spec(path, leaf) -> P:
        keys = _leaf_path_keys(path)
        name = _leaf_param_name(keys)
        # scanned models stack group params on a leading n_groups dim;
        # unrolled models keep a list of per-group dicts (no extra dim)
        stacked = ("groups" in keys) and cfg.scan_layers
        shape = leaf.shape
        if stacked:
            base = add_fsdp(_param_spec(name, shape[1:], ms), shape[1:])
            return P(None, *base)
        return add_fsdp(_param_spec(name, shape, ms), shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def batch_spec(mesh: Mesh) -> P:
    """Token batches: batch dim over the edge (pod+data) axes."""
    return P(edge_axes(mesh))


def batch_sharding(cfg: ModelConfig, mesh: Mesh, batch_shape: Any,
                   shard_batch: bool = True) -> Any:
    """Shardings for a train/prefill input batch pytree."""
    ea = edge_axes(mesh)

    def leaf(path, x) -> P:
        keys = [getattr(k, "key", None) for k in path]
        nd = len(x.shape)
        if not shard_batch or x.shape[0] % max(
                1, _prod(_axis_size(mesh, a) for a in ea)):
            return P(*([None] * nd))
        if "prefix_emb" in keys:
            return P(ea, None, None)
        return P(ea, *([None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(leaf, batch_shape)


def _prod(it) -> int:
    out = 1
    for v in it:
        out *= v
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache_shape: Any,
                batch: int) -> Any:
    """PartitionSpecs for the decode cache.

    batch >= n_edge_devices -> shard batch over edge axes; batch == 1
    (long-context) -> shard the KV *sequence* dim over the edge axes
    instead, giving flash-decoding-style partial-softmax collectives.
    """
    ms = _axis_size(mesh, "model")
    ea = edge_axes(mesh)
    n_edge = _prod(_axis_size(mesh, a) for a in ea)
    shard_batch = _div(batch, n_edge)

    def leaf_spec(path, leaf) -> P:
        keys = _leaf_path_keys(path)
        name = _leaf_param_name(keys)
        stacked = ("groups" in keys) and cfg.scan_layers
        shape = leaf.shape[1:] if stacked else leaf.shape
        nd = len(shape)
        spec: list = [None] * nd
        if name in ("k", "v"):            # [B, S, KV, hd]
            if shard_batch:
                spec[0] = ea
            elif _div(shape[1], n_edge):
                spec[1] = ea              # seq-sharded KV (batch=1)
            if _div(shape[2], ms):
                spec[2] = "model"
            elif _div(shape[3], ms):
                spec[3] = "model"
        elif name == "conv":              # [B, K-1, C]
            if shard_batch:
                spec[0] = ea
            if _div(shape[2], ms):
                spec[2] = "model"
        elif name == "ssm":               # [B, H, P, N]
            if shard_batch:
                spec[0] = ea
            if _div(shape[1], ms):
                spec[1] = "model"
            elif _div(shape[2], ms):
                spec[2] = "model"
        # "index": replicated scalar
        if stacked:
            return P(None, *spec)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


def to_shardings(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# EL data-plane placement (shared by the single-run compiled programs in
# repro.el.ingraph / repro.el.events and the sweep engine repro.el.sweep)
# ---------------------------------------------------------------------------

#: Control-plane knobs with a trailing per-edge dim ``[..., E]`` — the
#: sweep engine stacks these as ``[n_cells, E]``; a single run passes
#: them as ``[E]`` (replicated: they are bytes, and the single-run
#: control plane — bandit stats, budgets, finish times — replicates).
EL_EDGE_KNOBS = ("comp", "comm", "min_edge_cost")
#: Scalar control-plane knobs (``[n_cells]`` in a sweep, 0-d in a run).
#: ``event_cap`` is the async engine's traced int32 event budget;
#: ``scn_drift`` / ``policy_id`` are the scenario engine's drift rate
#: and policy-switch selector (``repro.el.scenarios``).
EL_SCALAR_KNOBS = ("ucb_c", "budget", "cost_noise", "async_alpha",
                   "event_cap", "scn_drift", "policy_id")
#: Scenario schedule knobs ``[period, E]`` (``[n_cells, period, E]`` in
#: a sweep) — control plane like every other knob: replicated in a
#: single run, cell-sharded only along the sweep axis.
EL_SCHEDULE_KNOBS = ("scn_active", "scn_mult")


def el_edge_dim_axes(axis_names: Sequence[str],
                     axis_sizes: Dict[str, int],
                     n_edges: int) -> Optional[Tuple[str, ...]]:
    """Mesh axes the ``[n_edges, ...]`` data-plane dim shards over.

    Pure placement policy (no devices), resolver-style like
    ``param_specs``: the edge dim goes over the (``pod``, ``data``) axes
    when it tiles them, and *replicates* otherwise (a 3-edge fleet on a
    2-wide data axis cannot split evenly — the run still works, just
    without edge parallelism).  Returns the axis tuple or ``None``.
    """
    ea = tuple(a for a in _EDGE_AXIS_NAMES if a in axis_names)
    n_shards = _prod(axis_sizes.get(a, 1) for a in ea)
    if ea and n_shards > 1 and n_edges % n_shards == 0:
        return ea
    return None


def el_run_partition_specs(axis_names: Sequence[str],
                           axis_sizes: Dict[str, int],
                           n_edges: int,
                           knob_names: Sequence[str]
                           ) -> Tuple[P, Dict[str, P]]:
    """PartitionSpecs for one EL run's (edge data, knobs).

    The per-edge datasets ``xs [E, N, d]`` / ``ys [E, N]`` shard their
    edge dim over (``pod``, ``data``) via :func:`el_edge_dim_axes`; the
    control-plane knobs all replicate — bandit statistics, budgets and
    finish times are the replicated control plane, only the data plane
    (per-edge params/data) spreads over the mesh.  Pure (no devices) so
    the placement policy is unit-testable, mirroring
    ``repro.el.sweep.sweep_partition_specs``.
    """
    ea = el_edge_dim_axes(axis_names, axis_sizes, n_edges)
    edge_spec = P(ea) if ea else P(None)
    knob_specs = {name: P() for name in knob_names}
    return edge_spec, knob_specs


def el_stacked_param_specs(mesh: Mesh, n_edges: int,
                           stacked_params: Any) -> Any:
    """PartitionSpecs for an ``[n_edges, ...]``-stacked param tree.

    The ``el_state_specs`` layout (``repro.federated.local_sgd``) for
    the in-graph programs: leading edge dim over (``pod``, ``data``)
    when it tiles, each parameter's own dims by the per-arch name+shape
    resolver (large model tensors over ``model``, classic/unknown names
    replicate).  ``stacked_params`` may hold tracers — only ``.shape``
    is read, so this works at trace time inside the compiled programs.

    Scanned-LM group stacking (``param_specs``' ``groups`` rule) is NOT
    handled here: the compiled EL programs only admit flat
    ``InGraphExecutor`` param trees today (``check_ingraph_support``);
    staging an LM executor in-graph must teach this function the extra
    ``n_groups`` dim first.
    """
    ms = _axis_size(mesh, "model")
    ea = el_edge_dim_axes(mesh.axis_names, dict(
        zip(mesh.axis_names, mesh.devices.shape)), n_edges)

    def leaf_spec(path, leaf) -> P:
        name = _leaf_param_name(_leaf_path_keys(path))
        base = _param_spec(name, leaf.shape[1:], ms)
        return P(ea, *base)

    return jax.tree_util.tree_map_with_path(leaf_spec, stacked_params)


def el_cohort_slot_axes(axis_names: Sequence[str],
                        axis_sizes: Dict[str, int],
                        n_slots: int) -> Optional[Tuple[str, ...]]:
    """Mesh axes a fleet cohort's ``[n_slots, ...]`` tenant-slot dim
    shards over: the (``pod``, ``data``) axes when the slot count tiles
    them, replication otherwise — the same tiles-or-replicates policy as
    the single-run edge dim (:func:`el_edge_dim_axes`), because a
    cohort's slot dim *is* its batch dim.  Pure (no devices)."""
    return el_edge_dim_axes(axis_names, axis_sizes, n_slots)


def el_cohort_state_specs(mesh: Mesh, n_slots: int, state: Any) -> Any:
    """PartitionSpecs for a cohort's slot-stacked carry/knob pytree:
    every leaf with a leading ``[n_slots]`` dim shards that dim over the
    cohort slot axes (inner dims replicated — classic-model tensors are
    tiny; the per-slot math is the unsharded cell's, which is what keeps
    fleet runs bit-identical to single runs), anything else replicates.
    ``state`` may hold tracers — only ``.shape`` is read."""
    ea = el_cohort_slot_axes(mesh.axis_names, dict(
        zip(mesh.axis_names, mesh.devices.shape)), n_slots)

    def leaf_spec(leaf) -> P:
        nd = len(leaf.shape)
        if ea and nd >= 1 and leaf.shape[0] == n_slots:
            return P(ea, *([None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree.map(leaf_spec, state)


def el_run_in_shardings(mesh: Mesh, model_cfg: Optional[ModelConfig],
                        params_shape: Any,
                        knob_names: Sequence[str]) -> Tuple[Any, ...]:
    """NamedShardings for the compiled EL programs' call signature
    ``(init_params, rng, knobs)``: params by the per-arch resolver
    (classic models replicate — their tensors are tiny), the rng key and
    every knob replicated (the control plane)."""
    if model_cfg is not None:
        p_sh = to_shardings(mesh, param_specs(model_cfg, mesh,
                                              params_shape))
    else:
        p_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                            params_shape)
    rep = NamedSharding(mesh, P())
    return p_sh, rep, {k: rep for k in knob_names}
