"""Bench-regression bookkeeping: history, baselines, tolerances, ledger.

The bench scripts (``scripts/bench_el.py`` / ``scripts/bench_fleet.py``)
append every run as one schema-versioned JSONL entry to
``BENCH_history.jsonl`` — commit, timestamp, meta, rows — so the perf
trajectory across PRs is a file, not archaeology.  ``scripts/
bench_check.py`` then compares a fresh run against the committed
baselines with per-metric tolerances and a *ledger* of known
regressions (``BENCH_ledger.json``): rows declared expected-slow
relative to a reference row are exempt from the gate, and when a PR
actually fixes one the gate flips to "failing better" so the stale
ledger entry gets removed instead of silently masking the win.

Metric direction matters: ``us_per_aggregation`` regressing means
going UP, ``tenants_per_sec`` regressing means going DOWN.  All
comparisons are relative (ratios), so the within-run ratio checks are
robust to host speed; absolute fresh-vs-baseline comparisons are for
same-config runs on the same class of host.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence

#: history/ledger schema version (bump on breaking row changes)
SCHEMA_VERSION = 1

#: metrics where larger is BETTER (everything else: smaller is better)
HIGHER_IS_BETTER = frozenset({"tenants_per_sec"})

#: default relative tolerances for fresh-vs-baseline comparison —
#: wall-clock on a shared CPU host is noisy, byte counts are exact
DEFAULT_TOLERANCES: Dict[str, float] = {
    "us_per_aggregation": 0.25,
    "wall_us": 0.25,
    "wall_s": 0.25,
    "tenants_per_sec": 0.25,
    "peak_live_bytes": 0.05,
}


def git_commit(cwd: Optional[str] = None) -> Optional[str]:
    """Best-effort ``git rev-parse HEAD`` (None outside a checkout)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except Exception:                                       # pragma: no cover
        return None


def history_entry(kind: str, meta: Mapping[str, Any],
                  rows: Mapping[str, Any], *,
                  commit: Optional[str] = None,
                  timestamp: Optional[float] = None) -> Dict[str, Any]:
    """One schema-versioned history record (not yet written)."""
    return {
        "schema": SCHEMA_VERSION,
        "kind": kind,
        "commit": commit if commit is not None else git_commit(),
        "timestamp": float(timestamp if timestamp is not None
                           else time.time()),
        "meta": dict(meta),
        "rows": dict(rows),
    }


def append_history(path: str, kind: str, meta: Mapping[str, Any],
                   rows: Mapping[str, Any], *,
                   commit: Optional[str] = None) -> Dict[str, Any]:
    """Append one bench run to the JSONL history; returns the entry."""
    entry = history_entry(kind, meta, rows, commit=commit)
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def load_history(path: str, kind: Optional[str] = None
                 ) -> List[Dict[str, Any]]:
    """All (optionally kind-filtered) history entries, oldest first.
    Unknown schemas load anyway — readers filter on ``schema``."""
    out: List[Dict[str, Any]] = []
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            entry = json.loads(line)
            if kind is None or entry.get("kind") == kind:
                out.append(entry)
    return out


# ---------------------------------------------------------------------------
# Ledger of known regressions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    """One known, accepted regression: ``row`` is expected slower than
    ``reference`` on ``metric`` by up to ``max_ratio``; when a fix
    brings the ratio under ``fixed_below_ratio`` the gate flips to
    "failing better" — remove the entry and commit the win."""

    bench: str                    # "el" | "fleet"
    row: str
    metric: str
    reference: str
    max_ratio: float
    fixed_below_ratio: float = 1.5
    reason: str = ""

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def load_ledger(path: str) -> List[LedgerEntry]:
    """Parse ``BENCH_ledger.json`` (missing file = empty ledger)."""
    if not os.path.exists(path):
        return []
    with open(path) as f:
        doc = json.load(f)
    fields = {f.name for f in dataclasses.fields(LedgerEntry)}
    return [LedgerEntry(**{k: v for k, v in e.items() if k in fields})
            for e in doc.get("known", [])]


def ledgered(entries: Sequence[LedgerEntry], bench: str, row: str,
             metric: str) -> Optional[LedgerEntry]:
    for e in entries:
        if e.bench == bench and e.row == row and e.metric == metric:
            return e
    return None


# ---------------------------------------------------------------------------
# Comparisons
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Finding:
    """One gate finding; ``kind`` is ``regression`` (fail),
    ``fixed`` (failing-better: stale ledger entry), ``known``
    (ledgered, within bounds) or ``ok``."""

    kind: str
    bench: str
    row: str
    metric: str
    detail: str

    def __str__(self) -> str:
        return (f"[{self.kind}] {self.bench}:{self.row}.{self.metric} "
                f"— {self.detail}")


def _rel_change(metric: str, base: float, fresh: float) -> float:
    """Signed relative regression: positive = worse, direction-aware."""
    if base == 0:
        return 0.0 if fresh == 0 else float("inf")
    change = (fresh - base) / abs(base)
    return -change if metric in HIGHER_IS_BETTER else change


def compare_to_baseline(baseline_rows: Mapping[str, Mapping[str, Any]],
                        fresh_rows: Mapping[str, Mapping[str, Any]],
                        *, bench: str,
                        ledger: Sequence[LedgerEntry] = (),
                        tolerances: Optional[Mapping[str, float]] = None
                        ) -> List[Finding]:
    """Row-by-row fresh-vs-baseline comparison (same-config runs).

    Every metric named in ``tolerances`` and present (as a number) in
    both copies of a row is compared; a direction-aware relative change
    past the tolerance is a ``regression`` finding unless that
    (row, metric) is ledgered — ledgered pairs are checked by
    :func:`check_ledger` against their reference instead."""
    tol = dict(DEFAULT_TOLERANCES if tolerances is None else tolerances)
    findings: List[Finding] = []
    for row_name in sorted(set(baseline_rows) & set(fresh_rows)):
        base_row, fresh_row = baseline_rows[row_name], fresh_rows[row_name]
        for metric, t in sorted(tol.items()):
            b, f = base_row.get(metric), fresh_row.get(metric)
            if not isinstance(b, (int, float)) \
                    or not isinstance(f, (int, float)):
                continue
            rel = _rel_change(metric, float(b), float(f))
            if rel <= t:
                continue
            if ledgered(ledger, bench, row_name, metric):
                findings.append(Finding(
                    "known", bench, row_name, metric,
                    f"{b:g} -> {f:g} ({rel:+.0%}), ledgered"))
            else:
                findings.append(Finding(
                    "regression", bench, row_name, metric,
                    f"{b:g} -> {f:g} ({rel:+.0%} > {t:.0%} tolerance)"))
    return findings


def check_ledger(rows: Mapping[str, Mapping[str, Any]],
                 ledger: Sequence[LedgerEntry], *, bench: str
                 ) -> List[Finding]:
    """Validate each ledgered row against its in-run reference row.

    Within-run ratios are host-speed independent, so this check works on
    the committed baselines AND on smoke-scale fresh runs.  Outcomes:
    ratio > ``max_ratio`` → the known regression got *worse*
    (``regression``); ratio < ``fixed_below_ratio`` → it is FIXED
    (``fixed`` — the gate fails "better" until the entry is removed);
    otherwise ``known``."""
    findings: List[Finding] = []
    for e in ledger:
        if e.bench != bench:
            continue
        row, ref = rows.get(e.row), rows.get(e.reference)
        if row is None or ref is None:
            findings.append(Finding(
                "regression", bench, e.row, e.metric,
                f"ledger references missing row(s): "
                f"{e.row if row is None else e.reference}"))
            continue
        rv, fv = row.get(e.metric), ref.get(e.metric)
        if not isinstance(rv, (int, float)) \
                or not isinstance(fv, (int, float)) or fv == 0:
            findings.append(Finding(
                "regression", bench, e.row, e.metric,
                "ledgered metric missing or zero in rows"))
            continue
        ratio = float(rv) / float(fv)
        if e.metric in HIGHER_IS_BETTER:
            ratio = 1.0 / ratio if ratio else float("inf")
        if ratio > e.max_ratio:
            findings.append(Finding(
                "regression", bench, e.row, e.metric,
                f"known regression got worse: {ratio:.2f}x "
                f"{e.reference} (ledger allows {e.max_ratio:.2f}x)"))
        elif ratio < e.fixed_below_ratio:
            findings.append(Finding(
                "fixed", bench, e.row, e.metric,
                f"now {ratio:.2f}x {e.reference} (< "
                f"{e.fixed_below_ratio:.2f}x) — remove the stale "
                f"ledger entry and keep the win"))
        else:
            findings.append(Finding(
                "known", bench, e.row, e.metric,
                f"{ratio:.2f}x {e.reference} (ledgered, allowed up to "
                f"{e.max_ratio:.2f}x): {e.reason or 'known'}"))
    return findings


def compare_ratios(baseline_rows: Mapping[str, Mapping[str, Any]],
                   fresh_rows: Mapping[str, Mapping[str, Any]], *,
                   bench: str, metric: str,
                   pairs: Sequence[tuple],
                   ledger: Sequence[LedgerEntry] = (),
                   slack: float = 1.0) -> List[Finding]:
    """Compare WITHIN-RUN ratios (row/reference) between a fresh run and
    the baseline — the smoke gate: sizes and host speed differ between a
    CI smoke and the committed baseline, but a sharded tier suddenly
    costing 3x its replicated reference when the baseline says 1.9x is a
    structural regression regardless of scale.  ``pairs`` is
    ``[(row, reference), ...]``; a fresh ratio worse than baseline_ratio
    * (1 + slack) on a non-ledgered row is a regression."""
    findings: List[Finding] = []
    for row_name, ref_name in pairs:
        vals = []
        for rows in (baseline_rows, fresh_rows):
            row, ref = rows.get(row_name), rows.get(ref_name)
            if row is None or ref is None:
                vals.append(None)
                continue
            rv, fv = row.get(metric), ref.get(metric)
            if not isinstance(rv, (int, float)) \
                    or not isinstance(fv, (int, float)) or not fv:
                vals.append(None)
            else:
                vals.append(float(rv) / float(fv))
        base_ratio, fresh_ratio = vals
        if base_ratio is None or fresh_ratio is None:
            continue
        if fresh_ratio > base_ratio * (1.0 + slack):
            kind = ("known"
                    if ledgered(ledger, bench, row_name, metric)
                    else "regression")
            findings.append(Finding(
                kind, bench, row_name, metric,
                f"ratio vs {ref_name}: {base_ratio:.2f}x -> "
                f"{fresh_ratio:.2f}x (slack {slack:.0%})"))
        else:
            findings.append(Finding(
                "ok", bench, row_name, metric,
                f"ratio vs {ref_name}: {base_ratio:.2f}x -> "
                f"{fresh_ratio:.2f}x"))
    return findings


def worst_exit_code(findings: Sequence[Finding]) -> int:
    """The gate's verdict: 1 on any ``regression``, else 3 on any
    ``fixed`` (failing better — update the ledger), else 0."""
    kinds = {f.kind for f in findings}
    if "regression" in kinds:
        return 1
    if "fixed" in kinds:
        return 3
    return 0
