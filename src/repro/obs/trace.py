"""Host-side span/trace layer: timed scopes + structured JSONL events.

The compiled programs are observable in-graph via the telemetry rings
(``repro.obs.rings``); everything *around* them — session compiles,
program dispatches, fleet waves, admission refills, cache lookups — is
host work, traced here:

    from repro import obs

    with obs.span("cohort.wave", cohort=0, slots_active=3):
        ...

A span times its block (``perf_counter_ns``), enters a
``jax.profiler.TraceAnnotation`` of the same name — so when a profiler
trace is active (``--trace-dir`` on the launchers, or
``jax.profiler.trace``) the host scopes line up with the device
timeline — and records a structured event on the process-wide
:class:`Tracer`.  ``configure(jsonl_path=...)`` additionally streams
every event as one JSON line; the default tracer keeps a bounded
in-memory buffer so tracing is always on and never grows without bound.

Events are plain dicts::

    {"ev": "span", "name": "cohort.wave", "ts": <unix seconds>,
     "dur_us": 812.4, "slots_active": 3, ...}
    {"ev": "event", "name": "cohort.refill", "ts": ..., "slot": 2, ...}

Everything is best-effort and side-effect-free for the traced
computation: tracing never touches program math, RNG streams, or
compile keys.
"""

from __future__ import annotations

import collections
import contextlib
import json
import time
from typing import Any, Deque, Dict, Iterator, List, Optional

import jax

#: in-memory event buffer bound of the default tracer — big enough for
#: a whole fleet drain, small enough to never matter.
DEFAULT_BUFFER = 4096


def _jsonable(v: Any) -> Any:
    """Coerce numpy/jax scalars (and anything else) to JSON-safe
    values; arrays become lists, unknown objects become ``repr``."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if hasattr(v, "item") and getattr(v, "ndim", None) == 0:
        return v.item()
    if hasattr(v, "tolist"):
        return v.tolist()
    return repr(v)


class Tracer:
    """Collects span/event records; optionally streams them as JSONL.

    One process-wide instance (:func:`get_tracer`) backs the module
    level :func:`span` / :func:`event` helpers; tests and embedders can
    build private tracers and swap them in with :func:`configure` /
    :func:`use_tracer`.
    """

    def __init__(self, jsonl_path: Optional[str] = None,
                 buffer: int = DEFAULT_BUFFER):
        self._events: Deque[Dict[str, Any]] = collections.deque(
            maxlen=buffer)
        self._path = jsonl_path
        self._file = open(jsonl_path, "a") if jsonl_path else None

    # -- recording -----------------------------------------------------------

    def emit(self, record: Dict[str, Any]) -> None:
        self._events.append(record)
        if self._file is not None:
            self._file.write(json.dumps(record) + "\n")
            self._file.flush()

    def event(self, name: str, **attrs: Any) -> None:
        """Record an instantaneous event."""
        self.emit({"ev": "event", "name": name, "ts": time.time(),
                   **{k: _jsonable(v) for k, v in attrs.items()}})

    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any) -> Iterator[Dict[str, Any]]:
        """Timed scope: wall duration + ``jax.profiler.TraceAnnotation``.

        Yields a mutable dict — attributes added to it inside the block
        land on the emitted record (e.g. a wave span learns how many
        slots finished only after stepping)."""
        extra: Dict[str, Any] = {}
        t0 = time.perf_counter_ns()
        with jax.profiler.TraceAnnotation(name):
            try:
                yield extra
            finally:
                dur_ns = time.perf_counter_ns() - t0
                self.emit({"ev": "span", "name": name, "ts": time.time(),
                           "dur_us": dur_ns / 1e3,
                           **{k: _jsonable(v) for k, v in attrs.items()},
                           **{k: _jsonable(v) for k, v in extra.items()}})

    # -- introspection / lifecycle -------------------------------------------

    def events(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        """A snapshot of the buffered events (newest last), optionally
        filtered by ``name``."""
        evs = list(self._events)
        if name is not None:
            evs = [e for e in evs if e.get("name") == name]
        return evs

    def clear(self) -> None:
        self._events.clear()

    @property
    def jsonl_path(self) -> Optional[str]:
        return self._path

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None


_TRACER = Tracer()


def get_tracer() -> Tracer:
    """The process-wide tracer behind :func:`span` / :func:`event`."""
    return _TRACER


def use_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-wide tracer (returns the previous one)."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def configure(jsonl_path: Optional[str] = None,
              buffer: int = DEFAULT_BUFFER) -> Tracer:
    """Replace the process-wide tracer — with a JSONL sink, the way the
    launchers' ``--metrics-out`` wires span streaming on."""
    old = use_tracer(Tracer(jsonl_path=jsonl_path, buffer=buffer))
    old.close()
    return get_tracer()


def span(name: str, **attrs: Any):
    """``with obs.span("session.dispatch", mode="sync"): ...``"""
    return _TRACER.span(name, **attrs)


def event(name: str, **attrs: Any) -> None:
    """Record an instantaneous event on the process-wide tracer."""
    _TRACER.event(name, **attrs)


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse a span/event JSONL file (skipping blank lines)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
