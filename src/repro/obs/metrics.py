"""Metrics registry + exposition: counters/gauges/histograms per run,
rendered as Prometheus text format and JSON.

This is the host-side aggregation layer over the other two obs
substrates: the in-graph rings (``repro.obs.rings``) supply per-round
signals, the tracer (``repro.obs.trace``) supplies span durations, and
a :class:`MetricsRegistry` folds both into a flat, scrapable snapshot —
``ELReport.telemetry`` carries the raw material, ``--metrics-out`` on
the launch CLIs writes the rendered files, ``scripts/obs_summary.py``
pretty-prints them.

Deliberately tiny and dependency-free: enough of the Prometheus
`text exposition format <https://prometheus.io/docs/instrumenting/exposition_formats/>`_
(``# HELP`` / ``# TYPE``, labels, cumulative histogram buckets) for a
real scraper to ingest, plus :func:`parse_prometheus` so CI can assert
the output round-trips.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")

#: default histogram buckets (seconds-ish scale; µs spans divide first)
DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0)


def _labels_key(labels: Optional[Mapping[str, str]]
                ) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v))
                        for k, v in (labels or {}).items()))


def _escape_label(v: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote and
    newline (exposition-format spec, in that order so the escapes
    themselves survive)."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _unescape_label(v: str) -> str:
    out: List[str] = []
    i = 0
    while i < len(v):
        c = v[i]
        if c == "\\" and i + 1 < len(v):
            nxt = v[i + 1]
            out.append({"n": "\n", '"': '"', "\\": "\\"}.get(nxt, c + nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def _fmt_labels(items: Tuple[Tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{k}="{_escape_label(v)}"' for k, v in items]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


@dataclasses.dataclass
class Counter:
    """Monotonic counter; one value per label set."""

    name: str
    help: str
    values: Dict[Tuple[Tuple[str, str], ...], float] = \
        dataclasses.field(default_factory=dict)

    def inc(self, amount: float = 1.0,
            labels: Optional[Mapping[str, str]] = None) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        k = _labels_key(labels)
        self.values[k] = self.values.get(k, 0.0) + float(amount)


@dataclasses.dataclass
class Gauge:
    """Point-in-time value; one value per label set."""

    name: str
    help: str
    values: Dict[Tuple[Tuple[str, str], ...], float] = \
        dataclasses.field(default_factory=dict)

    def set(self, value: float,
            labels: Optional[Mapping[str, str]] = None) -> None:
        self.values[_labels_key(labels)] = float(value)


@dataclasses.dataclass
class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics)."""

    name: str
    help: str
    buckets: Tuple[float, ...] = DEFAULT_BUCKETS
    counts: Dict[Tuple[Tuple[str, str], ...], List[int]] = \
        dataclasses.field(default_factory=dict)
    sums: Dict[Tuple[Tuple[str, str], ...], float] = \
        dataclasses.field(default_factory=dict)
    totals: Dict[Tuple[Tuple[str, str], ...], int] = \
        dataclasses.field(default_factory=dict)

    def observe(self, value: float,
                labels: Optional[Mapping[str, str]] = None) -> None:
        k = _labels_key(labels)
        if k not in self.counts:
            self.counts[k] = [0] * len(self.buckets)
            self.sums[k] = 0.0
            self.totals[k] = 0
        v = float(value)
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[k][i] += 1
        self.sums[k] += v
        self.totals[k] += 1

    def observe_many(self, values: Sequence[float],
                     labels: Optional[Mapping[str, str]] = None) -> None:
        for v in values:
            self.observe(v, labels)


class MetricsRegistry:
    """A named family of counters/gauges/histograms with renderers.

    ``counter()``/``gauge()``/``histogram()`` create-or-return (same
    name must keep the same type), so builders can compose registries
    incrementally — e.g. the fleet CLI folds per-tenant report metrics
    and server stats into one registry before writing files.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}

    def _register(self, cls, name: str, help: str, **kw) -> Any:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        m = self._metrics.get(name)
        if m is None:
            m = cls(name=name, help=help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS
                  ) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str) -> Any:
        return self._metrics[name]

    # -- renderers -----------------------------------------------------------

    def render_prometheus(self) -> str:
        """The registry in Prometheus text exposition format."""
        lines: List[str] = []
        for name in self.names():
            m = self._metrics[name]
            kind = {Counter: "counter", Gauge: "gauge",
                    Histogram: "histogram"}[type(m)]
            lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, Histogram):
                for k in sorted(m.counts):
                    for le, c in zip(m.buckets, m.counts[k]):
                        le_lab = 'le="' + _fmt_value(le) + '"'
                        lines.append(
                            f"{name}_bucket{_fmt_labels(k, le_lab)} {c}")
                    inf_lab = 'le="+Inf"'
                    lines.append(
                        f"{name}_bucket{_fmt_labels(k, inf_lab)}"
                        f" {m.totals[k]}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(k)}"
                        f" {_fmt_value(m.sums[k])}")
                    lines.append(
                        f"{name}_count{_fmt_labels(k)} {m.totals[k]}")
            else:
                for k in sorted(m.values):
                    lines.append(
                        f"{name}{_fmt_labels(k)}"
                        f" {_fmt_value(m.values[k])}")
        return "\n".join(lines) + "\n"

    def to_json(self) -> Dict[str, Any]:
        """A JSON-safe snapshot (the ``--metrics-out`` ``.json`` file)."""
        out: Dict[str, Any] = {}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out[name] = {
                    "type": "histogram", "help": m.help,
                    "buckets": list(m.buckets),
                    "series": [
                        {"labels": dict(k), "counts": m.counts[k],
                         "sum": m.sums[k], "count": m.totals[k]}
                        for k in sorted(m.counts)],
                }
            else:
                kind = "counter" if isinstance(m, Counter) else "gauge"
                out[name] = {
                    "type": kind, "help": m.help,
                    "series": [{"labels": dict(k), "value": v}
                               for k, v in sorted(m.values.items())],
                }
        return out


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)\s*$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> Dict[str, List[Dict[str, Any]]]:
    """Parse Prometheus text format back into
    ``{name: [{"labels": {...}, "value": float}, ...]}`` — strict on
    sample lines (raises ``ValueError`` on malformed ones), which is
    exactly what the CI smoke wants from ``--metrics-out`` output.
    Histogram series parse as their ``_bucket``/``_sum``/``_count``
    sample names.
    """
    samples: Dict[str, List[Dict[str, Any]]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(
                f"malformed Prometheus sample on line {lineno}: {line!r}")
        raw = m.group("value")
        value = float("inf") if raw == "+Inf" else (
            float("-inf") if raw == "-Inf" else float(raw))
        labels = {k: _unescape_label(v)
                  for k, v in _LABEL_RE.findall(m.group("labels") or "")}
        samples.setdefault(m.group("name"), []).append(
            {"labels": labels, "value": value})
    return samples


# ---------------------------------------------------------------------------
# Registry builders (ELReport / fleet stats → metrics)
# ---------------------------------------------------------------------------


def registry_from_report(report, *, registry: Optional[MetricsRegistry]
                         = None,
                         labels: Optional[Mapping[str, str]] = None
                         ) -> MetricsRegistry:
    """Fold one :class:`repro.el.report.ELReport` into a registry.

    Emits run-level gauges/counters (rounds, final metric, consumption,
    wall time, per-arm pulls), the compile-cache counters when
    ``report.telemetry['cache']`` is present, program-profile gauges
    (``el_profile_*``: flops, peak live bytes, the per-op collective
    census) when ``report.telemetry['profile']`` is present, and
    ring-derived series (budget remaining, per-round cost / merge-α
    histograms) when the run recorded in-graph telemetry.
    """
    reg = registry if registry is not None else MetricsRegistry()
    labels = dict(labels or {})
    base = {"mode": report.mode or "?", "policy": report.policy or "?",
            **labels}
    reg.counter("el_rounds_total",
                "global aggregations completed").inc(
        report.n_aggregations, base)
    reg.gauge("el_final_metric", "final eval metric").set(
        report.final_metric, base)
    reg.gauge("el_total_consumed",
              "total resource units consumed").set(
        report.total_consumed, base)
    reg.gauge("el_wall_time", "simulated wall-clock at termination").set(
        report.wall_time, base)
    reg.gauge("el_elapsed_seconds", "host wall seconds for the run").set(
        report.elapsed_s, base)
    for arm, pulls in enumerate(report.arm_pulls or []):
        reg.counter("el_arm_pulls_total", "bandit pulls per arm").inc(
            pulls, {**base, "arm": str(arm + 1)})

    tele = report.telemetry or {}
    cache = tele.get("cache")
    if cache:
        for k in ("hits", "misses", "evictions"):
            if k in cache:
                reg.counter(f"el_program_cache_{k}_total",
                            f"compiled-program cache {k}").inc(
                    cache[k], labels)
        if "entries" in cache:
            reg.gauge("el_program_cache_entries",
                      "compiled programs cached").set(
                cache["entries"], labels)
    prof = tele.get("profile")
    if prof:
        _profile_gauges = (
            ("flops", "XLA cost-analysis flops per dispatch"),
            ("bytes_accessed", "XLA cost-analysis bytes accessed"),
            ("argument_bytes", "per-device argument bytes"),
            ("output_bytes", "per-device output bytes"),
            ("temp_bytes", "per-device temp bytes"),
            ("alias_bytes", "donated/aliased input bytes"),
            ("peak_live_bytes",
             "arguments + outputs + temps - aliased, per device"),
            ("generated_code_bytes", "compiled executable code size"),
            ("collective_bytes",
             "per-device bytes moved by collectives per dispatch"),
            ("hlo_lines", "optimized HLO line count"),
        )
        for field, help_ in _profile_gauges:
            v = prof.get(field)
            if v is not None:
                reg.gauge(f"el_profile_{field}", help_).set(
                    float(v), base)
        for op, d in sorted((prof.get("collectives") or {}).items()):
            op_labels = {**base, "op": op}
            reg.gauge("el_profile_collectives",
                      "collective op census of the compiled program"
                      ).set(float(d.get("count", 0)), op_labels)
            reg.gauge("el_profile_collective_op_bytes",
                      "per-device result bytes of one collective op"
                      ).set(float(d.get("bytes", 0)), op_labels)
    rings = tele.get("rings")
    if rings:
        from repro.obs.rings import unroll_ring
        rings = unroll_ring(rings)     # round order, written slots only
        resid = np.asarray(rings["budget_resid"], np.float64)
        if resid.size:
            reg.gauge("el_budget_remaining",
                      "min residual budget after the last recorded "
                      "round").set(float(resid[-1]), base)
        cost_key = "round_cost" if "round_cost" in rings else "cost"
        costs = np.asarray(rings[cost_key], np.float64)
        if costs.size:
            reg.histogram(
                "el_round_cost", "charged cost per round/event",
                buckets=_cost_buckets(costs)).observe_many(costs, base)
        if "alpha" in rings:
            reg.histogram(
                "el_merge_alpha", "async staleness-weighted merge rate",
                buckets=(0.05, 0.1, 0.2, 0.3, 0.4, 0.5, 0.75, 1.0)
            ).observe_many(np.asarray(rings["alpha"], np.float64), base)
        if "interarrival" in rings:
            inter = np.asarray(rings["interarrival"], np.float64)
            reg.histogram(
                "el_event_interarrival",
                "simulated time between async merge events",
                buckets=_cost_buckets(inter)).observe_many(inter, base)
        if "active_edges" in rings:
            # scenario-engine columns (repro.el.scenarios): fleet-churn
            # census per recorded round/event
            act = np.asarray(rings["active_edges"], np.float64)
            if act.size:
                reg.gauge("el_scenario_active_edges",
                          "active edges in the last recorded "
                          "round/event").set(float(act[-1]), base)
            reg.counter("el_scenario_dropouts_total",
                        "edge dropout transitions over the recorded "
                        "window").inc(
                int(np.sum(np.asarray(rings["dropouts"], np.int64))),
                base)
            reg.counter("el_scenario_rejoins_total",
                        "edge rejoin transitions over the recorded "
                        "window").inc(
                int(np.sum(np.asarray(rings["rejoins"], np.int64))),
                base)
    return reg


def _cost_buckets(values: np.ndarray) -> Tuple[float, ...]:
    """Data-scaled buckets: powers of two spanning the sample range (the
    EL cost scale depends entirely on the config's comp/comm costs)."""
    hi = float(np.max(values)) if values.size else 1.0
    if hi <= 0:
        return (1.0,)
    top = 2.0 ** math.ceil(math.log2(hi))
    return tuple(top / 2.0 ** i for i in reversed(range(8)))


def registry_from_fleet(stats: Mapping[str, Any],
                        *, registry: Optional[MetricsRegistry] = None
                        ) -> MetricsRegistry:
    """Fold ``FleetServer.stats()`` into a registry."""
    reg = registry if registry is not None else MetricsRegistry()
    counters = ("tenants_submitted", "tenants_done", "compiles", "waves",
                "cache_hits", "cache_misses", "cache_evictions")
    for k in counters:
        if k in stats:
            reg.counter(f"fleet_{k}_total", f"fleet server {k}").inc(
                stats[k])
    for k in ("tenants_pending", "tenants_active", "cohorts"):
        if k in stats:
            reg.gauge(f"fleet_{k}", f"fleet server {k}").set(stats[k])
    return reg


def spans_into_registry(events: Sequence[Mapping[str, Any]],
                        *, registry: Optional[MetricsRegistry] = None
                        ) -> MetricsRegistry:
    """Fold tracer span records (``repro.obs.trace``) into per-span-name
    duration histograms (seconds) + event counters."""
    reg = registry if registry is not None else MetricsRegistry()
    for ev in events:
        name = str(ev.get("name", "?")).replace(".", "_")
        if ev.get("ev") == "span":
            reg.histogram(f"obs_span_{name}_seconds",
                          f"wall duration of {ev.get('name')} spans"
                          ).observe(float(ev.get("dur_us", 0.0)) / 1e6)
        else:
            reg.counter(f"obs_event_{name}_total",
                        f"{ev.get('name')} events").inc()
    return reg


def write_metrics_files(registry: MetricsRegistry, path: str,
                        *, spans_jsonl: Optional[str] = None) -> List[str]:
    """Write the ``--metrics-out`` artifact set: ``path`` (Prometheus
    text) and ``path + '.json'`` (JSON snapshot).  Returns the paths
    written; ``spans_jsonl`` (the tracer's sink, already on disk) is
    appended to the returned list for the CLI summary line."""
    with open(path, "w") as f:
        f.write(registry.render_prometheus())
    json_path = path + ".json"
    with open(json_path, "w") as f:
        json.dump(registry.to_json(), f, indent=2, sort_keys=True)
    written = [path, json_path]
    if spans_jsonl:
        written.append(spans_jsonl)
    return written
