"""argparse glue shared by the ``repro.launch`` CLIs.

One flag set, one lifecycle, three launchers::

    add_metrics_args(ap)                 # --metrics-out [--trace-dir]
    begin_observability(args)            # arm the JSONL sink / profiler
    ... run ...
    finish_observability(args, registry) # flush + write the artifact set

``--metrics-out PATH`` writes three files: ``PATH`` (Prometheus text
exposition), ``PATH.json`` (the same registry as JSON) and
``PATH.spans.jsonl`` (every span/event the tracer saw, streamed live).
``--trace-dir DIR`` additionally captures a ``jax.profiler`` trace of
the whole run — the ``obs.span`` scopes appear as ``TraceAnnotation``
rows on the device timeline.
"""

from __future__ import annotations

from typing import Optional


def add_metrics_args(ap, *, trace_dir: bool = False) -> None:
    """Add the observability flags to an ``argparse`` parser."""
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write run metrics to PATH (Prometheus text), "
                         "PATH.json (JSON snapshot) and PATH.spans.jsonl "
                         "(streamed tracer spans)")
    if trace_dir:
        ap.add_argument("--trace-dir", default=None, metavar="DIR",
                        help="capture a jax.profiler trace of the run "
                             "into DIR (inspect with TensorBoard or "
                             "ui.perfetto.dev)")


def begin_observability(args) -> bool:
    """Arm the sinks BEFORE the run: swap in a tracer streaming to
    ``PATH.spans.jsonl`` and (with ``--trace-dir``) start a profiler
    trace.  Returns whether ``--metrics-out`` is active."""
    if getattr(args, "metrics_out", None):
        from repro.obs import trace
        trace.configure(jsonl_path=args.metrics_out + ".spans.jsonl")
    if getattr(args, "trace_dir", None):
        import jax
        jax.profiler.start_trace(args.trace_dir)
    return bool(getattr(args, "metrics_out", None))


def finish_observability(args, registry=None):
    """Flush at the end of the run: stop the profiler trace, fold the
    buffered spans/events into ``registry`` (a fresh one when ``None``)
    and write the ``--metrics-out`` artifact set.  No-op for flags that
    were not passed; returns the registry written (or ``None``)."""
    if getattr(args, "trace_dir", None):
        import jax
        jax.profiler.stop_trace()
        print(f"profiler trace written to {args.trace_dir}", flush=True)
    if not getattr(args, "metrics_out", None):
        return None
    from repro.obs import spans_into_registry, write_metrics_files
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import get_tracer
    reg = registry if registry is not None else MetricsRegistry()
    tracer = get_tracer()
    spans_into_registry(tracer.events(), registry=reg)
    written = write_metrics_files(reg, args.metrics_out,
                                  spans_jsonl=tracer.jsonl_path)
    tracer.close()
    print("metrics written: " + ", ".join(written), flush=True)
    return reg


def telemetry_arg(ap) -> None:
    """Add ``--telemetry [N]``: switch the in-graph rings on, optionally
    with an explicit ring size (rounds/events kept)."""
    ap.add_argument("--telemetry", nargs="?", type=int, const=True,
                    default=None, metavar="RING",
                    help="record the in-graph telemetry rings (repro.obs."
                         "rings; default off — the off path compiles "
                         "bit-identically); optional RING sets the ring "
                         "length (default 128)")
