"""Program profiles: XLA cost/memory/collective introspection.

One compiled EL program = one :class:`ProgramProfile` — FLOPs and bytes
accessed from XLA's ``cost_analysis()``, per-device argument / output /
temp / alias bytes (and the derived peak) from ``memory_analysis()``,
and a collective census parsed from the optimized HLO.  The profile is
the static half of observability: the telemetry rings (``repro.obs.
rings``) say what a run *did*, the profile says what the executable
*is* — how many all-gathers a sharded program issues per dispatch,
whether donation actually aliased the params, how much live memory the
while-loop body holds.

Extraction is an extra ``lower().compile()`` (AOT compiles do not share
the jit dispatch cache), so callers keep it lazy and opt-in:
``ELSession`` computes a profile once per cached program only when
asked (``profile=``/``contract=`` or ``REPRO_EL_PROFILE=1``), and
``scripts/bench_el.py`` profiles every tier it times anyway.

:class:`CollectiveContract` turns the profile into a declarative,
dispatch-time assertion — "a sharded sync program all-gathers and never
all-reduces", "a donated program aliases exactly the param bytes" —
replacing one-off HLO string checks in tests with a single checkable
object (``contract.enforce(profile)`` raises
:class:`ContractViolation`).

The HLO collective parser (:func:`parse_collectives` /
:func:`_type_bytes`) moved here from ``repro.launch.dryrun`` — dryrun
mutates ``XLA_FLAGS`` at import (512 forced devices), so nothing
observability-side may import it; dryrun now re-exports from here.
``repro.obs`` never imports ``repro.el``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

#: collective op mnemonics the census meters (HLO op-name spellings)
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|"
                       r"u64|c64|c128)\[([0-9,]*)\]")

_COLLECTIVE_RE = re.compile(
    r"=\s+(\(?[a-z0-9\[\],{}\s]+?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum the bytes moved by every collective op in the optimized HLO.

    Post-optimization HLO prints operands without types, so we meter the
    RESULT type of each collective: for all-reduce / all-to-all /
    collective-permute the result equals the operand; for all-gather the
    result is the gathered (received) payload per device; for
    reduce-scatter we scale the result back up by the shrink factor when
    derivable.  Shapes in the partitioned module are per-device.
    ``-start`` async forms are counted once (the ``-done`` op has a
    different result structure and is skipped via the op-name match).
    """
    per_op: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        nbytes = _type_bytes(result_type)
        d = per_op.setdefault(op, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += nbytes
    total = sum(d["bytes"] for d in per_op.values())
    return {"per_op": per_op, "bytes_per_device": total}


# ---------------------------------------------------------------------------
# Compiled-artifact readers (best-effort per section)
# ---------------------------------------------------------------------------


def memory_dict(compiled) -> Dict[str, Any]:
    """``memory_analysis()`` of a Compiled as a plain dict (``{"error":
    ...}`` when the backend cannot report it)."""
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                  # pragma: no cover
        return {"error": str(e)}
    out: Dict[str, Any] = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def cost_dict(compiled) -> Dict[str, Any]:
    """``cost_analysis()`` of a Compiled, filtered to the stable keys."""
    try:
        ca = compiled.cost_analysis()
    except Exception as e:                                  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if k in ("flops", "bytes accessed", "transcendentals")}


# ---------------------------------------------------------------------------
# ProgramProfile
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProgramProfile:
    """The static cost card of one compiled XLA executable.

    All fields are best-effort (``None`` when the backend withholds the
    analysis); ``collectives`` maps op mnemonic → ``{"count", "bytes"}``
    with per-device result bytes (see :func:`parse_collectives`).
    ``peak_live_bytes`` is the bench convention: arguments + outputs +
    temps − aliased, per device.
    """

    flops: Optional[float] = None
    bytes_accessed: Optional[float] = None
    transcendentals: Optional[float] = None
    argument_bytes: Optional[int] = None
    output_bytes: Optional[int] = None
    temp_bytes: Optional[int] = None
    alias_bytes: Optional[int] = None
    generated_code_bytes: Optional[int] = None
    peak_live_bytes: Optional[int] = None
    collectives: Dict[str, Dict[str, float]] = \
        dataclasses.field(default_factory=dict)
    collective_bytes: int = 0
    hlo_lines: Optional[int] = None
    backend: Optional[str] = None
    donated: bool = False
    errors: Tuple[str, ...] = ()

    def collective_count(self, op: str) -> int:
        """Census count of one collective op (0 when absent)."""
        return int(self.collectives.get(op, {}).get("count", 0))

    @property
    def total_collectives(self) -> int:
        return sum(int(d.get("count", 0))
                   for d in self.collectives.values())

    def to_json(self) -> Dict[str, Any]:
        """A JSON-safe snapshot (``ELReport.telemetry["profile"]``,
        BENCH rows)."""
        d = dataclasses.asdict(self)
        d["errors"] = list(self.errors)
        return d

    def summary(self) -> str:
        """One human line: flops, peak bytes, census."""
        cens = ", ".join(f"{op}={self.collective_count(op)}"
                         for op in COLLECTIVES
                         if self.collective_count(op)) or "none"
        flops = "?" if self.flops is None else f"{self.flops:.3g}"
        peak = ("?" if self.peak_live_bytes is None
                else f"{self.peak_live_bytes / 1e6:.2f}MB")
        return (f"flops={flops} peak={peak} alias={self.alias_bytes} "
                f"collectives[{cens}]")


def profile_compiled(compiled, *, donated: bool = False) -> ProgramProfile:
    """Extract a :class:`ProgramProfile` from a ``jax`` Compiled object
    (the result of ``jit(f).lower(*args).compile()``).  Every section is
    best-effort: a backend that withholds one analysis still yields a
    profile, with the failure recorded in ``profile.errors``."""
    errors: List[str] = []
    kw: Dict[str, Any] = {"donated": donated}

    cost = cost_dict(compiled)
    if "error" in cost:
        errors.append(f"cost: {cost['error']}")
    else:
        kw["flops"] = cost.get("flops")
        kw["bytes_accessed"] = cost.get("bytes accessed")
        kw["transcendentals"] = cost.get("transcendentals")

    mem = memory_dict(compiled)
    if "error" in mem:
        errors.append(f"memory: {mem['error']}")
    else:
        kw["argument_bytes"] = mem.get("argument_size_in_bytes")
        kw["output_bytes"] = mem.get("output_size_in_bytes")
        kw["temp_bytes"] = mem.get("temp_size_in_bytes")
        kw["alias_bytes"] = mem.get("alias_size_in_bytes")
        kw["generated_code_bytes"] = mem.get(
            "generated_code_size_in_bytes")
        if None not in (kw.get("argument_bytes"), kw.get("output_bytes"),
                        kw.get("temp_bytes"), kw.get("alias_bytes")):
            kw["peak_live_bytes"] = (kw["argument_bytes"]
                                     + kw["output_bytes"]
                                     + kw["temp_bytes"]
                                     - kw["alias_bytes"])

    try:
        hlo = compiled.as_text()
        census = parse_collectives(hlo)
        kw["collectives"] = census["per_op"]
        kw["collective_bytes"] = int(census["bytes_per_device"])
        kw["hlo_lines"] = hlo.count("\n")
    except Exception as e:                                  # pragma: no cover
        errors.append(f"hlo: {e}")

    try:
        import jax
        kw["backend"] = jax.default_backend()
    except Exception:                                       # pragma: no cover
        pass
    return ProgramProfile(errors=tuple(errors), **kw)


def profile_jit(jfn, *example_args, donated: bool = False
                ) -> ProgramProfile:
    """Profile a jitted callable by AOT-lowering it on ``example_args``
    (concrete arrays or ``ShapeDtypeStruct`` trees).

    The AOT compile does NOT share the jit dispatch cache — it costs one
    extra XLA compile — so callers cache the result per program (the
    session stores it on the :class:`repro.el.cache.ProgramCache`
    entry).  ``donated`` is a caller annotation recorded on the profile
    (the aliasing itself is read from ``memory_analysis``)."""
    compiled = jfn.lower(*example_args).compile()
    return profile_compiled(compiled, donated=donated)


# ---------------------------------------------------------------------------
# Collective contracts
# ---------------------------------------------------------------------------


class ContractViolation(AssertionError):
    """A compiled program broke its declared collective/aliasing
    contract."""


#: a count constraint: an exact int or an inclusive ``(lo, hi)`` range
CountConstraint = Union[int, Tuple[int, int]]


def _check_count(op: str, actual: int, want: CountConstraint
                 ) -> Optional[str]:
    if isinstance(want, tuple):
        lo, hi = want
        if not (lo <= actual <= hi):
            return (f"{op}: count {actual} outside [{lo}, {hi}]")
        return None
    if actual != int(want):
        return f"{op}: count {actual} != {int(want)}"
    return None


@dataclasses.dataclass(frozen=True)
class CollectiveContract:
    """A declarative assertion over a :class:`ProgramProfile`.

    ``counts`` maps collective op mnemonics to an exact count or an
    inclusive ``(lo, hi)`` range; ops NOT named are unconstrained.
    ``alias_bytes`` (when set) must match the profile exactly — the
    donation contract is ``alias_bytes == param_bytes`` for donated
    programs and ``== 0`` otherwise.  ``check`` returns the violations
    (empty = pass); ``enforce`` raises :class:`ContractViolation`.

    The canonical instances::

        # sync-sharded on the 2x2 debug mesh: gather-before-reduce —
        # the edge stack is all-gathered BEFORE the aggregation einsum,
        # so the program must contain NO all-reduce (any partial-sum
        # reordering would break sharded-vs-unsharded bit-identity)
        CollectiveContract("sync-sharded-2x2",
                           counts={"all-gather": (1, 16),
                                   "all-reduce": 0})

        # donated run: XLA aliased the whole param tree into the output
        CollectiveContract("donated", alias_bytes=1920)
    """

    name: str = "contract"
    counts: Mapping[str, CountConstraint] = \
        dataclasses.field(default_factory=dict)
    alias_bytes: Optional[int] = None

    def check(self, profile: ProgramProfile) -> List[str]:
        """The list of violations (empty when the profile satisfies the
        contract)."""
        bad: List[str] = []
        for op, want in sorted(dict(self.counts).items()):
            msg = _check_count(op, profile.collective_count(op), want)
            if msg is not None:
                bad.append(msg)
        if self.alias_bytes is not None:
            actual = profile.alias_bytes
            if actual is None:
                bad.append("alias_bytes: unavailable "
                           "(memory_analysis withheld)")
            elif int(actual) != int(self.alias_bytes):
                bad.append(f"alias_bytes: {actual} != {self.alias_bytes}")
        return bad

    def enforce(self, profile: ProgramProfile) -> None:
        bad = self.check(profile)
        if bad:
            raise ContractViolation(
                f"contract {self.name!r} violated: " + "; ".join(bad))


def param_tree_bytes(tree: Any) -> int:
    """Total bytes of a params tree (shapes x itemsize) — the donated
    side of the alias contract.  Accepts concrete arrays or
    ``ShapeDtypeStruct`` trees."""
    import jax
    import numpy as np
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(np.prod(np.shape(leaf), dtype=np.int64)
                     * np.dtype(leaf.dtype).itemsize)
    return total


#: loose all-gather bound for multi-device contracts: the exact count is
#: an XLA-version detail (the optimizer merges gathers between releases;
#: this toolchain emits 2 per single-run program where older ones emitted
#: 6) — the INVARIANT is >= 1 gather and 0 all-reduces.
DEFAULT_GATHER_RANGE: Tuple[int, int] = (1, 16)


#: all-reduce allowance for sharded SCENARIO programs: the churn mask
#: arithmetic (active-edge counts, mask-renormalized weight sums,
#: slowest-ACTIVE-edge slot) reduces over the sharded edge axis, which
#: GSPMD lowers as partial-sum all-reduces.  These are scalar
#: control-plane reductions, not data-plane partial sums — the
#: gather-before-reduce discipline still governs the parameter path.
SCENARIO_REDUCE_RANGE: Tuple[int, int] = (0, 32)


def default_contract(*, mesh=None, donated: bool = False,
                     param_bytes: Optional[int] = None,
                     mode: str = "sync",
                     scenario: bool = False) -> CollectiveContract:
    """The contract every compiled EL program is expected to satisfy.

    * no mesh (or a 1-device mesh): NO collectives of any kind;
    * multi-device mesh (sync AND async): gather-before-reduce — at
      least one all-gather, zero all-reduce / reduce-scatter /
      all-to-all (bit-identity with the unsharded program forbids
      partial-sum reordering);
    * ``scenario`` (a ``ScenarioSpec``-path program) on a multi-device
      mesh: additionally up to ``SCENARIO_REDUCE_RANGE[1]`` all-reduces
      — the scalar churn-mask reductions over the sharded edge axis;
    * ``donated`` with ``param_bytes``: the whole param tree aliased
      (``alias_bytes == param_bytes``); non-donated: ``== 0``.
    """
    n_dev = 1
    if mesh is not None:
        import numpy as np
        n_dev = int(np.asarray(mesh.devices).size)
    if n_dev > 1:
        counts: Dict[str, CountConstraint] = {
            "all-gather": DEFAULT_GATHER_RANGE,
            "all-reduce": (SCENARIO_REDUCE_RANGE if scenario else 0),
            "reduce-scatter": 0, "all-to-all": 0}
    else:
        counts = {op: 0 for op in COLLECTIVES}
    alias = None
    if donated and param_bytes is not None:
        alias = int(param_bytes)
    elif not donated:
        alias = 0
    tag = "sharded" if n_dev > 1 else "replicated"
    if scenario:
        tag += "-scenario"
    return CollectiveContract(
        name=f"{mode}-{tag}" + ("-donated" if donated else ""),
        counts=counts, alias_bytes=alias)
