"""In-graph telemetry rings for the compiled EL programs.

Once ``run_sync_ingraph`` / ``run_async_ingraph`` / a fleet cohort enter
their ``lax.while_loop``, the paper's whole online trade-off — bandit
arm dynamics, budget burn, merge staleness — is invisible until the run
ends.  This module adds fixed-size metric rings to the loop carries:
each round/event writes its signals at ``t % ring_size``, so the last
``ring_size`` rounds of every signal come back in the program's output
dict (``out["telemetry"]``) with zero host synchronization during the
run.

The rings are **static-gated**: the cells take ``telemetry=None`` by
default and then build *exactly* today's carry — no extra key, no extra
op, the same traced program bit-for-bit.  With a :class:`TelemetrySpec`
the carry gains one ``"telem"`` subtree and each body records under a
``jax.named_scope("obs.telemetry")`` (so only the on-path HLO changes).
The spec is frozen/hashable on purpose: it joins the session's
compile-cache keys and the fleet's cohort keys, so on/off (and
different ring sizes) never share or thrash a cache slot.

Recorded signals (everything float32/int32, matching the programs'
in-graph dtypes):

  sync  (per round)   ``arm``, ``round_cost`` (the straggler slot),
                      ``budget_resid`` (min residual after the charge),
                      ``arm_counts``/``arm_utility`` ``[ring, K]`` (the
                      bandit's post-update per-arm UCB statistics)
  async (per event)   ``edge``, ``arm``, ``cost`` (the charge),
                      ``budget_resid`` (the event edge's residual),
                      ``alpha``/``staleness`` (the merge mix), and
                      ``interarrival`` (event wall-time gap), plus the
                      event edge's ``arm_counts``/``arm_utility``

``sync_reference_telemetry`` / ``async_reference_telemetry`` replay the
rings host-side in ``np.float32`` from the program's *history* arrays
using the same op sequence the device used — the equivalence oracle the
telemetry tests compare against bit-for-bit (fixed-cost mode).

**Storage is packed by dtype group** so a record is a handful of
scatters, not one per scalar: the float32 signals live in one
``[ring, n_floats]`` buffer (column order ``_SYNC_FLOATS`` /
``_ASYNC_FLOATS``) and the async int32 pair in one ``[ring, 2]``
(``_ASYNC_INTS``), alongside the ``[ring, K]`` bandit snapshots.
``finalize_telemetry`` unpacks the columns back to the public field
names, so ``out["telemetry"]`` — and everything reading it — is
unchanged.  ``async_ring_record_wave`` lands a K-event wave's records in
their per-event slots with ONE drop-mode vector scatter per group
(wave lanes are consecutive events and K <= ring_size, so in-wave slots
never collide).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

#: default ring length: covers a whole default sync run (max_rounds=512
#: rarely exceeds a few hundred charged rounds) at ~KB-scale state.
DEFAULT_RING = 128

#: packed-column orders (the storage layout; ``finalize_telemetry``
#: unpacks them back to these public names)
_SYNC_FLOATS = ("round_cost", "budget_resid")
_ASYNC_INTS = ("edge", "arm")
_ASYNC_FLOATS = ("cost", "budget_resid", "alpha", "staleness",
                 "interarrival")
#: scenario-path extras (both modes): fleet activity per round/event —
#: present only when the cell was built with BOTH a telemetry spec and a
#: ScenarioSpec (``sync_ring_init(..., scenario=True)``)
_SCN_INTS = ("active_edges", "dropouts", "rejoins")


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Static telemetry configuration of a compiled EL program.

    Frozen + hashable so it participates in compile-cache keys and
    cohort bucketing: two runs share a compiled program only when their
    telemetry gating (and ring length) agree.
    """

    ring_size: int = DEFAULT_RING

    def __post_init__(self):
        if self.ring_size < 1:
            raise ValueError(
                f"ring_size must be >= 1, got {self.ring_size}")


def as_spec(telemetry: Union[None, bool, int, TelemetrySpec]
            ) -> Optional[TelemetrySpec]:
    """Normalize the user-facing ``telemetry=`` flag.

    ``None``/``False`` → off (the program compiles bit-identical to the
    ungated one); ``True`` → default spec; an int → that ring size; a
    :class:`TelemetrySpec` passes through.
    """
    if telemetry is None or telemetry is False:
        return None
    if telemetry is True:
        return TelemetrySpec()
    if isinstance(telemetry, TelemetrySpec):
        return telemetry
    if isinstance(telemetry, int):
        return TelemetrySpec(ring_size=telemetry)
    raise TypeError(
        f"telemetry= expects None/bool/int/TelemetrySpec, got "
        f"{type(telemetry).__name__}")


# ---------------------------------------------------------------------------
# Device-side ring init/record (called from the cell closures; jnp only
# inside so importing this module never forces jax initialization)
# ---------------------------------------------------------------------------


def sync_ring_init(spec: TelemetrySpec, n_arms: int, *,
                   scenario: bool = False) -> Dict[str, Any]:
    """The sync carry's ``"telem"`` subtree: empty ``[ring]`` /
    ``[ring, n_floats]`` / ``[ring, K]`` buffers (``arm`` is -1 where
    nothing was recorded; float columns in ``_SYNC_FLOATS`` order).
    ``scenario=True`` (the scenario-path cells) adds a packed
    ``[ring, 3]`` int group in ``_SCN_INTS`` column order; ``False``
    builds exactly the classic subtree."""
    import jax.numpy as jnp
    r = spec.ring_size
    ring = {
        "arm": jnp.full((r,), -1, jnp.int32),
        "floats": jnp.zeros((r, len(_SYNC_FLOATS)), jnp.float32),
        "arm_counts": jnp.zeros((r, n_arms), jnp.int32),
        "arm_utility": jnp.zeros((r, n_arms), jnp.float32),
    }
    if scenario:
        ring["scn"] = jnp.zeros((r, len(_SCN_INTS)), jnp.int32)
    return ring


def sync_ring_record(ring: Dict[str, Any], spec: TelemetrySpec, *,
                     t, arm, round_cost, budget_resid,
                     bstate: Dict[str, Any], scn=None) -> Dict[str, Any]:
    """Write round ``t``'s signals at slot ``t % ring_size`` (values the
    body already computed — recording adds scatters, never math; the
    float group lands as ONE row write).  ``scn=`` is the scenario
    path's ``(active_edges, dropouts, rejoins)`` int triple."""
    import jax.numpy as jnp
    i = jnp.mod(t, spec.ring_size)
    out = {
        "arm": ring["arm"].at[i].set(arm.astype(jnp.int32)),
        "floats": ring["floats"].at[i].set(
            jnp.stack([round_cost, budget_resid])),
        "arm_counts": ring["arm_counts"].at[i].set(bstate["counts"]),
        "arm_utility": ring["arm_utility"].at[i].set(
            bstate["utility_sum"]),
    }
    if scn is not None:
        out["scn"] = ring["scn"].at[i].set(
            jnp.stack([jnp.asarray(v).astype(jnp.int32) for v in scn]))
    return out


def async_ring_init(spec: TelemetrySpec, n_arms: int, *,
                    scenario: bool = False) -> Dict[str, Any]:
    """The async carry's ``"telem"`` subtree: the packed ``[ring, 2]``
    int group (``edge``/``arm``, -1 where nothing was recorded), the
    ``[ring, n_floats]`` float group (``_ASYNC_FLOATS`` column order)
    and the ``[ring, K]`` bandit snapshots.  ``scenario=True`` adds the
    ``[ring, 3]`` ``_SCN_INTS`` group (see :func:`sync_ring_init`)."""
    import jax.numpy as jnp
    r = spec.ring_size
    ring = {
        "ints": jnp.full((r, len(_ASYNC_INTS)), -1, jnp.int32),
        "floats": jnp.zeros((r, len(_ASYNC_FLOATS)), jnp.float32),
        "arm_counts": jnp.zeros((r, n_arms), jnp.int32),
        "arm_utility": jnp.zeros((r, n_arms), jnp.float32),
    }
    if scenario:
        ring["scn"] = jnp.zeros((r, len(_SCN_INTS)), jnp.int32)
    return ring


def async_ring_record(ring: Dict[str, Any], spec: TelemetrySpec, *,
                      t, edge, arm, cost, budget_resid, alpha, staleness,
                      interarrival, bstate_e: Dict[str, Any], scn=None
                      ) -> Dict[str, Any]:
    """Write event ``t``'s signals at slot ``t % ring_size`` — four
    scatters total (one per storage group), not one per scalar.
    ``scn=`` is the scenario path's ``(active_edges, dropouts,
    rejoins)`` int triple."""
    import jax.numpy as jnp
    i = jnp.mod(t, spec.ring_size)
    out = {
        "ints": ring["ints"].at[i].set(jnp.stack(
            [edge.astype(jnp.int32), arm.astype(jnp.int32)])),
        "floats": ring["floats"].at[i].set(jnp.stack(
            [cost, budget_resid, alpha, staleness, interarrival])),
        "arm_counts": ring["arm_counts"].at[i].set(bstate_e["counts"]),
        "arm_utility": ring["arm_utility"].at[i].set(
            bstate_e["utility_sum"]),
    }
    if scn is not None:
        out["scn"] = ring["scn"].at[i].set(
            jnp.stack([jnp.asarray(v).astype(jnp.int32) for v in scn]))
    return out


def async_ring_record_wave(ring: Dict[str, Any], spec: TelemetrySpec, *,
                           t0, valid, edge, arm, cost, budget_resid,
                           alpha, staleness, interarrival,
                           arm_counts, arm_utility) -> Dict[str, Any]:
    """Land a K-event wave's records in their per-event ring slots with
    one drop-mode vector scatter per storage group.

    Lane ``j`` is event ``t0 + j`` (waves accept a prefix of lanes), so
    its slot is ``(t0 + j) % ring_size``; invalid lanes route out of
    bounds and drop.  In-wave slots are distinct whenever the wave width
    is <= ``ring_size`` (enforced at cell build), so scatter order
    within the wave cannot matter — the resulting ring equals K
    sequential :func:`async_ring_record` calls exactly.
    """
    import jax.numpy as jnp
    lane = jnp.arange(edge.shape[0], dtype=jnp.int32)
    idx = jnp.where(valid, jnp.mod(t0 + lane, spec.ring_size),
                    jnp.int32(spec.ring_size))
    ints = jnp.stack([edge.astype(jnp.int32),
                      arm.astype(jnp.int32)], axis=1)       # [Kw, 2]
    floats = jnp.stack([cost, budget_resid, alpha, staleness,
                        interarrival], axis=1)              # [Kw, 5]
    return {
        "ints": ring["ints"].at[idx].set(ints, mode="drop"),
        "floats": ring["floats"].at[idx].set(floats, mode="drop"),
        "arm_counts": ring["arm_counts"].at[idx].set(arm_counts,
                                                     mode="drop"),
        "arm_utility": ring["arm_utility"].at[idx].set(arm_utility,
                                                       mode="drop"),
    }


def finalize_telemetry(telem: Dict[str, Any], t,
                       spec: TelemetrySpec) -> Dict[str, Any]:
    """The ``out["telemetry"]`` subtree a gated finalize emits: the ring
    buffers unpacked to their public field names, plus the write head
    (= rounds recorded) and the static ring size, so hosts can unroll
    wraparound without out-of-band state.  Unpacking here keeps the
    packed storage an implementation detail — readers see the same
    per-signal ``[ring]`` arrays as always."""
    import jax.numpy as jnp
    out: Dict[str, Any] = {}
    if "ints" in telem:                      # async packed layout
        for j, name in enumerate(_ASYNC_INTS):
            out[name] = telem["ints"][:, j]
        float_names = _ASYNC_FLOATS
    else:                                    # sync layout
        out["arm"] = telem["arm"]
        float_names = _SYNC_FLOATS
    for j, name in enumerate(float_names):
        out[name] = telem["floats"][:, j]
    out["arm_counts"] = telem["arm_counts"]
    out["arm_utility"] = telem["arm_utility"]
    if "scn" in telem:                       # scenario-path extras
        for j, name in enumerate(_SCN_INTS):
            out[name] = telem["scn"][:, j]
    return {**out, "head": t, "ring_size": jnp.int32(spec.ring_size)}


# ---------------------------------------------------------------------------
# Host-side ring reading
# ---------------------------------------------------------------------------


def ring_order(head: int, ring_size: int) -> List[Tuple[int, int]]:
    """The ``(round_t, slot)`` pairs a ring holds, oldest first: rounds
    ``max(0, head - ring_size) .. head - 1`` at slots ``t % ring_size``.
    """
    head, ring_size = int(head), int(ring_size)
    return [(t, t % ring_size) for t in range(max(0, head - ring_size),
                                              head)]


def unroll_ring(telemetry: Dict[str, Any]) -> Dict[str, np.ndarray]:
    """Reorder an ``out["telemetry"]`` dict's buffers into round order
    (oldest retained round first), dropping never-written slots."""
    order = ring_order(telemetry["head"], telemetry["ring_size"])
    slots = [s for _, s in order]
    return {k: np.asarray(v)[slots] for k, v in telemetry.items()
            if k not in ("head", "ring_size")}


# ---------------------------------------------------------------------------
# Host-side reference replays (the equivalence oracle for the tests)
# ---------------------------------------------------------------------------


def _replay_bandit(arms: np.ndarray, utilities: np.ndarray,
                   costs: np.ndarray, n_arms: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Replay ``jax_bandit_update`` for one bandit: per-step post-update
    (counts, utility_sum) snapshots, accumulated in np.float32 in pull
    order — the device's exact op sequence."""
    counts = np.zeros(n_arms, np.int32)
    usum = np.zeros(n_arms, np.float32)
    out_c = np.zeros((len(arms), n_arms), np.int32)
    out_u = np.zeros((len(arms), n_arms), np.float32)
    for t, (a, u) in enumerate(zip(arms, utilities)):
        counts[a] += 1
        usum[a] = np.float32(usum[a] + np.float32(u))
        out_c[t] = counts
        out_u[t] = usum
    del costs                      # cost_sum is not ring-recorded
    return out_c, out_u


def sync_reference_telemetry(out: Dict[str, Any],
                             knobs: Dict[str, np.ndarray],
                             n_arms: int) -> Dict[str, np.ndarray]:
    """Replay the sync rings from the program's history arrays.

    Valid for fixed-cost runs (``cost_noise == 0``, where the noise
    multiplier is exactly 1.0): every replayed quantity repeats the
    device's f32 op sequence on the same values —

      * ``round_cost``  = ``max_e(interval * comp_e + comm_e)``;
      * ``budget_resid``= ``budget - wall_t`` (in sync every edge's
        consumed equals the cumulative straggler wall, accumulated by
        the identical additions, so the device's ``min(budget -
        consumed)`` is this very subtraction);
      * the bandit statistics replay ``jax_bandit_update`` from the
        (interval, utility) history.

    Returns round-ordered arrays shaped like :func:`unroll_ring` of the
    device telemetry.
    """
    tele = out["telemetry"]
    head = int(np.asarray(tele["head"]))
    ring = int(np.asarray(tele["ring_size"]))
    interval = np.asarray(out["interval"])[:head]
    utility = np.asarray(out["utility"])[:head].astype(np.float32)
    wall = np.asarray(out["wall"])[:head].astype(np.float32)
    comp = np.asarray(knobs["comp"], np.float32)
    comm = np.asarray(knobs["comm"], np.float32)
    budget = np.float32(knobs["budget"])

    arms = (interval - 1).astype(np.int32)
    round_cost = np.array(
        [np.max(np.float32(i) * comp + comm) for i in
         interval.astype(np.float32)], np.float32)
    budget_resid = np.float32(budget - wall)
    counts, usum = _replay_bandit(arms, utility, round_cost, n_arms)

    lo = max(0, head - ring)
    return {
        "arm": arms[lo:head],
        "round_cost": round_cost[lo:head],
        "budget_resid": budget_resid[lo:head],
        "arm_counts": counts[lo:head],
        "arm_utility": usum[lo:head],
    }


def async_reference_telemetry(out: Dict[str, Any],
                              knobs: Dict[str, np.ndarray],
                              n_edges: int, n_arms: int
                              ) -> Dict[str, np.ndarray]:
    """Replay the async rings from the program's history arrays.

    Replays the event loop's bookkeeping — per-edge budget
    accumulation, the model-version / fetch-version staleness chain
    (``staleness_alpha``'s exact f32 expression), event inter-arrival —
    from the recorded (edge, interval, utility, cost, wall) history.
    Valid whenever the history is (both cost modes: ``cost`` is the
    realized charge).
    """
    tele = out["telemetry"]
    head = int(np.asarray(tele["head"]))
    ring = int(np.asarray(tele["ring_size"]))
    edge = np.asarray(out["edge"])[:head].astype(np.int32)
    interval = np.asarray(out["interval"])[:head].astype(np.int32)
    utility = np.asarray(out["utility"])[:head].astype(np.float32)
    cost = np.asarray(out["cost"])[:head].astype(np.float32)
    wall = np.asarray(out["wall"])[:head].astype(np.float32)
    budget = np.float32(knobs["budget"])
    alpha0 = np.float32(knobs["async_alpha"])

    arms = (interval - 1).astype(np.int32)
    consumed = np.zeros(n_edges, np.float32)
    fetch_ver = np.zeros(n_edges, np.int64)
    version = 0
    resid = np.zeros(head, np.float32)
    alpha = np.zeros(head, np.float32)
    stale = np.zeros(head, np.float32)
    inter = np.zeros(head, np.float32)
    # per-edge bandits: replay each edge's pull sequence independently
    counts = np.zeros((n_edges, n_arms), np.int32)
    usum = np.zeros((n_edges, n_arms), np.float32)
    out_c = np.zeros((head, n_arms), np.int32)
    out_u = np.zeros((head, n_arms), np.float32)
    prev_wall = np.float32(0.0)
    for t in range(head):
        e = int(edge[t])
        consumed[e] = np.float32(consumed[e] + cost[t])
        resid[t] = np.float32(budget - consumed[e])
        s = np.float32(np.float32(version - fetch_ver[e])
                       / np.float32(max(n_edges, 1)))
        stale[t] = s
        alpha[t] = np.float32(alpha0 / np.float32(1.0 + s))
        inter[t] = np.float32(wall[t] - prev_wall)
        prev_wall = wall[t]
        a = int(arms[t])
        counts[e, a] += 1
        usum[e, a] = np.float32(usum[e, a] + utility[t])
        out_c[t] = counts[e]
        out_u[t] = usum[e]
        version += 1
        fetch_ver[e] = version

    lo = max(0, head - ring)
    return {
        "edge": edge[lo:head],
        "arm": arms[lo:head],
        "cost": cost[lo:head],
        "budget_resid": resid[lo:head],
        "alpha": alpha[lo:head],
        "staleness": stale[lo:head],
        "interarrival": inter[lo:head],
        "arm_counts": out_c[lo:head],
        "arm_utility": out_u[lo:head],
    }
