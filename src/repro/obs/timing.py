"""Wall-clock timing helpers — ONE implementation for every bench.

``scripts/bench_el.py``, ``scripts/bench_fleet.py`` and
``benchmarks/microbench.py`` each grew their own copy of the
``perf_counter``-delta / min-of-repeats / mean-over-calls pattern; this
module is the shared replacement.  All primitives measure host
wall-clock via ``time.perf_counter_ns`` and report floats, so swapping
them in leaves the BENCH json value *schema* untouched.

  * :func:`time_block` — ``with time_block() as tb: ...`` then read
    ``tb.ns`` / ``tb.us`` / ``tb.ms`` / ``tb.s``;
  * :func:`timeit_us` — mean µs/call over ``n`` calls after ``warmup``
    (the microbench contract);
  * :func:`repeat_s` — per-repeat wall seconds of a callable (the
    min-of-repeats benches take ``min()`` themselves — the floor is the
    honest cost on a shared CPU host);
  * :func:`summarize_ns` — min/mean/percentile summary of raw samples.
"""

from __future__ import annotations

import contextlib
import time
from typing import Callable, Dict, Iterator, List, Sequence


class TimedBlock:
    """The result handle :func:`time_block` yields; durations are
    populated when the ``with`` block exits (0 until then)."""

    __slots__ = ("ns",)

    def __init__(self) -> None:
        self.ns: int = 0

    @property
    def us(self) -> float:
        return self.ns / 1e3

    @property
    def ms(self) -> float:
        return self.ns / 1e6

    @property
    def s(self) -> float:
        return self.ns / 1e9


@contextlib.contextmanager
def time_block() -> Iterator[TimedBlock]:
    """Time a ``with`` block: ``with time_block() as tb: ...; tb.us``."""
    tb = TimedBlock()
    t0 = time.perf_counter_ns()
    try:
        yield tb
    finally:
        tb.ns = time.perf_counter_ns() - t0


def timeit_us(fn: Callable[[], object], n: int = 50,
              warmup: int = 3) -> float:
    """Mean µs per call of ``fn`` over ``n`` calls (after ``warmup``
    unrecorded calls) — the microbench ``_time`` contract."""
    for _ in range(warmup):
        fn()
    with time_block() as tb:
        for _ in range(n):
            fn()
    return tb.us / n


def repeat_s(fn: Callable[[], object], repeats: int) -> List[float]:
    """Wall seconds of each of ``repeats`` calls of ``fn`` (no warmup —
    the benches warm explicitly so compile cost is visible where they
    choose, not here)."""
    out: List[float] = []
    for _ in range(repeats):
        with time_block() as tb:
            fn()
        out.append(tb.s)
    return out


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile of an ascending sequence."""
    if len(sorted_vals) == 1:
        return float(sorted_vals[0])
    pos = q / 100.0 * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return float(sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac)


def summarize_ns(samples_ns: Sequence[float]) -> Dict[str, float]:
    """Summary statistics of raw duration samples (any unit — the name
    records the convention the span layer emits): min/mean/std/p50/p90/
    max plus the sample count.  ``std`` is the population standard
    deviation (0 for a single sample) — the BENCH rows' spread
    convention."""
    if not samples_ns:
        return {"count": 0, "min": 0.0, "mean": 0.0, "std": 0.0,
                "p50": 0.0, "p90": 0.0, "max": 0.0}
    vals = sorted(float(x) for x in samples_ns)
    mean = sum(vals) / len(vals)
    var = sum((x - mean) ** 2 for x in vals) / len(vals)
    return {
        "count": len(vals),
        "min": vals[0],
        "mean": mean,
        "std": var ** 0.5,
        "p50": _percentile(vals, 50.0),
        "p90": _percentile(vals, 90.0),
        "max": vals[-1],
    }
