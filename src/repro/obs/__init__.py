"""``repro.obs`` — observability for the compiled EL stack.

Three substrates, one package:

  * **in-graph telemetry rings** (:mod:`repro.obs.rings`) — fixed-size
    metric buffers threaded through the compiled sync/async/cell-batch
    carries, gated by a static ``telemetry=`` flag (off = today's
    program bit-for-bit);
  * **host span/trace layer** (:mod:`repro.obs.trace`) —
    ``obs.span("cohort.wave")`` timed scopes with
    ``jax.profiler.TraceAnnotation``, streamed as structured JSONL;
  * **metrics registry + exposition** (:mod:`repro.obs.metrics`) —
    counters/gauges/histograms rendered as Prometheus text + JSON via
    ``ELReport.telemetry`` and the launchers' ``--metrics-out``.

Plus the perf half: **program profiles + collective contracts**
(:mod:`repro.obs.prof` — XLA cost/memory analysis and the HLO
collective census of every compiled EL program, with declarative
dispatch-time contracts), **bench-regression bookkeeping**
(:mod:`repro.obs.regress` — ``BENCH_history.jsonl``, baselines,
tolerances and the known-regression ledger behind
``scripts/bench_check.py``), and the shared bench timing helpers
(:mod:`repro.obs.timing`).  ``repro.obs`` never imports ``repro.el``
— the EL runtime imports obs (lazily where it is hot), so there is no
cycle.
"""

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               parse_prometheus, registry_from_fleet,
                               registry_from_report, spans_into_registry,
                               write_metrics_files)
from repro.obs.prof import (CollectiveContract, ContractViolation,
                            ProgramProfile, default_contract,
                            param_tree_bytes, parse_collectives,
                            profile_compiled, profile_jit)
from repro.obs.regress import (Finding, LedgerEntry, append_history,
                               check_ledger, compare_ratios,
                               compare_to_baseline, load_history,
                               load_ledger, worst_exit_code)
from repro.obs.rings import (TelemetrySpec, as_spec,
                             async_reference_telemetry, ring_order,
                             sync_reference_telemetry, unroll_ring)
from repro.obs.timing import (TimedBlock, repeat_s, summarize_ns,
                              time_block, timeit_us)
from repro.obs.trace import (Tracer, configure, event, get_tracer,
                             read_jsonl, span, use_tracer)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "parse_prometheus", "registry_from_fleet", "registry_from_report",
    "spans_into_registry", "write_metrics_files",
    "CollectiveContract", "ContractViolation", "ProgramProfile",
    "default_contract", "param_tree_bytes", "parse_collectives",
    "profile_compiled", "profile_jit",
    "Finding", "LedgerEntry", "append_history", "check_ledger",
    "compare_ratios", "compare_to_baseline", "load_history",
    "load_ledger", "worst_exit_code",
    "TelemetrySpec", "as_spec", "async_reference_telemetry",
    "ring_order", "sync_reference_telemetry", "unroll_ring",
    "TimedBlock", "repeat_s", "summarize_ns", "time_block", "timeit_us",
    "Tracer", "configure", "event", "get_tracer", "read_jsonl", "span",
    "use_tracer",
]
