"""§Perf hillclimb driver: lower optimization variants for the three
chosen pairs and emit before/after roofline terms.

Each variant is a (flags, tag) combination run through repro.launch.dryrun
in a SUBPROCESS (each needs its own 512-device jax process).  Results
append to results/dryrun_opt.jsonl with distinct tags; calibration twins
(tagged calib1/calib2 within the same file+tag) let roofline.py correct
scan undercounting per variant.

Usage: python scripts/perf_hillclimb.py [--pair N]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (arch, shape, extra dryrun args, tag)
VARIANTS = [
    # -- pair 1: qwen3-1.7b x train_4k ------------------------------------
    # paper-faithful EL round (the technique itself), interval ~4
    ("qwen3-1.7b", "train_4k", ["--step", "el_round", "--h-max", "4"],
     "el_round_h4"),
    # larger interval: fewer aggregations per round
    ("qwen3-1.7b", "train_4k", ["--step", "el_round", "--h-max", "8"],
     "el_round_h8"),
    # beyond-paper: sharded cross-entropy (no logits all-gather)
    ("qwen3-1.7b", "train_4k", ["--fused-xent"], "fused_xent"),
    # beyond-paper: no activation checkpointing (flops down, memory up)
    ("qwen3-1.7b", "train_4k", ["--no-remat"], "no_remat"),
    # combined
    ("qwen3-1.7b", "train_4k", ["--fused-xent", "--no-remat"],
     "fused_xent_no_remat"),
    # -- pair 2: deepseek-moe-16b x prefill_32k ---------------------------
    # beyond-paper: sort-based MoE dispatch (O(Tk) vs O(TkE) bookkeeping)
    ("deepseek-moe-16b", "prefill_32k", ["--moe-sort-dispatch"],
     "moe_sort"),
    # beyond-paper: serving prefill emits last-position logits only
    ("deepseek-moe-16b", "prefill_32k", ["--prefill-last-only"],
     "prefill_last"),
    ("deepseek-moe-16b", "prefill_32k",
     ["--moe-sort-dispatch", "--prefill-last-only"], "moe_sort_last"),
    # -- pair 3: qwen2.5-14b x long_500k ----------------------------------
    # beyond-paper: windowed KV slice decode (O(window) cache reads)
    ("qwen2.5-14b", "long_500k", ["--window-slice"], "window_slice"),
]


def run_variant(arch, shape, args, tag, calibrate=True):
    out = os.path.join(REPO, "results", "dryrun_opt.jsonl")
    base = [sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", "pod",
            "--out", out, "--skip-existing"]
    if tag:
        base += ["--tag", tag]
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    cmds = [base + args]
    if calibrate and "--step" not in args:
        cmds.append(base + args + ["--calibrate"])
    for cmd in cmds:
        print(">>", " ".join(cmd[3:]), flush=True)
        r = subprocess.run(cmd, env=env, cwd=REPO)
        if r.returncode:
            print(f"!! variant failed: {tag}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=int, default=None,
                    help="run a single variant index")
    args = ap.parse_args()
    for i, (arch, shape, extra, tag) in enumerate(VARIANTS):
        if args.only is not None and i != args.only:
            continue
        run_variant(arch, shape, extra, tag)


if __name__ == "__main__":
    main()
