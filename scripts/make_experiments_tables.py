"""Generate the EXPERIMENTS.md data tables from results/*.json(l).

Usage: PYTHONPATH=src:. python scripts/make_experiments_tables.py
Writes markdown fragments to results/tables/*.md which EXPERIMENTS.md
references (and inlines at finalization).
"""

from __future__ import annotations

import json
import os
import sys
from collections import OrderedDict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import roofline


def dedupe(rows):
    seen = OrderedDict()
    for r in rows:
        key = (r["arch"], r["shape"], r["mesh"], r.get("step"),
               r.get("tag", ""))
        seen[key] = r          # last write wins
    return list(seen.values())


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def dryrun_table(rows) -> str:
    hdr = ("| arch | shape | mesh | step | args GiB/dev | temps GiB/dev | "
           "flops/dev | coll MB/dev | compile s |\n" + "|---|" * 9 + "\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r.get('step')} | FAIL: {r.get('error')} | | | | |")
            continue
        mem = r.get("memory", {})
        cost = r.get("cost", {})
        coll = r.get("collectives", {}).get("bytes_per_device", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['step']} | "
            f"{fmt_bytes(mem.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes', 0))} | "
            f"{cost.get('flops', 0):.3e} | {coll / 2**20:.1f} | "
            f"{r.get('compile_s', 0):.0f} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    os.makedirs("results/tables", exist_ok=True)
    all_rows = []
    for f in ("results/dryrun.jsonl", "results/dryrun_mp.jsonl",
              "results/calib.jsonl", "results/calib_mp.jsonl",
              "results/dryrun_el.jsonl", "results/dryrun_opt.jsonl"):
        all_rows += roofline.load_records([f])
    rows = dedupe(all_rows)
    calib = roofline.calibration_index(rows)
    main = [r for r in rows if not r.get("tag", "").startswith("calib")]
    ok = [r for r in main if r.get("ok")]
    print(f"{len(main)} unique main combos ({len(calib)} calibrated), "
          f"{len(main) - len(ok)} failures")

    with open("results/tables/dryrun.md", "w") as f:
        f.write(dryrun_table(main))

    roof = []
    for r in ok:
        a = roofline.analyze(r, calib)
        if a:
            roof.append(a)
    roof.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"], r["step"]))
    with open("results/tables/roofline.md", "w") as f:
        f.write(roofline.markdown_table(roof))
    with open("results/tables/roofline.json", "w") as f:
        json.dump(roof, f, indent=1, default=str)

    # dominant-term summary
    from collections import Counter
    doms = Counter((r["shape"], r["dominant"]) for r in roof
                   if r["mesh"] == "16x16" and r["step"] != "el_round")
    print("dominant terms (16x16 baseline):")
    for (shape, dom), n in sorted(doms.items()):
        print(f"  {shape:12s} {dom:10s} x{n}")


if __name__ == "__main__":
    main()
