"""The bench-regression gate → exit codes CI can act on.

Three modes over the committed bench artifacts (``BENCH_el.json``,
``BENCH_fleet.json``) and the known-regression ledger
(``BENCH_ledger.json``):

  * **default** (no flags) — validate the committed baselines
    themselves: every ledgered row is checked against its in-run
    reference (within-run ratios are host-speed independent), and every
    compiled EL row's recorded collective census / alias bytes is
    checked against the declarative contracts (sharded rows
    gather-before-reduce: ``all-reduce == 0``; donated rows alias the
    param tree, non-donated rows alias nothing), and every telemetry
    tier's recorded within-run overhead must sit under the
    ``repro.obs`` acceptance bound (<10%/aggregation);
  * ``--fresh FILE [--baseline FILE] --bench el|fleet`` — row-by-row
    comparison of a fresh same-config run against a baseline with the
    per-metric relative tolerances (``repro.obs.regress.
    DEFAULT_TOLERANCES``), plus the ledger/contract checks on the
    fresh rows;
  * ``--smoke`` — the CI gate: run a small ``bench_el.py`` on the
    debug mesh, check contracts + ledger on the fresh rows, and
    compare WITHIN-RUN tier ratios (sharded/replicated,
    donate/bare) against the committed baseline — sizes and host
    speed differ between a CI smoke and the committed run, but a
    sharded tier suddenly costing 3x when the baseline says 1.2x is
    structural.  The smoke run is appended to ``BENCH_history.jsonl``.

Exit codes: ``0`` ok · ``1`` regression (gate fails) · ``2`` usage/IO
error · ``3`` failing-better (a ledgered regression is FIXED — remove
the stale ``BENCH_ledger.json`` entry and keep the win).

    PYTHONPATH=src python scripts/bench_check.py            # baselines
    PYTHONPATH=src python scripts/bench_check.py --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from typing import Any, Dict, List, Mapping, Optional, Sequence

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.prof import (DEFAULT_GATHER_RANGE, CollectiveContract,
                            ProgramProfile)
from repro.obs.regress import (Finding, LedgerEntry, append_history,
                               check_ledger, compare_ratios,
                               compare_to_baseline, load_ledger,
                               worst_exit_code)

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")

#: within-run tier ratios the smoke gate tracks (row, reference); the
#: us_per_aggregation ratio between these pairs is scale-robust
SMOKE_PAIRS = (
    ("el_sync_sharded", "el_sync_ingraph"),
    ("el_sync_sharded_donate", "el_sync_ingraph"),
    ("el_async_sharded", "el_async_ingraph"),
    ("el_async_sharded_donate", "el_async_ingraph"),
    ("el_sync_ingraph_telemetry", "el_sync_ingraph"),
    ("el_async_ingraph_telemetry", "el_async_ingraph"),
    ("el_async_ingraph_batched", "el_async_ingraph"),
    ("el_sync_ingraph_churn", "el_sync_ingraph"),
)

#: the instrumentation acceptance bound: an in-graph add-on tier (the
#: telemetry rings, the scenario engine's churn path) may cost at most
#: this much per aggregation over the bare program (a within-run
#: percentage, so host-speed independent)
TELEMETRY_OVERHEAD_PCT = 10.0


def telemetry_findings(rows: Mapping[str, Mapping[str, Any]],
                       *, bench: str = "el") -> List[Finding]:
    """The per-round overhead tolerance rows: every tier that recorded a
    within-run ``overhead_vs_ingraph_pct`` (the ``*_telemetry`` rings,
    the ``*_churn`` scenario path) must sit under
    :data:`TELEMETRY_OVERHEAD_PCT`."""
    findings: List[Finding] = []
    for name in sorted(rows):
        pct = rows[name].get("overhead_vs_ingraph_pct")
        if pct is None:
            continue
        if pct > TELEMETRY_OVERHEAD_PCT:
            findings.append(Finding(
                "regression", bench, name, "telemetry_overhead",
                f"in-graph add-on costs {pct:+.2f}%/agg over the bare "
                f"program (bound: +{TELEMETRY_OVERHEAD_PCT:.0f}%)"))
        else:
            findings.append(Finding(
                "ok", bench, name, "telemetry_overhead",
                f"{pct:+.2f}% <= +{TELEMETRY_OVERHEAD_PCT:.0f}%"))
    return findings


def _row_profile(row: Mapping[str, Any]) -> ProgramProfile:
    """Rehydrate the profile-shaped fields of a BENCH row (enough for a
    :class:`CollectiveContract` check)."""
    return ProgramProfile(
        alias_bytes=row.get("alias_bytes"),
        collectives=row.get("collectives") or {},
    )


def contract_findings(rows: Mapping[str, Mapping[str, Any]],
                      *, bench: str = "el") -> List[Finding]:
    """The declarative contracts over recorded BENCH rows.

    * ``*_sharded*`` rows: gather-before-reduce — at least one
      all-gather, zero all-reduce / reduce-scatter / all-to-all;
    * other compiled rows: no collectives at all;
    * ``*_donate`` rows: ``alias_bytes > 0`` and identical across every
      donated row of the bench (one param tree — one alias size);
    * non-donated rows: ``alias_bytes == 0``.

    Host rows (no census recorded) are skipped.
    """
    findings: List[Finding] = []
    donate_alias: Dict[str, int] = {}
    for name in sorted(rows):
        row = rows[name]
        if "collectives" not in row:
            continue                      # host rows carry no profile
        donated = name.endswith("_donate")
        if "sharded" in name:
            counts = {"all-gather": DEFAULT_GATHER_RANGE,
                      "all-reduce": 0, "reduce-scatter": 0,
                      "all-to-all": 0}
        else:
            counts = {"all-gather": 0, "all-reduce": 0,
                      "reduce-scatter": 0, "all-to-all": 0,
                      "collective-permute": 0}
        contract = CollectiveContract(
            name=name, counts=counts,
            alias_bytes=None if donated else 0)
        for bad in contract.check(_row_profile(row)):
            findings.append(Finding("regression", bench, name,
                                    "contract", bad))
        alias = row.get("alias_bytes")
        if donated:
            if not isinstance(alias, int) or alias <= 0:
                findings.append(Finding(
                    "regression", bench, name, "contract",
                    f"donated row aliased {alias!r} bytes (expected the "
                    "param tree > 0 — donation fell off)"))
            else:
                donate_alias[name] = alias
    if len(set(donate_alias.values())) > 1:
        findings.append(Finding(
            "regression", bench, "/".join(sorted(donate_alias)),
            "contract",
            f"donated rows alias different byte counts: {donate_alias} "
            "(one param tree must alias one size)"))
    if not findings:
        findings.append(Finding("ok", bench, "*", "contract",
                                "census + alias contracts hold"))
    return findings


def _load_rows(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)["rows"]


def _report(findings: Sequence[Finding]) -> int:
    for f in findings:
        if f.kind != "ok":
            print(f)
    code = worst_exit_code(findings)
    n_reg = sum(f.kind == "regression" for f in findings)
    n_fix = sum(f.kind == "fixed" for f in findings)
    n_known = sum(f.kind == "known" for f in findings)
    verdict = {0: "OK", 1: "REGRESSION", 3: "FAILING-BETTER"}[code]
    print(f"bench_check: {verdict} ({len(findings)} checks, "
          f"{n_reg} regressions, {n_known} known, {n_fix} fixed)")
    return code


def check_baselines(args) -> int:
    """Default mode: the committed artifacts must satisfy their own
    ledger and contracts."""
    ledger = load_ledger(args.ledger)
    findings: List[Finding] = []
    for bench, path in (("el", args.el), ("fleet", args.fleet)):
        if not os.path.exists(path):
            print(f"bench_check: missing {path}", file=sys.stderr)
            return 2
        rows = _load_rows(path)
        findings += check_ledger(rows, ledger, bench=bench)
        if bench == "el":
            findings += contract_findings(rows, bench=bench)
            findings += telemetry_findings(rows, bench=bench)
    return _report(findings)


def check_fresh(args) -> int:
    """Fresh-vs-baseline comparison (same-config runs)."""
    baseline = args.baseline or (args.el if args.bench == "el"
                                 else args.fleet)
    for p in (args.fresh, baseline):
        if not os.path.exists(p):
            print(f"bench_check: missing {p}", file=sys.stderr)
            return 2
    ledger = load_ledger(args.ledger)
    fresh = _load_rows(args.fresh)
    findings = compare_to_baseline(
        _load_rows(baseline), fresh, bench=args.bench, ledger=ledger)
    findings += check_ledger(fresh, ledger, bench=args.bench)
    if args.bench == "el":
        findings += contract_findings(fresh, bench=args.bench)
        findings += telemetry_findings(fresh, bench=args.bench)
    return _report(findings)


def run_smoke(args) -> int:
    """The CI gate: a small fresh bench_el run on the debug mesh,
    contract-checked and ratio-compared against the committed baseline."""
    if not os.path.exists(args.el):
        print(f"bench_check: missing baseline {args.el}", file=sys.stderr)
        return 2
    out = os.path.join(tempfile.mkdtemp(prefix="bench_smoke_"),
                       "BENCH_el_smoke.json")
    cmd = [sys.executable,
           os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "bench_el.py"),
           "--devices", str(args.devices), "--edges", "4",
           "--samples", "512", "--batch", "64", "--budget", "300",
           "--max-rounds", "16", "--max-events", "64", "--repeats", "2",
           "--skip-host", "--no-history", "--out", out]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), env.get("PYTHONPATH", "")])
    print("bench_check: smoke run:", " ".join(cmd), flush=True)
    proc = subprocess.run(cmd, env=env)
    if proc.returncode != 0:
        print("bench_check: smoke bench failed", file=sys.stderr)
        return 2
    with open(out) as f:
        smoke = json.load(f)
    ledger = load_ledger(args.ledger)
    findings = contract_findings(smoke["rows"], bench="el")
    findings += compare_ratios(
        _load_rows(args.el), smoke["rows"], bench="el",
        metric="us_per_aggregation", pairs=SMOKE_PAIRS, ledger=ledger,
        slack=args.slack)
    findings += check_ledger(smoke["rows"], ledger, bench="el")
    if not args.no_history:
        append_history(args.history, "el-smoke", smoke["meta"],
                       smoke["rows"])
        print(f"bench_check: appended smoke run to {args.history}")
    return _report(findings)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="bench regression gate over BENCH_*.json")
    ap.add_argument("--el", default=os.path.join(ROOT, "BENCH_el.json"))
    ap.add_argument("--fleet",
                    default=os.path.join(ROOT, "BENCH_fleet.json"))
    ap.add_argument("--ledger",
                    default=os.path.join(ROOT, "BENCH_ledger.json"))
    ap.add_argument("--history",
                    default=os.path.join(ROOT, "BENCH_history.jsonl"))
    ap.add_argument("--fresh", help="fresh BENCH json to compare")
    ap.add_argument("--baseline",
                    help="baseline for --fresh (default: the committed "
                         "artifact of --bench)")
    ap.add_argument("--bench", choices=("el", "fleet"), default="el",
                    help="which bench --fresh came from")
    ap.add_argument("--smoke", action="store_true",
                    help="run a small bench_el and gate on within-run "
                         "tier ratios + contracts (the CI step)")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced host devices for --smoke")
    ap.add_argument("--slack", type=float, default=1.5,
                    help="allowed relative worsening of within-run "
                         "ratios in --smoke (1.5 = 150%%)")
    ap.add_argument("--no-history", action="store_true",
                    help="skip the BENCH_history.jsonl append in "
                         "--smoke")
    args = ap.parse_args(argv)
    if args.smoke:
        return run_smoke(args)
    if args.fresh:
        return check_fresh(args)
    return check_baselines(args)


if __name__ == "__main__":
    raise SystemExit(main())
