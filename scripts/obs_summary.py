"""Summarize (and CI-assert) a ``--metrics-out`` artifact set.

Reads the three files the launchers write — ``PATH`` (Prometheus text),
``PATH.json`` (the same registry as JSON) and ``PATH.spans.jsonl`` (the
streamed tracer spans/events) — and prints a human summary: every
metric with its samples, plus per-span-name duration stats aggregated
from the JSONL.

CI assertion flags (exit non-zero on violation):

  * ``--check NAME[,NAME...]``         — these metric names must appear
    in the Prometheus exposition (and it must parse strictly);
  * ``--require-spans NAME[,NAME...]`` — the spans JSONL must contain at
    least one span/event per name.

    PYTHONPATH=src python scripts/obs_summary.py /tmp/fleet.prom \
        --check fleet_waves_total,fleet_compiles_total \
        --require-spans fleet.compile,cohort.wave,cohort.refill
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.metrics import parse_prometheus
from repro.obs.timing import summarize_ns
from repro.obs.trace import read_jsonl


def summarize_spans(events):
    """Per-name span duration stats (+ plain event counts)."""
    spans, counts = {}, {}
    for ev in events:
        name = ev.get("name", "?")
        if ev.get("ev") == "span":
            spans.setdefault(name, []).append(
                int(float(ev.get("dur_us", 0.0)) * 1e3))   # us -> ns
        else:
            counts[name] = counts.get(name, 0) + 1
    return ({n: summarize_ns(s) for n, s in sorted(spans.items())},
            dict(sorted(counts.items())))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="summarize a launcher --metrics-out artifact set")
    ap.add_argument("path", help="the Prometheus text file (PATH); "
                                 "PATH.spans.jsonl is read when present")
    ap.add_argument("--check", default=None, metavar="NAMES",
                    help="comma-separated metric names that must appear "
                         "in the exposition (CI assertion)")
    ap.add_argument("--require-spans", default=None, metavar="NAMES",
                    help="comma-separated span/event names that must "
                         "appear in PATH.spans.jsonl (CI assertion)")
    args = ap.parse_args(argv)

    with open(args.path) as f:
        text = f.read()
    metrics = parse_prometheus(text)
    print(f"{args.path}: {len(metrics)} metrics")
    for name, samples in sorted(metrics.items()):
        vals = ", ".join(
            f"{s['labels'] or ''}{'=' if s['labels'] else ''}"
            f"{s['value']:g}" for s in samples[:4])
        more = f" (+{len(samples) - 4} more)" if len(samples) > 4 else ""
        print(f"  {name}: {vals}{more}")

    prof_names = [n for n in metrics if n.startswith("el_profile_")]
    if prof_names:
        print("\nprogram profile (repro.obs.prof):")
        for scalar in ("el_profile_flops", "el_profile_peak_live_bytes",
                       "el_profile_alias_bytes",
                       "el_profile_collective_bytes"):
            if scalar in metrics:
                v = metrics[scalar][0]["value"]
                print(f"  {scalar.removeprefix('el_profile_')}: {v:g}")
        for s in metrics.get("el_profile_collectives", []):
            op = s["labels"].get("op", "?")
            print(f"  collective {op}: x{s['value']:g}")

    scn_names = [n for n in metrics if n.startswith("el_scenario_")]
    if scn_names:
        print("\nfleet dynamics (repro.el.scenarios):")
        for name in ("el_scenario_active_edges",
                     "el_scenario_dropouts_total",
                     "el_scenario_rejoins_total"):
            if name in metrics:
                v = metrics[name][0]["value"]
                print(f"  {name.removeprefix('el_scenario_')}: {v:g}")

    spans_path = args.path + ".spans.jsonl"
    span_names = set()
    if os.path.exists(spans_path):
        events = read_jsonl(spans_path)
        span_names = {e.get("name") for e in events}
        stats, counts = summarize_spans(events)
        print(f"\n{spans_path}: {len(events)} records")
        for name, st in stats.items():
            print(f"  span {name}: n={st['count']} "
                  f"p50={st['p50'] / 1e3:.0f}us "
                  f"p90={st['p90'] / 1e3:.0f}us "
                  f"max={st['max'] / 1e3:.0f}us")
        for name, n in counts.items():
            print(f"  event {name}: n={n}")

    failures = []
    if args.check:
        for name in args.check.split(","):
            if name and name not in metrics:
                failures.append(f"metric {name!r} missing from "
                                f"{args.path}")
    if args.require_spans:
        if not os.path.exists(spans_path):
            failures.append(f"{spans_path} not found")
        else:
            for name in args.require_spans.split(","):
                if name and name not in span_names:
                    failures.append(f"span/event {name!r} missing from "
                                    f"{spans_path}")
    if failures:
        for f_ in failures:
            print(f"ERROR: {f_}", file=sys.stderr)
        raise SystemExit(1)
    if args.check or args.require_spans:
        print("\nall checks passed")


if __name__ == "__main__":
    main()
